#!/usr/bin/env python
"""Static invariant checker CLI — the front door of ``repro.analysis``.

Subcommands::

    lint        trace-purity lint (TP00x) over src/repro
    artifacts   tuned-DB (AR00x) + bench-baseline (BA00x) validation
    coverage    sharding-rule coverage (SH00x) of all model families
    stats       Engine.stats() keys vs the versioned schema (ST001)
    report      all of the above + the committed-baseline ratchet gate

``report`` is what CI runs: errors not present in
``tests/analysis_baseline.json`` fail the build (exit 1); warnings are
printed but never fail.  ``--update-baseline`` blesses the current error
set as the new floor — shrink it, don't grow it.  ``--json FILE`` writes
the findings (any subcommand) for the step-summary renderer and the
uploaded artifact.

Run it locally before pushing::

    PYTHONPATH=src python scripts/analyze.py report

Check catalog and waiver workflow: docs/STATIC_ANALYSIS.md.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _lint_findings():
    from repro.analysis.callgraph import CallGraph
    from repro.analysis.purity import PurityChecker
    graph = CallGraph(REPO_ROOT)
    findings = PurityChecker(graph).run()
    return findings, graph


def _artifact_findings():
    from repro.analysis.artifacts import (validate_baselines_dir,
                                          validate_tuned_dir)
    out = validate_tuned_dir(os.path.join(REPO_ROOT, "tuned"),
                             root=REPO_ROOT)
    out += validate_baselines_dir(
        os.path.join(REPO_ROOT, "benchmarks", "baselines"), root=REPO_ROOT)
    return out


def _coverage_findings():
    from repro.analysis.coverage import check_coverage
    return check_coverage()


def _stats_findings():
    from repro.analysis.stats_checks import check_stats_schema
    return check_stats_schema(REPO_ROOT)


def _emit(findings, args, extra_blob=None):
    from repro.analysis.findings import SEV_ERROR, sort_findings
    findings = sort_findings(findings)
    for f in findings:
        print(f.render())
    errors = [f for f in findings if f.severity == SEV_ERROR]
    warnings = [f for f in findings if f.severity != SEV_ERROR]
    print(f"[analyze] {len(errors)} error(s), {len(warnings)} warning(s)")
    if getattr(args, "json", None):
        blob = {"findings": [f.to_json() for f in findings],
                "errors": len(errors), "warnings": len(warnings)}
        blob.update(extra_blob or {})
        with open(args.json, "w") as fh:
            json.dump(blob, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"[analyze] wrote {args.json}")
    return errors, warnings


def cmd_lint(args):
    findings, graph = _lint_findings()
    if args.verbose:
        for info in graph.traced_functions():
            print(f"[traced] {info.key}  <- {graph.traced_via[info.key]}")
    errors, _ = _emit(findings, args,
                      {"traced_functions": len(graph.traced)})
    return 1 if errors and args.strict else 0


def cmd_artifacts(args):
    errors, _ = _emit(_artifact_findings(), args)
    return 1 if errors and args.strict else 0


def cmd_coverage(args):
    from repro.analysis.coverage import coverage_summary
    findings = _coverage_findings()
    summary = coverage_summary() if args.summary else None
    if summary:
        for family, kinds in summary.items():
            stat = ", ".join(
                f"{kind}: {v['sharded']}/{v['leaves']} leaves sharded"
                for kind, v in kinds.items())
            print(f"[coverage] {family}: {stat}")
    errors, _ = _emit(findings, args, {"coverage": summary} if summary
                      else None)
    return 1 if errors and args.strict else 0


def cmd_stats(args):
    errors, _ = _emit(_stats_findings(), args)
    return 1 if errors and args.strict else 0


def cmd_report(args):
    from repro.analysis.findings import (load_baseline, ratchet,
                                         save_baseline, SEV_ERROR)
    findings, graph = _lint_findings()
    findings = (findings + _artifact_findings() + _coverage_findings()
                + _stats_findings())
    errors, warnings = _emit(findings, args,
                             {"traced_functions": len(graph.traced)})

    baseline_path = args.baseline
    if args.update_baseline:
        path = save_baseline(errors, baseline_path)
        print(f"[analyze] baseline blessed -> {path} "
              f"({len(errors)} finding(s))")
        return 0

    baseline = load_baseline(baseline_path)
    new, fixed = ratchet(errors, baseline)
    if fixed:
        print(f"[analyze] {len(fixed)} baseline finding(s) no longer fire "
              f"— ratchet forward with --update-baseline:")
        for key in fixed:
            print(f"  fixed: {key}")
    if new:
        print(f"[analyze] FAIL: {len(new)} finding(s) not in the baseline "
              f"({len(baseline)} tolerated):")
        for f in new:
            print(f"  new: {f.render()}")
        print("[analyze] fix them, pragma a sanctioned exception "
              "(# analysis: allow(<id>)), or — exceptionally — bless with "
              "--update-baseline")
        return 1
    print(f"[analyze] ok: no findings beyond the baseline "
          f"({len(baseline)} tolerated, {len(warnings)} warning(s))")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[1],
                                 prog="analyze.py")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p, strict_default=False):
        p.add_argument("--json", help="write findings JSON to this path")
        p.add_argument("--strict", action="store_true",
                       default=strict_default,
                       help="exit 1 on any error finding (no baseline)")

    p = sub.add_parser("lint", help="trace-purity lint (TP00x)")
    common(p)
    p.add_argument("--verbose", action="store_true",
                   help="also print the traced function set")
    p.set_defaults(fn=cmd_lint)

    p = sub.add_parser("artifacts",
                       help="tuned-DB + bench-baseline validation "
                            "(AR00x/BA00x)")
    common(p)
    p.set_defaults(fn=cmd_artifacts)

    p = sub.add_parser("coverage",
                       help="sharding-rule coverage of model families "
                            "(SH00x)")
    common(p)
    p.add_argument("--summary", action="store_true",
                   help="print per-family sharded-leaf statistics")
    p.set_defaults(fn=cmd_coverage)

    p = sub.add_parser("stats",
                       help="Engine.stats() key set vs the versioned "
                            "stats schema (ST001)")
    common(p)
    p.set_defaults(fn=cmd_stats)

    p = sub.add_parser("report",
                       help="all checks + the committed-baseline ratchet "
                            "gate (what CI runs)")
    p.add_argument("--json", help="write findings JSON to this path")
    p.add_argument("--baseline",
                   help="ratchet file (default tests/analysis_baseline.json)")
    p.add_argument("--update-baseline", action="store_true",
                   help="bless the current error findings as the new floor")
    p.set_defaults(fn=cmd_report)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
