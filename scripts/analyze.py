#!/usr/bin/env python
"""Compatibility shim — the analyzer CLI lives in ``repro.analysis.cli``.

Equivalent invocations::

    python scripts/analyze.py <cmd>
    PYTHONPATH=src python -m repro.analysis <cmd>
    repro-analyze <cmd>                       # installed console script
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
