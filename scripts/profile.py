#!/usr/bin/env python
"""Profile the serve/train paths and render where the time goes vs could go.

    python scripts/profile.py serve --mesh data=4,model=2
    python scripts/profile.py train --steps 3
    python scripts/profile.py diff PROFILE_serving.json PROFILE_other.json

``serve``/``train`` run a reduced workload twice — a warmup pass compiles
everything OUTSIDE the trace, then the measured pass runs under
``repro.profiling.trace`` — post-process the capture into the per-op-family
breakdown (collective vs GEMM vs attention vs host-transfer device time,
host-sync counts, ``serve.*``/``train.*`` annotation spans), attach the
analytic roofline of the same step (HLO-derived compute/memory/collective
terms against the hardware profile's peaks), and write a schema-valid
``PROFILE_<kind>.json``.  The report prints both side by side: the measured
breakdown is "where the time goes", the roofline is "where it could go".

``--mesh data=N,model=M`` forces the host to expose enough devices (the
XLA flag must precede jax's first init, which is why this script sets it
before importing jax).  ``diff`` compares two PROFILE files family by
family — e.g. the same serve workload before/after a sharding change.

The CI profiling leg runs ``serve --mesh data=4,model=2`` and fails on any
schema violation (``validate_profile``) — op families missing, zero totals,
or a trace that captured nothing.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def _mesh_devices(spec):
    """Device count a --mesh spec needs (None for no/auto mesh) — computed
    WITHOUT importing jax/repro so the device-count flag can still be set."""
    if not spec or spec.strip() == "auto":
        return None
    n = 1
    for part in spec.split(","):
        part = part.strip()
        if "=" in part:
            n *= int(part.partition("=")[2])
    return n


def _ensure_devices(n: int) -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = " ".join(filter(None, [
            flags, f"--xla_force_host_platform_device_count={n}"]))


# ---------------------------------------------------------------------------
# Roofline of the measured step (the "where it could go" column)
# ---------------------------------------------------------------------------

def _roofline(lowered_fn, args_, kind, arch, mesh, model_flops, hardware):
    """Lower+compile the step, run the trip-count-corrected HLO analyzer,
    and return the roofline row (None when the profile is unregistered or
    the lowering fails — the roofline is advisory, never fatal)."""
    try:
        import jax
        from repro.core.hardware import get_profile
        from repro.launch.hlo_stats import analyze_hlo
        from repro.launch.mesh import mesh_axis_label
        from repro.launch.roofline import roofline_row
        chips = int(mesh.size) if mesh is not None else 1
        hlo = jax.jit(lowered_fn).lower(*args_).compile().as_text()
        stats = analyze_hlo(hlo, default_group=chips)
        rec = {
            "status": "OK", "arch": arch, "kind": kind,
            "shape": kind, "mesh": mesh_axis_label(mesh) or "single",
            "chips": chips, "model_flops": model_flops,
            "hlo_stats": {
                "flops": stats.flops,
                "traffic_bytes": stats.traffic_bytes,
                "collective_link_bytes": stats.collective_link_bytes,
                "collective_count": stats.collective_count,
            },
        }
        return roofline_row(rec, get_profile(hardware))
    except Exception as e:      # advisory: report the miss, keep the profile
        print(f"[roofline] skipped: {type(e).__name__}: {e}")
        return None


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------

def _fmt_us(us: float) -> str:
    return f"{us / 1e3:.2f}ms" if us >= 1e3 else f"{us:.0f}us"


def render(blob: dict) -> None:
    fams = blob["families"]
    print(f"\n[profile] kind={blob['kind']} hardware={blob['hardware']} "
          f"mesh={blob['mesh'] or 'single'}")
    print(f"[profile] device-op time {_fmt_us(blob['totals']['op_us'])} over "
          f"wall {_fmt_us(blob['totals']['wall_us'])}; "
          f"host syncs: {blob['host_syncs']}")
    print("[profile] family breakdown (device time):")
    for fam, e in fams.items():
        bar = "#" * int(round(e["fraction"] * 40))
        print(f"  {fam:14s} {_fmt_us(e['us']):>10s} {e['fraction']*100:5.1f}% "
              f"(n={e['count']:<5d}) {bar}")
    if blob.get("annotations"):
        print("[profile] annotated spans (wall time):")
        for name, e in blob["annotations"].items():
            print(f"  {name:22s} {_fmt_us(e['us']):>10s} (n={e['count']})")
    top = blob.get("top_ops") or []
    if top:
        ops = ", ".join(f"{o['name']}={_fmt_us(o['us'])}" for o in top[:6])
        print(f"[profile] top ops: {ops}")
    r = blob.get("roofline")
    if r:
        print(f"[roofline] analytic bounds on {r['hardware']} "
              f"({r['chips']} chip(s)): compute {r['compute_s']*1e6:.1f}us | "
              f"memory {r['memory_s']*1e6:.1f}us | "
              f"collective {r['collective_s']*1e6:.1f}us "
              f"-> dominant: {r['dominant']}")
        terms = {"compute": r["compute_s"], "memory": r["memory_s"],
                 "collective": r["collective_s"]}
        total = sum(terms.values()) or 1.0
        meas_coll = fams["collective"]["fraction"]
        print(f"[compare] collective share — measured {meas_coll*100:.1f}% "
              f"vs roofline {terms['collective']/total*100:.1f}%: a large "
              "measured excess means collectives are NOT overlapped "
              "(latency-hiding headroom)")


def _write(blob: dict, out: str) -> None:
    from repro.profiling import validate_profile
    validate_profile(blob)
    with open(out, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"[profile] wrote {out}")


# ---------------------------------------------------------------------------
# serve | train | diff
# ---------------------------------------------------------------------------

def cmd_serve(args) -> None:
    import jax
    from repro.configs.catalog import get_config
    from repro.core.hardware import resolve_hardware
    from repro.launch.mesh import build_mesh, mesh_axis_label
    from repro.models import build_model
    from repro.models.model import active_param_count
    from repro.profiling import build_profile, trace
    from repro.serve import Engine, ServeConfig

    hardware = resolve_hardware(args.hardware)
    mesh = build_mesh(args.mesh, hardware=hardware) if args.mesh else None
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [[(7 * i + j) % cfg.vocab_size for j in range(args.plen)]
               for i in range(args.batch)]
    eng = Engine(model, params,
                 ServeConfig(max_batch=args.batch, max_len=args.max_len,
                             profile=True, hardware=hardware, mesh=mesh))
    print("[profile] warmup (compile, outside the trace)...")
    eng.generate(prompts, args.max_new)
    trace_dir = args.trace_dir or tempfile.mkdtemp(prefix="repro-trace-serve-")
    print(f"[profile] tracing into {trace_dir} ...")
    with trace(trace_dir):
        eng.generate(prompts, args.max_new)

    st = eng.stats()
    roof = _roofline(
        eng._with_mesh(model.decode_step),
        (eng.params, jax.numpy.zeros((args.batch, 1), jax.numpy.int32),
         eng._cache, jax.numpy.int32(0),
         jax.numpy.zeros((args.batch,), jax.numpy.int32)),
        "decode", cfg.name, mesh,
        2 * active_param_count(model) * args.batch, hardware)
    blob = build_profile(
        "serving", trace_dir=trace_dir, hardware=hardware,
        mesh=mesh_axis_label(mesh), roofline=roof,
        extra={"engine": {
            "decode_tok_s": (st["tokens_generated"] / st["decode_seconds"]
                             if st["decode_seconds"] else 0.0),
            "device_transfers": st["device_transfers"],
            "waves": st["waves"],
            "decode_unroll": st["decode_unroll"],
            "decode_unroll_source": st["decode_unroll_source"],
        }})
    _write(blob, args.out)
    render(blob)


def cmd_train(args) -> None:
    import jax
    from repro.configs.catalog import get_config
    from repro.core.hardware import resolve_hardware
    from repro.data import DataConfig, TokenPipeline
    from repro.distributed import sharding as sh
    from repro.launch.mesh import build_mesh, mesh_axis_label
    from repro.models import build_model
    from repro.models.model import active_param_count
    from repro.optim import AdamW
    from repro.profiling import annotate, build_profile, trace
    from repro.train import Trainer, TrainerConfig, init_train_state

    hardware = resolve_hardware(args.hardware)
    mesh = build_mesh(args.mesh, hardware=hardware) if args.mesh else None
    rules = sh.rules_for_mesh(mesh) if mesh is not None else None
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    opt = AdamW(learning_rate=1e-3)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq_len,
                                    global_batch=args.batch))
    trainer = Trainer(model, opt, pipe,
                      TrainerConfig(total_steps=args.steps + 1, log_every=10),
                      mesh=mesh, rules=rules)
    state = init_train_state(model, opt, jax.random.PRNGKey(0), False)
    print("[profile] warmup step (compile, outside the trace)...")
    state, metrics = trainer._step(state, trainer.data_iter(0))
    jax.block_until_ready(metrics["loss"])
    trace_dir = args.trace_dir or tempfile.mkdtemp(prefix="repro-trace-train-")
    print(f"[profile] tracing {args.steps} step(s) into {trace_dir} ...")
    with trace(trace_dir):
        for i in range(1, args.steps + 1):
            with annotate("train.step"):
                state, metrics = trainer._step(state, trainer.data_iter(i))
        jax.block_until_ready(metrics["loss"])

    roof = _roofline(
        lambda s, b: trainer._step(s, b), (state, trainer.data_iter(0)),
        "train", cfg.name, mesh,
        6 * active_param_count(model) * args.batch * args.seq_len, hardware)
    blob = build_profile("training", trace_dir=trace_dir, hardware=hardware,
                         mesh=mesh_axis_label(mesh), roofline=roof,
                         extra={"steps_traced": args.steps})
    _write(blob, args.out)
    render(blob)


def cmd_diff(args) -> None:
    from repro.profiling import FAMILIES, validate_profile
    with open(args.a) as f:
        a = validate_profile(json.load(f))
    with open(args.b) as f:
        b = validate_profile(json.load(f))
    print(f"[diff] A={args.a} (kind={a['kind']}, mesh={a['mesh']}) "
          f"vs B={args.b} (kind={b['kind']}, mesh={b['mesh']})")
    print(f"  {'family':14s} {'A':>10s} {'B':>10s} {'B/A':>7s}")
    for fam in FAMILIES:
        ua, ub = a["families"][fam]["us"], b["families"][fam]["us"]
        ratio = f"{ub / ua:.2f}x" if ua else "-"
        print(f"  {fam:14s} {_fmt_us(ua):>10s} {_fmt_us(ub):>10s} {ratio:>7s}")
    wa, wb = a["totals"]["wall_us"], b["totals"]["wall_us"]
    print(f"  {'wall':14s} {_fmt_us(wa):>10s} {_fmt_us(wb):>10s} "
          f"{(wb / wa if wa else 0):.2f}x")
    print(f"  host syncs: {a['host_syncs']} -> {b['host_syncs']}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p):
        p.add_argument("--arch", default="llama3.2-1b")
        p.add_argument("--full", action="store_true",
                       help="full-size config (default: reduced, CPU-runnable)")
        p.add_argument("--mesh", default=None,
                       help="'data=N,model=M' (forces host device count)")
        p.add_argument("--hardware", default=None)
        p.add_argument("--batch", type=int, default=8)
        p.add_argument("--trace-dir", default=None,
                       help="keep the raw trace here (default: temp dir)")

    ps = sub.add_parser("serve", help="profile a serve-engine generate call")
    common(ps)
    ps.add_argument("--plen", type=int, default=16)
    ps.add_argument("--max-new", type=int, default=16)
    ps.add_argument("--max-len", type=int, default=256)
    ps.add_argument("--out", default="PROFILE_serving.json")

    pt = sub.add_parser("train", help="profile training steps")
    common(pt)
    pt.add_argument("--steps", type=int, default=2)
    pt.add_argument("--seq-len", type=int, default=32)
    pt.add_argument("--out", default="PROFILE_training.json")

    pd = sub.add_parser("diff", help="compare two PROFILE_*.json files")
    pd.add_argument("a")
    pd.add_argument("b")

    args = ap.parse_args()
    if args.cmd in ("serve", "train"):
        n = _mesh_devices(args.mesh)
        if n and n > 1:
            _ensure_devices(n)
    {"serve": cmd_serve, "train": cmd_train, "diff": cmd_diff}[args.cmd](args)


if __name__ == "__main__":
    main()
