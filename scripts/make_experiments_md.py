"""Regenerate the data-driven tables of EXPERIMENTS.md from results/dryrun.json."""
import json, sys
sys.path.insert(0, "src")
from repro.launch.roofline import load_rows, markdown_table, roofline_row, fmt_s

results = json.load(open("results/dryrun.json"))

def dryrun_summary():
    rows = ["| arch | shape | mesh | status | state bytes/dev | compile s | collectives (count/dev/step) |",
            "|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        if "#" in key:
            continue
        r = results[key]
        if r["status"] == "OK":
            ab = r["memory"].get("argument_bytes")
            ab = f"{ab/1e6:.0f} MB" if ab else "n/a"
            cc = int(r["hlo_stats"]["collective_count"])
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | {ab} | "
                        f"{r['seconds_compile']} | {cc} |")
        else:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | — | — | — |")
    return "\n".join(rows)

def perf_table(cell, order):
    rows = [f"**{cell}**", "",
            "| iteration | compute | memory | collective | dominant | est. step | MFU-proxy | step speedup |",
            "|---|---|---|---|---|---|---|---|"]
    base = None
    for tag in order:
        key = cell if tag == "baseline" else f"{cell}#{tag}"
        if key not in results or results[key].get("status") != "OK":
            rows.append(f"| {tag} | (failed/skipped) | | | | | | |")
            continue
        r = roofline_row(results[key])
        if base is None:
            base = r
        sp = base["est_step_s"] / r["est_step_s"]
        rows.append(f"| {tag} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
                    f"{fmt_s(r['collective_s'])} | {r['dominant']} | {fmt_s(r['est_step_s'])} | "
                    f"{r['mfu_proxy']*100:.1f}% | x{sp:.2f} |")
    return "\n".join(rows)

single_rows, single_skips = load_rows("results/dryrun.json", "single")
multi_rows, multi_skips = load_rows("results/dryrun.json", "multi")

out = {
    "dryrun_summary": dryrun_summary(),
    "roofline_single": markdown_table(single_rows, single_skips),
    "roofline_multi": markdown_table(multi_rows, multi_skips),
    "perf_moonshot": perf_table("moonshot-v1-16b-a3b/train_4k/single",
        ["baseline", "ep-pin", "ep-pin+lc512", "ep-pin+bf16c", "ep-pin+vjp16", "ep-pin+rdots"]),
    "perf_stablelm": perf_table("stablelm-12b/train_4k/single",
        ["baseline", "pbf16", "pbf16+sp", "pbf16+vjp16", "pbf16+rdots"]),
    "perf_whisper": perf_table("whisper-large-v3/train_4k/single",
        ["baseline", "pbf16", "pbf16+sp", "pbf16+vjp16", "pbf16+rdots"]),
}
json.dump(out, open("/tmp/exp_tables.json", "w"))
print("tables written")
