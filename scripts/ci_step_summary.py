#!/usr/bin/env python
"""Render BENCH_*.json / PROFILE_*.json artifacts as step-summary markdown.

    python scripts/ci_step_summary.py BENCH_*.json PROFILE_*.json \
        >> "$GITHUB_STEP_SUMMARY"

CI appends the output of this script to ``$GITHUB_STEP_SUMMARY`` after each
leg so the per-backend benchmark rows and the profiling breakdown are
readable from the run page without downloading artifacts.  Missing files are
skipped silently (a leg that failed upstream simply contributes no table)
and a malformed file renders as a one-line note instead of failing the
step — the summary is reporting, never a gate.
"""
from __future__ import annotations

import json
import os
import sys


def _stats_md(path: str, blob: dict) -> list:
    """Engine stats dict (schema v2+): rendered group-by-group from the
    versioned schema, so the summary layout tracks the documented key set
    instead of a hand-picked copy."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.serve import stats_schema

    def _fmt(v):
        if isinstance(v, float):
            return f"{v:.4g}"
        if isinstance(v, dict):
            s = json.dumps(v, sort_keys=True, default=str)
            return f"`{s}`" if len(s) <= 80 else f"({len(v)} entries)"
        if isinstance(v, (list, tuple)):
            s = json.dumps(v, default=str)
            return f"`{s}`" if len(s) <= 80 else f"({len(v)} items)"
        return f"`{v}`" if v is not None else "—"

    title = os.path.basename(path)
    lines = [f"### `{title}` — engine stats schema "
             f"v{blob.get('schema_version', '?')}, scheduler "
             f"`{blob.get('scheduler', '?')}`", ""]
    for group, keys in stats_schema.groups().items():
        present = [k for k in keys if k in blob]
        if not present:
            continue
        lines += [f"**{group}**", "", "| key | value | doc |",
                  "| --- | --- | --- |"]
        for k in present:
            doc = stats_schema.STATS_SCHEMA[k].doc
            lines.append(f"| `{k}` | {_fmt(blob[k])} | {doc} |")
        lines.append("")
    extra = sorted(set(blob) - set(stats_schema.STATS_SCHEMA))
    if extra:
        lines += [f"undocumented keys (ST001 would flag these in "
                  f"`engine.stats()`): `{'`, `'.join(extra)}`", ""]
    return lines


def _bench_md(path: str, blob: dict) -> list:
    title = os.path.basename(path)
    mesh = blob.get("mesh")
    sub = f" — hardware `{blob.get('hardware', '?')}`"
    if mesh:
        sub += f", mesh `{mesh}`"
    lines = [f"### `{title}`{sub}", "",
             "| metric | us/item | derived |", "| --- | ---: | ---: |"]
    for row in blob.get("rows", []):
        lines.append(f"| `{row['name']}` | {row.get('us_per_call', 0.0):.2f} "
                     f"| {row.get('derived', 0.0):.4g} |")
    return lines + [""]


def _findings_md(path: str, blob: dict) -> list:
    """analysis-findings.json from ``scripts/analyze.py --json``."""
    title = os.path.basename(path)
    errors, warnings = blob.get("errors", 0), blob.get("warnings", 0)
    lines = [f"### `{title}` — {errors} error(s), {warnings} warning(s)"
             + (f", {blob['traced_functions']} traced function(s)"
                if "traced_functions" in blob else ""), ""]
    findings = blob.get("findings", [])
    if not findings:
        return lines + ["no findings — every invariant holds", ""]
    lines += ["| check | severity | location | scope | message |",
              "| --- | --- | --- | --- | --- |"]
    for f in findings:
        loc = f"{f.get('path', '?')}:{f['line']}" if f.get("line") \
            else f.get("path", "?")
        lines.append(f"| {f.get('check_id', '?')} | {f.get('severity', '?')} "
                     f"| `{loc}` | `{f.get('scope', '')}` "
                     f"| {f.get('message', '')} |")
    return lines + [""]


def _ir_md(path: str, blob: dict) -> list:
    """IR_REPORT.json from ``repro-analyze ir --json``: one row per traced
    config cell, then the findings table (usually empty)."""
    title = os.path.basename(path)
    gate = "active" if blob.get("hash_gate_active") else \
        (f"inactive (blessed under jax "
         f"{blob.get('fingerprint_jax_version')}, running "
         f"{blob.get('jax_version')})")
    lines = [f"### `{title}` — {len(blob.get('ir_cases', []))} config(s) "
             f"dry-traced in {blob.get('seconds', 0):.0f}s, IR005 hash gate "
             f"{gate}", "",
             "| config | entries | jit keys | peak MiB | loop collectives "
             "| err | warn | cached |",
             "| --- | --- | ---: | ---: | ---: | ---: | ---: | --- |"]
    for row in blob.get("ir_cases", []):
        peaks = [p for p in row.get("peak_bytes", {}).values()
                 if p is not None]
        peak = f"{max(peaks) / 2**20:.1f}" if peaks else "—"
        lines.append(
            f"| `{row['case']}` | {', '.join(row.get('entries', []))} "
            f"| {row.get('jit_keys', {}).get('total', '?')} | {peak} "
            f"| {row.get('while_collectives', 0)} | {row.get('errors', 0)} "
            f"| {row.get('warnings', 0)} "
            f"| {'yes' if row.get('cached') else 'no'} |")
    lines.append("")
    if blob.get("findings") is not None:
        lines += _findings_md(path, blob)
    return lines


def _profile_md(path: str, blob: dict) -> list:
    title = os.path.basename(path)
    lines = [f"### `{title}` — kind `{blob.get('kind', '?')}`, hardware "
             f"`{blob.get('hardware', '?')}`, mesh "
             f"`{blob.get('mesh') or 'single'}`", "",
             f"device-op time {blob['totals']['op_us'] / 1e3:.2f}ms over "
             f"wall {blob['totals']['wall_us'] / 1e3:.2f}ms; "
             f"host syncs: {blob.get('host_syncs', 0)}", "",
             "| family | device time (ms) | share | events |",
             "| --- | ---: | ---: | ---: |"]
    for fam, e in blob.get("families", {}).items():
        lines.append(f"| {fam} | {e['us'] / 1e3:.2f} "
                     f"| {e['fraction'] * 100:.1f}% | {e['count']} |")
    if blob.get("annotations"):
        lines += ["", "| annotated span | wall (ms) | count |",
                  "| --- | ---: | ---: |"]
        for name, e in blob["annotations"].items():
            lines.append(f"| `{name}` | {e['us'] / 1e3:.2f} | {e['count']} |")
    roof = blob.get("roofline")
    if roof:
        lines += ["", f"roofline ({roof['chips']} chip(s)): compute "
                  f"{roof['compute_s'] * 1e6:.1f}us, memory "
                  f"{roof['memory_s'] * 1e6:.1f}us, collective "
                  f"{roof['collective_s'] * 1e6:.1f}us — dominant: "
                  f"**{roof['dominant']}**"]
    return lines + [""]


def main(argv=None) -> int:
    paths = (argv if argv is not None else sys.argv[1:])
    for path in paths:
        if not os.path.exists(path):
            continue
        try:
            with open(path) as f:
                blob = json.load(f)
            if "ir_cases" in blob:
                lines = _ir_md(path, blob)
            elif "findings" in blob:
                lines = _findings_md(path, blob)
            elif "schema_version" in blob and "scheduler" in blob:
                lines = _stats_md(path, blob)
            elif "rows" in blob:
                lines = _bench_md(path, blob)
            elif "families" in blob:
                lines = _profile_md(path, blob)
            else:
                lines = [f"### `{os.path.basename(path)}`", "",
                         "unrecognized artifact shape "
                         "(no findings/rows/families)", ""]
        except Exception as e:
            lines = [f"### `{os.path.basename(path)}`", "",
                     f"unreadable: {type(e).__name__}: {e}", ""]
        print("\n".join(lines))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
