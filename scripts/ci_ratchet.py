#!/usr/bin/env python
"""Tiered test gate with a ratchet against the committed baseline.

    python scripts/ci_ratchet.py --tier fast            # tests minus slow
    python scripts/ci_ratchet.py --tier full            # everything
    python scripts/ci_ratchet.py --tier full --update-baseline

Runs pytest (``--continue-on-collection-errors`` so a broken module never
hides the rest of the suite), parses the JUnit XML, and compares the counts
against ``tests/baseline_status.json``:

* collection/runtime **errors** may not exceed the baseline,
* **failed** may not exceed the baseline (pre-existing failures tolerated,
  new ones fatal),
* **passed** may not drop below the baseline (tests can't silently vanish).

Improvements don't fail the gate — they print a reminder to ratchet the
baseline forward with ``--update-baseline`` so the better state becomes the
new floor.  The seed state (50 passed / 18 failed / 1 skipped, 4 collection
errors) is kept in the file for provenance.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import xml.etree.ElementTree as ET

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO, "tests", "baseline_status.json")

TIERS = {
    "fast": ["-m", "not slow"],
    "full": [],
}


def run_pytest(tier: str, extra):
    xml_path = os.path.join(tempfile.mkdtemp(prefix="ratchet-"), "junit.xml")
    cmd = [sys.executable, "-m", "pytest", "-q", "--tb=line",
           "--continue-on-collection-errors", f"--junit-xml={xml_path}"]
    cmd += TIERS[tier] + list(extra)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    print(f"[ratchet] running: {' '.join(cmd)}", flush=True)
    proc = subprocess.run(cmd, cwd=REPO, env=env)
    if not os.path.exists(xml_path):
        print("[ratchet] FATAL: pytest produced no junit xml "
              f"(exit {proc.returncode})")
        sys.exit(2)
    return parse_junit(xml_path)


def parse_junit(path: str) -> dict:
    root = ET.parse(path).getroot()
    suites = root.iter("testsuite") if root.tag == "testsuites" else [root]
    counts = {"tests": 0, "failed": 0, "errors": 0, "skipped": 0}
    for s in suites:
        counts["tests"] += int(s.get("tests", 0))
        counts["failed"] += int(s.get("failures", 0))
        counts["errors"] += int(s.get("errors", 0))
        counts["skipped"] += int(s.get("skipped", 0))
    counts["passed"] = (counts["tests"] - counts["failed"]
                        - counts["errors"] - counts["skipped"])
    return counts


def load_baseline() -> dict:
    with open(BASELINE) as f:
        return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tier", choices=sorted(TIERS), required=True)
    ap.add_argument("--update-baseline", action="store_true",
                    help="record the observed counts as the new floor")
    ap.add_argument("extra", nargs="*", help="extra pytest args")
    args = ap.parse_args(argv)

    counts = run_pytest(args.tier, args.extra)
    print(f"[ratchet] observed ({args.tier}): {counts}")

    blob = load_baseline()
    if args.update_baseline:
        blob.setdefault("tiers", {})[args.tier] = {
            k: counts[k] for k in ("passed", "failed", "errors", "skipped")}
        with open(BASELINE, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"[ratchet] baseline[{args.tier}] updated -> {BASELINE}")
        return 0

    base = blob.get("tiers", {}).get(args.tier)
    if base is None:
        print(f"[ratchet] no baseline for tier {args.tier!r}; "
              f"run with --update-baseline first")
        return 2

    problems = []
    if counts["errors"] > base["errors"]:
        problems.append(f"errors {counts['errors']} > baseline {base['errors']}")
    if counts["failed"] > base["failed"]:
        problems.append(f"failed {counts['failed']} > baseline {base['failed']}")
    if counts["passed"] < base["passed"]:
        problems.append(f"passed {counts['passed']} < baseline {base['passed']}")

    if problems:
        print(f"[ratchet] REGRESSION vs baseline {base}:")
        for p in problems:
            print(f"[ratchet]   - {p}")
        return 1

    improved = (counts["failed"] < base["failed"]
                or counts["errors"] < base["errors"]
                or counts["passed"] > base["passed"])
    if improved:
        print(f"[ratchet] improved vs baseline {base} — consider "
              f"`python scripts/ci_ratchet.py --tier {args.tier} "
              f"--update-baseline` to ratchet the floor forward")
    else:
        print(f"[ratchet] matches baseline {base}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
