#!/usr/bin/env python
"""Docs gate: fail on broken intra-repo markdown links.

    python scripts/check_docs.py            # check every tracked *.md
    python scripts/check_docs.py README.md  # check specific files

Scans ``[text](target)`` links in the repo's markdown files and verifies
that every *relative* target resolves to an existing file or directory
(anchors and external http(s)/mailto links are skipped).  Run by the CI
``docs`` job next to ``make_experiments_md.py --check``.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules"}
# [text](target) with no nested parens in the target; images included
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")


def md_files():
    for root, dirs, files in os.walk(REPO):
        dirs[:] = [d for d in dirs if d not in SKIP_DIRS]
        for f in sorted(files):
            if f.endswith(".md"):
                yield os.path.join(root, f)


def check_file(path: str):
    """Yields (lineno, target, resolved) for every broken link in ``path``."""
    with open(path, encoding="utf-8") as f:
        in_code = False
        for lineno, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_code = not in_code
            if in_code:
                continue
            for m in LINK_RE.finditer(line):
                target = m.group(1)
                if target.startswith(("http://", "https://", "mailto:", "#")):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                resolved = os.path.normpath(
                    os.path.join(os.path.dirname(path), rel))
                if not os.path.exists(resolved):
                    yield lineno, target, resolved


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    paths = ([os.path.join(REPO, a) for a in args] if args
             else list(md_files()))
    broken = 0
    checked = 0
    for path in paths:
        checked += 1
        for lineno, target, resolved in check_file(path):
            broken += 1
            rel = os.path.relpath(path, REPO)
            print(f"[docs] BROKEN {rel}:{lineno}: ({target}) -> {resolved}")
    print(f"[docs] checked {checked} markdown file(s), {broken} broken "
          f"intra-repo link(s)")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main())
