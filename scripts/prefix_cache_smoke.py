#!/usr/bin/env python
"""CI smoke: prefix-cache savings must scale with the shared-prefix length.

The acceptance contract of the prefix cache is quantitative, not "it
hits": serving a repeated-prefix workload must skip prefill work
*proportional to the shared-prefix length*, and the versioned
``stats()["prefix_cache"]`` counters are the measurement.  This script
drives one engine through three workloads whose only difference is the
shared-prefix length L and asserts, per L:

* a cold pass (cache just cleared) inserts every prompt and serves no
  cached token;
* a same-prefix/new-suffix pass hits **partial** on every prompt and
  serves exactly ``n * (L rounded down to the page size)`` cached tokens
  — the page-aligned shared prefix, nothing more, nothing less;
* an exact-repeat pass hits **full** on every prompt and its
  ``prefill_tokens_saved`` delta equals the workload's total prompt
  tokens (prefill skipped entirely);
* across lengths, the partial-hit savings scale exactly as
  ``L_aligned`` does (ratio check — proportionality, not just growth).

Greedy parity of the served tokens is the test suite's job
(``tests/test_prefix_cache.py``); this smoke is the *work-saving* gate CI
runs on every push.  Exit 0 = all assertions hold.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                      # noqa: E402
import numpy as np                                              # noqa: E402

from repro.configs.catalog import ARCHITECTURES                 # noqa: E402
from repro.models import build_model                            # noqa: E402
from repro.serve import Engine, Request, ServeConfig            # noqa: E402

ARCH = "llama3.2-1b"
PAGE = 4
PREFIX_LENGTHS = (8, 16, 24)    # page-aligned multiples of PAGE
N_REQUESTS = 4
MAX_NEW = 3
SEED = 7


def _drive(eng, prompts):
    handles = [eng.submit(Request(prompt=p, max_new_tokens=MAX_NEW))
               for p in prompts]
    eng.run()
    return [h.result(timeout=0) for h in handles]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--json", default=None,
                    help="also write the engine's final stats() dict to "
                         "this path (rendered schema-driven by "
                         "ci_step_summary.py)")
    args = ap.parse_args()
    cfg = ARCHITECTURES[ARCH].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    eng = Engine(model, params,
                 ServeConfig(max_batch=N_REQUESTS, max_len=64,
                             page_size=PAGE))
    rng = np.random.RandomState(SEED)
    failures = []
    partial_served = {}

    def check(cond, msg):
        tag = "ok  " if cond else "FAIL"
        print(f"[prefix-smoke] {tag} {msg}")
        if not cond:
            failures.append(msg)

    for L in PREFIX_LENGTHS:
        prefix = [int(t) for t in rng.randint(1, cfg.vocab_size, L)]
        suffix = lambda: [int(t) for t in rng.randint(1, cfg.vocab_size, 3)]
        cold_prompts = [prefix + suffix() for _ in range(N_REQUESTS)]
        new_prompts = [prefix + suffix() for _ in range(N_REQUESTS)]
        total_cold_tokens = sum(len(p) for p in cold_prompts)

        eng.clear_prefix_cache()
        st0 = eng.stats()["prefix_cache"]
        _drive(eng, cold_prompts)
        st1 = eng.stats()["prefix_cache"]
        # cold pass: within the pass, later requests may partial-hit the
        # pages the first insert pinned — but nothing was cached BEFORE it
        check(st1["inserts"] - st0["inserts"] == N_REQUESTS,
              f"L={L}: cold pass inserted all {N_REQUESTS} prompts")

        _drive(eng, new_prompts)
        st2 = eng.stats()["prefix_cache"]
        aligned = (L // PAGE) * PAGE
        served = st2["cached_tokens_served"] - st1["cached_tokens_served"]
        check(st2["hits_partial"] - st1["hits_partial"] == N_REQUESTS,
              f"L={L}: every new-suffix prompt partial-hit the prefix")
        check(served == N_REQUESTS * aligned,
              f"L={L}: partial hits served {served} cached tokens "
              f"(= {N_REQUESTS} x {aligned} page-aligned prefix)")
        partial_served[L] = served

        _drive(eng, cold_prompts)
        st3 = eng.stats()["prefix_cache"]
        saved = st3["prefill_tokens_saved"] - st2["prefill_tokens_saved"]
        check(st3["hits_full"] - st2["hits_full"] == N_REQUESTS,
              f"L={L}: exact repeats all full-hit")
        check(saved == total_cold_tokens,
              f"L={L}: full hits skipped prefill for all "
              f"{total_cold_tokens} prompt tokens (got {saved})")

    # proportionality across lengths: savings scale as the aligned prefix
    base_l = PREFIX_LENGTHS[0]
    for L in PREFIX_LENGTHS[1:]:
        want = partial_served[base_l] * L // base_l
        check(partial_served[L] == want,
              f"savings scale with prefix length: served[{L}]="
              f"{partial_served[L]} == served[{base_l}] * {L}/{base_l}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(eng.stats(), f, indent=1, sort_keys=True, default=str)
            f.write("\n")
        print(f"[prefix-smoke] wrote stats -> {args.json}")
    if failures:
        print(f"[prefix-smoke] FAILED: {len(failures)} assertion(s)")
        return 1
    print("[prefix-smoke] PASS: prefill savings proportional to "
          f"shared-prefix length over L={list(PREFIX_LENGTHS)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
