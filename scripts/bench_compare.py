#!/usr/bin/env python
"""Benchmark trend gate: diff a fresh BENCH_*.json against its committed baseline.

    python scripts/bench_compare.py BENCH_serving__cpu-interpret.json
    python scripts/bench_compare.py BENCH_*.json --tolerance 0.5
    python scripts/bench_compare.py BENCH_serving__cpu-interpret.json --write-baseline

CI runs this after every benchmark smoke: each per-backend artifact
(``BENCH_<suite>__<hardware>.json``) is compared row-for-row against the copy
committed under ``benchmarks/baselines/`` and the gate **fails when any
metric family's best ``derived`` value (throughput-like, higher is better)
regresses by more than the tolerance** (default ``--tolerance 0.3`` = 30%).

Row names embed run-dependent detail (the winning tile label, a speedup
value, evaluated/total counts), so rows are grouped into *metric families*
by normalizing those volatile tokens away; within a family the best
``derived`` is compared.  Families missing from the fresh run entirely also
fail the gate — a suite can't silently stop reporting a metric — and a
family whose baseline is nonzero but whose fresh best drops to zero fails
regardless of tolerance (the metric went dead).  A family whose *baseline*
``derived`` is zero cannot anchor a relative gate: it is reported as an
explicit warning (never silently passed) until the baseline is re-blessed
with a real value.  Families
whose ``derived`` is not a throughput (the guided-search evaluated-fraction
rows, where an efficiency win LOWERS the value) are reported but never
gated (``NEUTRAL_FAMILY_PREFIXES``).

Tolerances, most specific wins:

* ``--tolerance`` flag (or the ``BENCH_TOLERANCE`` env var) sets the default;
* the baseline JSON may carry a ``"tolerances"`` map of
  ``{family-prefix: fraction}`` for noisy families (e.g. wall-clock-measured
  rows on shared CI runners get a looser bound than deterministic
  model-scored rows).

Override knob for intentional regressions: re-bless the baseline with
``--write-baseline`` (which preserves the existing tolerances map) and commit
the result, or loosen the family's entry in ``"tolerances"``.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join(REPO, "benchmarks", "baselines")
DEFAULT_TOLERANCE = 0.30

#: normalizations mapping a volatile row name to its stable metric family
_VOLATILE = [
    (re.compile(r"-\d+(\.\d+)?x$"), ""),          # ...speedup-2.27x
    (re.compile(r"/eval\d+of\d+"), ""),           # guided eval counts
    (re.compile(r"/winner-[^/]+"), "/winner"),    # winner-match / winner-off
    (re.compile(r"/best=[^/]+"), "/best"),        # tab4 winning label
    (re.compile(r"/\d+x\d+(x\d+)?$"), "/cfg"),    # trailing tile/block label
    (re.compile(r"/\d+shapes/[^/]+$"), "/shapes"),  # lookup-provenance row
    (re.compile(r"/u\d+/[^/]+$"), "/unroll"),       # decode_unroll/u4/heuristic
    (re.compile(r"/p\d+/[^/]+$"), "/page"),         # page_size/p16/tuned:exact
]


#: metric families whose ``derived`` is NOT higher-is-better throughput
#: (e.g. the guided-search rows report the *fraction of the candidate space
#: evaluated* — an efficiency win LOWERS it) — reported but never gated.
NEUTRAL_FAMILY_PREFIXES = ("gemm_tune_guided/", "attn_tune_guided/")


def family(name: str) -> str:
    for pat, repl in _VOLATILE:
        name = pat.sub(repl, name)
    return name


def is_neutral(fam: str) -> bool:
    return fam.startswith(NEUTRAL_FAMILY_PREFIXES)


def families(blob: dict) -> dict:
    """{family: best derived} over the blob's rows (higher is better)."""
    out = {}
    for row in blob.get("rows", []):
        fam = family(row["name"])
        val = float(row.get("derived", 0.0))
        if fam not in out or val > out[fam]:
            out[fam] = val
    return out


def tolerance_for(fam: str, tolerances: dict, default: float) -> float:
    best = None
    for prefix, tol in tolerances.items():
        if fam.startswith(prefix) and (best is None or len(prefix) > best[0]):
            best = (len(prefix), float(tol))
    return best[1] if best else default


#: default per-family-prefix tolerances injected into NEW baselines: families
#: scored by wall clock on shared runners are noisy; model-scored families
#: are deterministic and keep the strict default.
DEFAULT_TOLERANCES = {
    "gemm_tune/cpu-interpret/measured": 0.90,
    "attn_tune/cpu-interpret/measured": 0.90,
    "gemm_scaling/host-xla": 0.90,
    "relative_peak/host-xla": 0.90,
    "serving/": 0.80,
    "serving_sustained/": 0.80,
    # per-request wall-clock percentiles on shared runners: very noisy;
    # the prefix_saved_frac row is counter-derived and keeps the strict
    # default via the more specific prefix
    "serving_latency/": 0.85,
    "serving_latency/llama3.2-1b/prefix_saved_frac": 0.10,
}


def compare(fresh_path: str, baseline_path: str, default_tol: float) -> int:
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(baseline_path) as f:
        base = json.load(f)

    tolerances = base.get("tolerances", {})
    fresh_fams = families(fresh)
    base_fams = families(base)

    failures = []
    for fam, base_val in sorted(base_fams.items()):
        tol = tolerance_for(fam, tolerances, default_tol)
        if fam not in fresh_fams:
            failures.append(f"{fam}: missing from fresh run "
                            f"(baseline best={base_val:.4g})")
            continue
        val = fresh_fams[fam]
        if is_neutral(fam):
            print(f"[bench-compare] info {fam}: {val:.4g} vs {base_val:.4g} "
                  f"(direction-neutral metric, not gated)")
            continue
        if base_val <= 0:
            # A zero baseline can't anchor a relative gate — say so loudly
            # instead of silently counting the family as passing.  Fix by
            # re-blessing once the family reports a real value.
            print(f"[bench-compare] warn {fam}: baseline is {base_val:.4g} "
                  f"(fresh {val:.4g}) — zero baseline cannot gate; re-bless "
                  f"to start tracking")
            continue
        if val <= 0:
            # a previously-nonzero family collapsing to zero is a breakage
            # (the metric stopped being measured), whatever the tolerance
            failures.append(
                f"{fam}: derived dropped to {val:.4g} "
                f"(baseline {base_val:.4g}) — metric went dead")
        elif val < base_val * (1.0 - tol):
            failures.append(
                f"{fam}: derived {val:.4g} < baseline {base_val:.4g} "
                f"- {tol:.0%} (floor {base_val * (1 - tol):.4g})")
        else:
            drift = (val / base_val - 1.0) * 100
            print(f"[bench-compare] ok   {fam}: {val:.4g} vs "
                  f"{base_val:.4g} ({drift:+.1f}%, tol {tol:.0%})")
    for fam in sorted(set(fresh_fams) - set(base_fams)):
        print(f"[bench-compare] new  {fam}: {fresh_fams[fam]:.4g} "
              f"(no baseline; re-bless to start tracking)")

    if failures:
        print(f"[bench-compare] REGRESSION in {fresh_path} vs {baseline_path}:")
        for msg in failures:
            print(f"[bench-compare]   - {msg}")
        print("[bench-compare] intentional? re-bless with "
              f"`python scripts/bench_compare.py {os.path.basename(fresh_path)}"
              " --write-baseline` (or loosen its \"tolerances\" entry) and "
              "commit the baseline")
        return 1
    print(f"[bench-compare] PASS {fresh_path}: "
          f"{len(base_fams)} metric families within tolerance")
    return 0


def require_improvement(fresh_path: str, required: list) -> int:
    """Absolute gate: each required family's best ``derived`` must be >= 1.0.

    The trend gate above is *relative* (vs the committed baseline), so a
    regression blessed into the baseline passes forever after.  Ratio-valued
    families (fused-vs-sync decode speedup) have an absolute meaning —
    >= 1.0 is "the optimized path wins" — and this pins them to it: the
    family must be present in the fresh run AND at >= 1.0, whatever the
    baseline says.  That is how the mesh decode 0.54x regression is kept
    from silently returning.
    """
    with open(fresh_path) as f:
        fresh_fams = families(json.load(f))
    failures = []
    for fam in required:
        val = fresh_fams.get(fam)
        if val is None:
            near = [f for f in fresh_fams if f.startswith(fam.split("/")[0])]
            failures.append(f"{fam}: family missing from {fresh_path} "
                            f"(present: {near or sorted(fresh_fams)[:8]})")
        elif val < 1.0:
            failures.append(f"{fam}: best derived {val:.4g} < 1.0 — the "
                            "optimized path lost to its reference")
        else:
            print(f"[bench-compare] ok   {fam}: {val:.4g} >= 1.0 "
                  "(required improvement holds)")
    if failures:
        print(f"[bench-compare] REQUIRED IMPROVEMENT FAILED in {fresh_path}:")
        for msg in failures:
            print(f"[bench-compare]   - {msg}")
        return 1
    return 0


def write_baseline(fresh_path: str, baseline_path: str) -> int:
    with open(fresh_path) as f:
        fresh = json.load(f)
    tolerances = dict(DEFAULT_TOLERANCES)
    if os.path.exists(baseline_path):
        with open(baseline_path) as f:
            tolerances = json.load(f).get("tolerances", tolerances)
    fresh["tolerances"] = tolerances
    os.makedirs(os.path.dirname(baseline_path), exist_ok=True)
    with open(baseline_path, "w") as f:
        json.dump(fresh, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"[bench-compare] blessed {fresh_path} -> {baseline_path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("fresh", nargs="+", help="fresh BENCH_*.json file(s)")
    ap.add_argument("--baseline-dir", default=BASELINE_DIR,
                    help="committed baseline dir (default: benchmarks/baselines)")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_TOLERANCE",
                                                 DEFAULT_TOLERANCE)),
                    help="default allowed fractional regression "
                         "(default 0.3; env override BENCH_TOLERANCE)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="bless the fresh file(s) as the new baseline "
                         "(keeps the existing tolerances map)")
    ap.add_argument("--require-improvement", action="append", default=[],
                    metavar="FAMILY",
                    help="metric family (normalized name) whose best derived "
                         "must be >= 1.0 in the fresh run — an absolute gate "
                         "for ratio metrics, independent of the baseline; "
                         "repeatable")
    args = ap.parse_args(argv)

    rc = 0
    for fresh_path in args.fresh:
        baseline_path = os.path.join(args.baseline_dir,
                                     os.path.basename(fresh_path))
        if args.require_improvement:
            # the absolute gate runs first — a file failing it is never
            # blessed into the baseline either
            req_rc = require_improvement(fresh_path, args.require_improvement)
            rc |= req_rc
            if req_rc and args.write_baseline:
                print(f"[bench-compare] refusing to bless {fresh_path}: "
                      "required improvement failed")
                continue
        if args.write_baseline:
            rc |= write_baseline(fresh_path, baseline_path)
            continue
        if not os.path.exists(baseline_path):
            print(f"[bench-compare] SKIP {fresh_path}: no committed baseline "
                  f"at {baseline_path} (bless one with --write-baseline)")
            continue
        rc |= compare(fresh_path, baseline_path, args.tolerance)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
