#!/usr/bin/env python
"""Autotuning CLI: produce, inspect, and ship the multi-op tuning database.

    python scripts/tune.py sweep  --hardware tpu-v5e --mode model --op all
    python scripts/tune.py sweep  --hardware tpu-v5e --op flash_attention
    python scripts/tune.py sweep  --hardware cpu-interpret --mode measure --op all
    python scripts/tune.py sweep  --mode measure            # hardware auto-detected
    python scripts/tune.py show   --hardware tpu-v5e
    python scripts/tune.py diff   --hardware tpu-v5e
    python scripts/tune.py verify                    # all DBs, all AR checks
    python scripts/tune.py verify --hardware tpu-v5e --prune
    python scripts/tune.py export --hardware cpu-interpret --format markdown

``--hardware`` names a registered profile (``tpu-v5e``, ``gpu-generic``,
``cpu-interpret``; ``host-cpu`` is a legacy alias of ``cpu-interpret``).
Omitting it resolves via ``$REPRO_HARDWARE`` or ``jax.devices()`` detection —
the CI backend matrix relies on exactly that.

``sweep`` writes/updates ``tuned/<hardware>.json`` (the committed paper-Tab.-4
artifact that serve/train/matmul auto-load); ``--op`` selects the kernel
family — ``gemm`` shapes are ``MxKxN``, ``flash_attention`` shapes are
``SQxSKVxD`` (query len x KV len x head dim), ``all`` sweeps both default
problem sets.  ``show``/``export`` render the DB as per-op markdown tables;
``diff`` re-runs a model-mode sweep over the DB's problems and reports
entries whose winner changed (e.g. after a cost-model edit).
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax.numpy as jnp  # noqa: E402

from repro.core import tuner, tuning_db  # noqa: E402
from repro.core.hardware import get_profile, resolve_hardware  # noqa: E402
from repro.core.registry import (  # noqa: E402
    OP_FLASH_ATTENTION, OP_GEMM, OP_PAGED_ATTN)
from repro.core.tile_config import (  # noqa: E402
    FLASH_INTERPRET_SPACE, INTERPRET_SPACE)

# Default problem set: the paper's tuning/control sizes plus the GEMM shapes a
# transformer block actually issues at serving/training scale (batchxseq rows,
# attention + MLP widths) — enough coverage that nearest-shape fallback has
# sensible neighbours for real model traffic.
DEFAULT_SHAPES = [
    (10240, 10240, 10240),   # paper tuning size
    (7168, 7168, 7168),      # paper control size
    (4096, 4096, 4096),
    (2048, 2048, 2048),
    (1024, 1024, 1024),
    (4096, 4096, 14336),     # MLP up-projection
    (4096, 14336, 4096),     # MLP down-projection
    (512, 4096, 4096),       # short-batch decode rows
    (8192, 4096, 4096),      # long-prefill rows
]
# Flash-attention default problems: (sq, skv, d) over the serve engine's
# power-of-two prefill buckets and the model zoo's head dims, so engine
# prefill lookups land on exact or near neighbours.
DEFAULT_FLASH_SHAPES = [
    (128, 128, 64), (128, 128, 128),
    (512, 512, 64), (512, 512, 128),
    (1024, 1024, 64), (1024, 1024, 128),
    (2048, 2048, 128),
    (4096, 4096, 128),
    (8192, 8192, 128),       # long-prefill rows
]
DEFAULT_FLASH_MEASURE_SHAPES = [(64, 64, 16), (128, 128, 32)]
# Paged-KV default problems: (max_batch, max_len) — the serve engine's
# pool-capacity lookup key, mirroring decode_loop.
DEFAULT_PAGED_SHAPES = [(4, 256), (8, 256), (8, 512), (16, 1024)]
DEFAULT_PAGED_MEASURE_SHAPES = [(4, 64), (8, 256)]
DTYPES = {"bfloat16": jnp.bfloat16, "bf16": jnp.bfloat16,
          "float32": jnp.float32, "f32": jnp.float32}


def _parse_shapes(text):
    shapes = []
    for part in text.split(","):
        try:
            dims = tuple(int(x) for x in part.lower().split("x"))
        except ValueError:
            dims = ()
        if len(dims) not in (2, 3):
            raise SystemExit(
                f"error: bad --shapes entry {part!r}; expected MxKxN "
                f"(e.g. 4096x4096x4096) or BxL for paged_attn (e.g. 8x512)")
        shapes.append(dims)
    return shapes


def _resolve_hw(args) -> str:
    """Canonical profile name for --hardware (None -> env pin / detection)."""
    name = resolve_hardware(args.hardware)
    if not args.hardware:
        print(f"[hw] no --hardware given; resolved to {name!r} "
              f"(REPRO_HARDWARE or jax.devices() detection)")
    args.hardware = name
    return name


def _db_path(args) -> str:
    return tuning_db.db_path(args.hardware, args.db_dir)


def _sweep_one_op(op, hw, shapes, dtypes, args):
    """Run one op's sweep over its problem list; returns SweepResults."""
    results = []
    for dt_name in dtypes:
        dtype = DTYPES[dt_name]
        for shape in shapes:
            if op == OP_GEMM:
                m, k, n = shape
                res = tuner.sweep_gemm(
                    m, k, n, dtype=dtype, hardware=hw, mode=args.mode,
                    search=args.search, top_k=args.top_k,
                    space=INTERPRET_SPACE if args.mode == "measure" else None,
                    repeats=args.repeats, record=False)
            elif op == OP_PAGED_ATTN:
                b, max_len = shape
                res = tuner.sweep_paged_attention(
                    b, max_len, dtype=dtype, hardware=hw, mode=args.mode,
                    repeats=args.repeats, record=False)
            else:
                sq, skv, d = shape
                res = tuner.sweep_flash_attention(
                    sq, skv, d, dtype=dtype, hardware=hw, mode=args.mode,
                    search=args.search, top_k=args.top_k,
                    space=(FLASH_INTERPRET_SPACE if args.mode == "measure"
                           else None),
                    repeats=args.repeats, record=False)
            results.append(res)
            b = res.best
            label = "x".join(str(s) for s in shape)
            print(f"[sweep] {hw.name} {op} {res.dtype:8s} {label}: "
                  f"best {b.config.label} ({b.gflops:.0f} GFLOP/s, "
                  f"{res.evaluated}/{res.candidates_total} evaluated, "
                  f"{res.pruned} pruned, {res.search})")
    return results


def cmd_sweep(args) -> int:
    hw = get_profile(_resolve_hw(args))
    ops = ([OP_GEMM, OP_FLASH_ATTENTION, OP_PAGED_ATTN]
           if args.op == "all" else [args.op])
    if args.shapes and len(ops) > 1:
        raise SystemExit("error: --shapes requires a single --op")
    dtypes = [args.dtype] if args.dtype else ["bfloat16", "float32"]

    path = _db_path(args)
    db = tuning_db.TuningDB(hw.name)
    if os.path.exists(path) and not args.fresh:
        db.merge(tuning_db.TuningDB.from_file(path))

    results = []
    for op in ops:
        if args.shapes:
            shapes = _parse_shapes(args.shapes)
        elif args.mode == "measure":
            # wall-clock sweeps need host-sized problems unless overridden
            if op == OP_GEMM:
                shapes = [(64, 64, 64), (128, 128, 128), (256, 256, 256)]
            elif op == OP_PAGED_ATTN:
                shapes = DEFAULT_PAGED_MEASURE_SHAPES
            else:
                shapes = DEFAULT_FLASH_MEASURE_SHAPES
        elif op == OP_PAGED_ATTN:
            shapes = DEFAULT_PAGED_SHAPES
        else:
            shapes = DEFAULT_SHAPES if op == OP_GEMM else DEFAULT_FLASH_SHAPES
        results += _sweep_one_op(op, hw, shapes, dtypes, args)
    db.merge(tuning_db.db_from_sweeps(hw.name, results))
    db.save(path)
    print(f"[sweep] wrote {len(db)} entries -> {path}")
    return 0


def _load_db(args) -> tuning_db.TuningDB:
    _resolve_hw(args)
    path = _db_path(args)
    if not os.path.exists(path):
        raise SystemExit(f"error: no tuning DB at {path}; "
                         f"run `tune.py sweep --hardware {args.hardware}` first")
    return tuning_db.TuningDB.from_file(path)


def cmd_show(args) -> int:
    print(_load_db(args).markdown())
    return 0


def cmd_diff(args) -> int:
    """Re-sweep the DB's problems in model mode; report changed winners."""
    db = _load_db(args)          # resolves --hardware first
    path = _db_path(args)
    hw = get_profile(args.hardware)
    changed = 0
    for rec in db.records():
        if rec.source != "model":
            continue  # measured entries are ground truth; don't second-guess
        kw = dict(dtype=DTYPES[rec.dtype], hardware=hw, mode="model",
                  search=args.search, top_k=args.top_k, record=False)
        if rec.op == OP_GEMM:
            res = tuner.sweep_gemm(rec.m, rec.k, rec.n, **kw)
        elif rec.op == OP_PAGED_ATTN:
            res = tuner.sweep_paged_attention(
                *rec.shape, dtype=DTYPES[rec.dtype], hardware=hw,
                mode="model", record=False)
        else:
            res = tuner.sweep_flash_attention(*rec.shape, **kw)
        new = res.best.config
        if new != rec.config:
            changed += 1
            shape = "x".join(str(s) for s in rec.shape)
            print(f"[diff] {rec.op} {rec.dtype} {shape}: "
                  f"{rec.config.label} -> {new.label}")
    print(f"[diff] {changed} of {len(db)} entries changed vs {path}")
    return 1 if changed and args.check else 0


def cmd_verify(args) -> int:
    """Validate tuned DBs with the static artifact checks (AR00x) and
    report — or with ``--prune``, rewrite without — stale entries."""
    from repro.analysis.artifacts import partition_stale, validate_tuning_db
    from repro.analysis.findings import SEV_ERROR

    if args.hardware:
        _resolve_hw(args)
        paths = [_db_path(args)]
        if not os.path.exists(paths[0]):
            raise SystemExit(f"error: no tuning DB at {paths[0]}")
    else:
        d = args.db_dir or tuning_db.default_tuned_dir()
        paths = sorted(
            os.path.join(d, f) for f in os.listdir(d)
            if f.endswith(".json")) if os.path.isdir(d) else []
        if not paths:
            print(f"[verify] no tuned DBs under {d}")
            return 0

    exit_code = 0
    for path in paths:
        findings = validate_tuning_db(path)
        errors = [f for f in findings if f.severity == SEV_ERROR]
        warns = [f for f in findings if f.severity != SEV_ERROR]
        for f in findings:
            print(f.render())
        db = None
        stale = []
        if not any(f.check_id == "AR005" for f in errors):
            db = tuning_db.TuningDB.from_file(path)
            live, stale = partition_stale(db)
        print(f"[verify] {path}: {len(errors)} error(s), "
              f"{len(warns)} warning(s), {len(stale)} stale "
              f"entr{'y' if len(stale) == 1 else 'ies'}")
        if errors:
            exit_code = 1
        if stale and args.prune and db is not None:
            pruned = tuning_db.TuningDB(db.hardware)
            for rec in live:
                pruned.add(rec, keep_best=False)
            pruned.save(path)
            print(f"[verify] pruned {len(stale)} stale entries -> {path} "
                  f"({len(pruned)} kept)")
        elif stale and not args.prune:
            print("[verify] re-run with --prune to drop them")
            if args.check_stale:
                exit_code = 1
    return exit_code


def cmd_export(args) -> int:
    db = _load_db(args)
    if args.format == "markdown":
        text = db.markdown() + "\n"
    else:
        import json
        text = json.dumps(db.to_json(), indent=1, sort_keys=True) + "\n"
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"[export] wrote {args.out}")
    else:
        print(text, end="")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p):
        p.add_argument("--hardware", default=None,
                       help="hardware profile (default: $REPRO_HARDWARE or "
                            "auto-detect from jax.devices())")
        p.add_argument("--db-dir", default=None,
                       help="tuning-DB dir (default: $REPRO_TUNED_DIR or repo tuned/)")

    p = sub.add_parser("sweep", help="tune problems and update the DB")
    common(p)
    p.add_argument("--op",
                   choices=[OP_GEMM, OP_FLASH_ATTENTION, OP_PAGED_ATTN,
                            "all"],
                   default=OP_GEMM,
                   help="kernel family to tune (shapes: gemm=MxKxN, "
                        "flash_attention=SQxSKVxD, paged_attn=BxL)")
    p.add_argument("--mode", choices=["model", "measure"], default="model")
    p.add_argument("--search", choices=[tuner.SEARCH_GUIDED,
                                        tuner.SEARCH_EXHAUSTIVE],
                   default=tuner.SEARCH_GUIDED)
    p.add_argument("--top-k", type=int, default=tuner.DEFAULT_TOP_K)
    p.add_argument("--shapes", default=None,
                   help="comma list of shapes (gemm: MxKxN; "
                        "flash_attention: SQxSKVxD)")
    p.add_argument("--dtype", choices=sorted(DTYPES), default=None)
    p.add_argument("--repeats", type=int, default=3)
    p.add_argument("--fresh", action="store_true",
                   help="discard existing DB entries instead of merging")
    p.set_defaults(fn=cmd_sweep)

    p = sub.add_parser("show", help="print the DB as a markdown table")
    common(p)
    p.set_defaults(fn=cmd_show)

    p = sub.add_parser("diff", help="re-sweep and report changed winners")
    common(p)
    p.add_argument("--search", default=tuner.SEARCH_GUIDED)
    p.add_argument("--top-k", type=int, default=tuner.DEFAULT_TOP_K)
    p.add_argument("--check", action="store_true",
                   help="exit nonzero when winners changed (CI drift gate)")
    p.set_defaults(fn=cmd_diff)

    p = sub.add_parser("verify",
                       help="validate tuned DBs against their hardware "
                            "profiles; --prune drops stale entries")
    common(p)
    p.add_argument("--prune", action="store_true",
                   help="rewrite the DB without stale entries")
    p.add_argument("--check-stale", action="store_true",
                   help="exit nonzero when stale entries exist (CI gate)")
    p.set_defaults(fn=cmd_verify)

    p = sub.add_parser("export", help="export the DB (markdown/json)")
    common(p)
    p.add_argument("--format", choices=["markdown", "json"], default="markdown")
    p.add_argument("--out", default=None)
    p.set_defaults(fn=cmd_export)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
