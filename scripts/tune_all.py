"""Generate the production tuned-tile table (paper Tab. 4 analogue) for every
GEMM shape the full-size models actually issue, via abstract tracing +
cost-model sweeps.  Output: results/tuned_tiles.json (loadable by
TileRegistry at launch)."""
import sys
sys.path.insert(0, "src")
import jax
import jax.numpy as jnp

from repro.configs.catalog import ARCHITECTURES
from repro.core import TileRegistry, capture_gemm_shapes, tune_model_gemms
from repro.models import build_model

registry = TileRegistry()
all_shapes = set()
for name, cfg in ARCHITECTURES.items():
    model = build_model(cfg)
    b, s = 4, 4096  # per-device-scale slice of train_4k
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    for k, sds in model.extra_inputs(b).items():
        batch[k] = sds
    with capture_gemm_shapes() as shapes:
        jax.eval_shape(lambda p, bt: model.forward(p, bt), model.abstract(), batch)
    uniq = sorted(set(shapes))
    all_shapes.update(uniq)
    print(f"{name:26s} {len(shapes):3d} GEMMs, {len(uniq):2d} unique shapes")

print(f"tuning {len(all_shapes)} unique shapes (cost model, tpu-v5e, bf16)...")
tuned = tune_model_gemms(sorted(all_shapes), dtype=jnp.bfloat16,
                         registry=registry)
registry.save("results/tuned_tiles.json")
print(f"wrote results/tuned_tiles.json with {len(registry.entries())} entries")
