"""Generate the production tuning DB (paper Tab. 4 analogue) for every GEMM
shape the full-size models actually issue, via abstract tracing + guided
cost-model sweeps.  Output: tuned/tpu-v5e.json (auto-loaded by matmul and the
serve/train launchers; see scripts/tune.py for the general CLI)."""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax
import jax.numpy as jnp

from repro.configs.catalog import ARCHITECTURES
from repro.core import TPU_V5E, capture_gemm_shapes, sweep_shapes, tuning_db
from repro.models import build_model

# The TPU target: this script regenerates the committed tpu-v5e DB.  For
# other backends use the general CLI: scripts/tune.py sweep --hardware ...
HW = TPU_V5E.name

all_shapes = set()
for name, cfg in ARCHITECTURES.items():
    model = build_model(cfg)
    b, s = 4, 4096  # per-device-scale slice of train_4k
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    for k, sds in model.extra_inputs(b).items():
        batch[k] = sds
    with capture_gemm_shapes() as shapes:
        jax.eval_shape(lambda p, bt: model.forward(p, bt), model.abstract(), batch)
    uniq = sorted(set(shapes))
    all_shapes.update(uniq)
    print(f"{name:26s} {len(shapes):3d} GEMMs, {len(uniq):2d} unique shapes")

print(f"tuning {len(all_shapes)} unique shapes (guided, {HW}, bf16)...")
results = sweep_shapes(sorted(all_shapes), dtype=jnp.bfloat16, record=False)

# Flash-attention problems: every head dim the zoo uses x the serve engine's
# power-of-two prefill buckets (+ train_4k), so op="flash_attention" lookups
# land on exact or near neighbours.
from repro.core import sweep_flash_attention  # noqa: E402

head_dims = sorted({cfg.resolved_head_dim for cfg in ARCHITECTURES.values()
                    if cfg.num_heads})
flash_problems = sorted({(s, s, d) for d in head_dims
                         for s in (128, 512, 1024, 2048, 4096)})
print(f"tuning {len(flash_problems)} flash-attention problems "
      f"(head dims {head_dims})...")
results += [sweep_flash_attention(sq, skv, d, dtype=jnp.bfloat16,
                                  record=False)
            for (sq, skv, d) in flash_problems]

path = tuning_db.db_path(HW)
db = tuning_db.TuningDB(HW)
if os.path.exists(path):
    db.merge(tuning_db.TuningDB.from_file(path))
db.merge(tuning_db.db_from_sweeps(HW, results))
db.save(path)
print(f"wrote {path} with {len(db)} entries")
