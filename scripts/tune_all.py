"""Generate the production tuning DB (paper Tab. 4 analogue) for every GEMM
shape the full-size models actually issue, via abstract tracing + guided
cost-model sweeps.  Output: tuned/tpu-v5e.json (auto-loaded by matmul and the
serve/train launchers; see scripts/tune.py for the general CLI)."""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax
import jax.numpy as jnp

from repro.configs.catalog import ARCHITECTURES
from repro.core import capture_gemm_shapes, sweep_shapes, tuning_db
from repro.models import build_model

all_shapes = set()
for name, cfg in ARCHITECTURES.items():
    model = build_model(cfg)
    b, s = 4, 4096  # per-device-scale slice of train_4k
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    for k, sds in model.extra_inputs(b).items():
        batch[k] = sds
    with capture_gemm_shapes() as shapes:
        jax.eval_shape(lambda p, bt: model.forward(p, bt), model.abstract(), batch)
    uniq = sorted(set(shapes))
    all_shapes.update(uniq)
    print(f"{name:26s} {len(shapes):3d} GEMMs, {len(uniq):2d} unique shapes")

print(f"tuning {len(all_shapes)} unique shapes (guided, tpu-v5e, bf16)...")
results = sweep_shapes(sorted(all_shapes), dtype=jnp.bfloat16, record=False)

path = tuning_db.db_path("tpu-v5e")
db = tuning_db.TuningDB("tpu-v5e")
if os.path.exists(path):
    db.merge(tuning_db.TuningDB.from_file(path))
db.merge(tuning_db.db_from_sweeps("tpu-v5e", results))
db.save(path)
print(f"wrote {path} with {len(db)} entries")
