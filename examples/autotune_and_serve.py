"""The paper's single-source thesis, live: tune GEMM tiles for two different
'architectures' (hardware targets) from the SAME kernel source, persist the
tuned table (Tab. 4), tune the flash-attention op's (bq, bk) blocks the same
way, then serve a model whose matmuls AND prefill attention consume them.

Run: PYTHONPATH=src python examples/autotune_and_serve.py
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.core import (GLOBAL_REGISTRY, HOST_CPU, INTERPRET_SPACE, TPU_V5E,
                        TileRegistry, capture_gemm_shapes,
                        sweep_flash_attention, sweep_gemm, tune_model_gemms)
from repro.configs.catalog import get_config
from repro.models import build_model
from repro.serve import Engine, ServeConfig

# -- 1. same kernel, two targets (paper: one source x {nvcc, icc, gcc, xlc})
reg = TileRegistry()
for hw, mode, space, n in ((TPU_V5E, "model", None, 8192),
                           (HOST_CPU, "measure", INTERPRET_SPACE, 64)):
    res = sweep_gemm(n, n, n, dtype=jnp.float32, mode=mode, space=space,
                     hardware=hw, registry=reg, repeats=1)
    print(f"[tune] {hw.name:10s} N={n:5d}: best {res.best.config.label} "
          f"({res.best.gflops:.1f} GFLOP/s {mode})")

with tempfile.NamedTemporaryFile(suffix=".json") as f:
    reg.save(f.name)
    reloaded = TileRegistry(f.name)
    print(f"[tune] persisted {len(reloaded.entries())} tuned entries (Tab. 4)")

# -- 2. trace a real model's GEMM shapes and tune them all -------------------
# Both the training forward AND the serving decode step are traced; tuning
# the decode shapes into the process-global registry is what turns the
# engine's per-token GEMM lookups below into 'exact' hits.
cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(),
                          attention_impl="flash")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
with capture_gemm_shapes() as shapes:
    model.forward(params, {"tokens": jnp.zeros((2, 16), jnp.int32)})
    jax.eval_shape(model.decode_step, params,
                   jax.ShapeDtypeStruct((2, 1), jnp.int32),
                   model.init_cache(2, 32),
                   jax.ShapeDtypeStruct((), jnp.int32),
                   jax.ShapeDtypeStruct((2,), jnp.int32))
uniq = sorted(set(shapes))
print(f"[trace] model issues {len(shapes)} GEMMs, {len(uniq)} unique shapes "
      "(forward + decode step)")
tuned = tune_model_gemms(uniq, dtype=cfg.dtype, registry=GLOBAL_REGISTRY)
for shape, cfg_t in list(tuned.items())[:4]:
    print(f"[tune]   {str(shape):24s} -> {cfg_t.label}")

# ...and the flash-attention op, same machinery: the engine buckets these
# prompts to a prefill length of 8, so tune that exact (sq, skv, head_dim)
# problem for an 'exact' provenance hit below.
hd = cfg.resolved_head_dim
res = sweep_flash_attention(8, 8, hd, dtype=cfg.dtype,
                            registry=GLOBAL_REGISTRY)
print(f"[tune]   flash (8, 8, {hd})         -> {res.best.config.label}")

# -- 3. serve with the tuned registry in ambient context ---------------------
# The engine is the production-shaped consumer: a fixed pool of KV-cache
# slots, ragged prompts (left-pad + masking), and a fused device-resident
# decode loop with ONE host transfer per generate call.  Pin the engine to
# the profile the sweeps above tuned for (tune_model_gemms defaults to the
# TPU target) — otherwise hardware auto-detection would key the lookups by
# this host's profile and the exact hits below would become misses.
eng = Engine(model, params, ServeConfig(max_batch=2, hardware=TPU_V5E.name))
outs = eng.generate([[11, 22, 33], [44, 55, 66, 77, 88]], max_new_tokens=6)
for p, o in zip(([11, 22, 33], [44, 55, 66, 77, 88]), outs):
    print(f"[serve] {p} -> {o}")

st = eng.stats()
print(f"[serve] {int(st['tokens_generated'])} tokens in "
      f"{int(st['waves'])} wave(s), {int(st['device_transfers'])} host "
      f"transfer(s), {int(st['slot_reuses'])} slot reuse(s)")
for shape, info in (st["decode_tile_lookups"] or {}).items():
    print(f"[serve]   decode GEMM {shape:>14s} -> tile {info['tile']} "
          f"({info['source']})")
for shape, info in (st["prefill_flash_lookups"] or {}).items():
    print(f"[serve]   prefill flash {shape:>12s} -> blocks {info['tile']} "
          f"({info['source']})")
