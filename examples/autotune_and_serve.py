"""The paper's single-source thesis, live: tune GEMM tiles for two different
'architectures' (hardware targets) from the SAME kernel source, persist the
tuned table (Tab. 4), then serve a model whose matmuls consume it.

Run: PYTHONPATH=src python examples/autotune_and_serve.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.core import (HOST_CPU, INTERPRET_SPACE, TPU_V5E, TileRegistry,
                        capture_gemm_shapes, sweep_gemm, tune_model_gemms)
from repro.configs.catalog import get_config
from repro.models import build_model
from repro.serve import Engine, ServeConfig

# -- 1. same kernel, two targets (paper: one source x {nvcc, icc, gcc, xlc})
reg = TileRegistry()
for hw, mode, space, n in ((TPU_V5E, "model", None, 8192),
                           (HOST_CPU, "measure", INTERPRET_SPACE, 64)):
    res = sweep_gemm(n, n, n, dtype=jnp.float32, mode=mode, space=space,
                     hardware=hw, registry=reg, repeats=1)
    print(f"[tune] {hw.name:10s} N={n:5d}: best {res.best.config.label} "
          f"({res.best.gflops:.1f} GFLOP/s {mode})")

with tempfile.NamedTemporaryFile(suffix=".json") as f:
    reg.save(f.name)
    reloaded = TileRegistry(f.name)
    print(f"[tune] persisted {len(reloaded.entries())} tuned entries (Tab. 4)")

# -- 2. trace a real model's GEMM shapes and tune them all -------------------
cfg = get_config("llama3.2-1b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
with capture_gemm_shapes() as shapes:
    model.forward(params, {"tokens": jnp.zeros((2, 16), jnp.int32)})
uniq = sorted(set(shapes))
print(f"[trace] model issues {len(shapes)} GEMMs, {len(uniq)} unique shapes")
tuned = tune_model_gemms(uniq, dtype=jnp.bfloat16, registry=reg)
for shape, cfg_t in list(tuned.items())[:4]:
    print(f"[tune]   {str(shape):24s} -> {cfg_t.label}")

# -- 3. serve with the tuned registry in ambient context ---------------------
eng = Engine(model, params, ServeConfig(max_batch=1))
out = eng.generate([[11, 22, 33]], max_new_tokens=6)
print(f"[serve] {out}")
