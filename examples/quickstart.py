"""Quickstart: the paper's workflow end-to-end in one minute on CPU.

1. tune the single-source GEMM for the target hardware (registry = Tab. 4),
2. train a tiny LM whose every matmul uses the tuned kernel path,
3. generate from it.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import GLOBAL_REGISTRY, sweep_gemm
from repro.configs.catalog import get_config
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.optim import AdamW
from repro.serve import Engine, ServeConfig
from repro.train import init_train_state, make_train_step

# -- 1. parameter tuning (the paper's contribution, Figs. 3/4 -> Tab. 4) ----
res = sweep_gemm(4096, 4096, 4096, dtype=jnp.bfloat16, mode="model")
print(f"[tune] best tile for 4096^3 bf16 on tpu-v5e: {res.best.config.label} "
      f"-> {res.best.gflops / 1000:.0f} TFLOP/s (model)")
print(f"[tune] registry now holds: "
      f"{GLOBAL_REGISTRY.get('tpu-v5e', jnp.bfloat16, 4096, 4096, 4096).label}")

# -- 2. train a tiny LM (every matmul rides core.matmul) --------------------
cfg = get_config("llama3.2-1b").reduced()
model = build_model(cfg)
opt = AdamW(learning_rate=3e-3)
state = init_train_state(model, opt, jax.random.PRNGKey(0))
step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=8))
for i in range(30):
    state, metrics = step(state, pipe(i))
    if i % 10 == 0:
        print(f"[train] step {i:3d} loss {float(metrics['loss']):.3f}")
print(f"[train] final loss {float(metrics['loss']):.3f}")

# -- 3. serve ---------------------------------------------------------------
eng = Engine(model, state.params, ServeConfig(max_batch=2))
outs = eng.generate([[3, 1, 4, 1, 5], [2, 7, 1, 8]], max_new_tokens=8)
print(f"[serve] generated: {outs}")
