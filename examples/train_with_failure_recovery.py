"""Fault-tolerance demo: train, 'crash', restart from checkpoint, verify the
resumed run is bitwise identical to an uninterrupted one.

Run: PYTHONPATH=src python examples/train_with_failure_recovery.py
"""
import tempfile

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.catalog import get_config
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.optim import AdamW
from repro.train import init_train_state, make_train_step, abstract_train_state

cfg = get_config("llama3.2-1b").reduced()
model = build_model(cfg)
opt = AdamW(learning_rate=1e-3)
pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=8))
step = jax.jit(make_train_step(model, opt))

with tempfile.TemporaryDirectory() as d:
    ck = Checkpointer(d)

    # reference run: 20 uninterrupted steps
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    for i in range(20):
        state, _ = step(state, pipe(i))
        if i + 1 == 10:
            ck.save(10, state)
    ref = state

    # 'crash' after step 10 -> restart from checkpoint -> replay 10..20
    print(f"[recovery] latest checkpoint: step {ck.latest_step()}")
    template = abstract_train_state(model, opt)
    state = ck.restore(10, template)
    for i in range(10, 20):
        state, m = step(state, pipe(i))

    diffs = [float(np.abs(np.asarray(a, np.float32)
                          - np.asarray(b, np.float32)).max())
             for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                             jax.tree_util.tree_leaves(state.params))]
    print(f"[recovery] max param diff after resumed run: {max(diffs):.2e}")
    assert max(diffs) == 0.0, "resume must be bitwise identical"
    print("[recovery] OK — restart is bitwise identical "
          "(deterministic data + atomic checkpoints)")
