"""Roofline summary rows from the dry-run records (skips cleanly when
results/dryrun.json has not been generated yet).  derived = MFU proxy."""
from __future__ import annotations

import os
from typing import List

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun.json")


def run() -> List[tuple]:
    if not os.path.exists(RESULTS):
        return [("roofline_summary/missing-results", 0.0, 0.0)]
    from repro.launch.roofline import load_rows
    rows_out = []
    for mesh in ("single", "multi"):
        rows, skips = load_rows(RESULTS, mesh)
        for r in rows:
            rows_out.append((
                f"roofline/{r['arch']}/{r['shape']}/{mesh}/"
                f"dom={r['dominant']}",
                r["est_step_s"] * 1e6, r["mfu_proxy"]))
    return rows_out
