"""Benchmark harness: one module per paper table/figure + framework hot paths.

Prints ``name,us_per_call,derived`` CSV (derived = GFLOPs/s, fraction of
peak, tokens/s, or model-ratio depending on the bench).

  PYTHONPATH=src python -m benchmarks.run                # all
  PYTHONPATH=src python -m benchmarks.run gemm_tuning    # one suite
"""
from __future__ import annotations

import sys
import traceback

SUITES = ["gemm_tuning", "gemm_scaling", "relative_peak", "ratio_model",
          "model_step", "roofline_summary"]


def main() -> None:
    wanted = sys.argv[1:] or SUITES
    print("name,us_per_call,derived")
    for suite in wanted:
        try:
            mod = __import__(f"benchmarks.{suite}", fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us:.2f},{derived:.4g}", flush=True)
        except Exception as e:  # keep the harness running across suites
            traceback.print_exc()
            print(f"{suite}/ERROR,0,0  # {type(e).__name__}: {e}", flush=True)


if __name__ == "__main__":
    main()
