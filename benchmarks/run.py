"""Benchmark harness: one module per paper table/figure + framework hot paths.

Prints ``name,us_per_call,derived`` CSV (derived = GFLOPs/s, fraction of
peak, tokens/s, or model-ratio depending on the bench).

  PYTHONPATH=src python -m benchmarks.run                      # all
  PYTHONPATH=src python -m benchmarks.run gemm_tuning          # one suite
  PYTHONPATH=src python -m benchmarks.run --smoke --json BENCH_gemm_tuning.json gemm_tuning

``--smoke`` asks suites that support it (via a ``run(smoke=True)`` parameter)
for a tiny-space variant suitable for CI; ``--json`` additionally writes the
rows as a machine-readable ``BENCH_*.json`` trajectory point (uploaded as a
workflow artifact by the fast CI tier).
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import traceback

SUITES = ["gemm_tuning", "attention_tuning", "gemm_scaling", "relative_peak",
          "ratio_model", "model_step", "roofline_summary", "serving",
          "serving_sustained", "serving_latency"]


def _run_suite(suite: str, smoke: bool, hardware=None, mesh=None):
    mod = __import__(f"benchmarks.{suite}", fromlist=["run"])
    params = inspect.signature(mod.run).parameters
    kwargs = {}
    if smoke and "smoke" in params:
        kwargs["smoke"] = True
    if hardware is not None and "hardware" in params:
        kwargs["hardware"] = hardware
    if mesh is not None and "mesh" in params:
        kwargs["mesh"] = mesh
    return list(mod.run(**kwargs))


def main(argv=None) -> int:
    from repro.core.hardware import resolve_hardware

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("suites", nargs="*", default=None,
                    help=f"suites to run (default: all of {SUITES})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem sizes for CI smoke runs")
    ap.add_argument("--hardware", default=None,
                    help="hardware profile for suites that tune per backend "
                         "(default: $REPRO_HARDWARE or auto-detect; threaded "
                         "to every suite with a hardware parameter)")
    ap.add_argument("--mesh", default=None,
                    help="device mesh spec ('data=N,model=M' | 'auto') for "
                         "suites that shard (threaded to every suite with a "
                         "mesh parameter; needs that many visible devices)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="also write rows to this JSON file")
    args = ap.parse_args(argv)

    hardware = resolve_hardware(args.hardware)
    wanted = args.suites or SUITES
    all_rows = []
    failed = 0
    print(f"# hardware={hardware} mesh={args.mesh or 'none'}")
    print("name,us_per_call,derived")
    for suite in wanted:
        try:
            for name, us, derived in _run_suite(suite, args.smoke, hardware,
                                                args.mesh):
                print(f"{name},{us:.2f},{derived:.4g}", flush=True)
                all_rows.append({"name": name, "us_per_call": us,
                                 "derived": derived})
        except Exception as e:  # keep the harness running across suites
            traceback.print_exc()
            print(f"{suite}/ERROR,0,0  # {type(e).__name__}: {e}", flush=True)
            failed += 1

    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump({"smoke": args.smoke, "hardware": hardware,
                       "mesh": args.mesh, "suites": wanted,
                       "rows": all_rows}, f, indent=1)
            f.write("\n")
        print(f"# wrote {len(all_rows)} rows -> {args.json_path}",
              file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
