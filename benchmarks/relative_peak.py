"""Paper Fig. 8: fraction of theoretical peak, tuned vs untuned, per
hardware x precision.  The paper's claim: untuned ~20%, tuned up to ~50%.
We report the same two points for the TPU-v5e target (cost model, best N)
plus the measured host-XLA fraction as the 'vendor library' reference."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import TPU_V5E, HOST_CPU, TileConfig, sweep_gemm
from repro.core.cost_model import gemm_cost
from repro.core.hardware import resolve_profile

UNTUNED = TileConfig(128, 128, 128)


def run(hardware=None) -> List[tuple]:
    hw = resolve_profile(hardware, default=TPU_V5E)
    rows = []
    for dtype in (jnp.bfloat16, jnp.float32):
        peak = hw.peak_for(dtype)
        best_frac, un_frac = 0.0, 0.0
        for n in range(2048, 20481, 2048):
            tuned = sweep_gemm(n, n, n, dtype=dtype, mode="model",
                               hardware=hw, record=False).best.config
            ct = gemm_cost(n, n, n, tuned, hw, dtype)
            cu = gemm_cost(n, n, n, UNTUNED, hw, dtype)
            best_frac = max(best_frac, ct.tflops * 1e12 / peak)
            un_frac = max(un_frac, cu.tflops * 1e12 / peak)
        name = jnp.dtype(dtype).name
        rows.append((f"relative_peak/{hw.name}/{name}/tuned", 0.0, best_frac))
        rows.append((f"relative_peak/{hw.name}/{name}/untuned", 0.0, un_frac))

    # measured host reference (xla := vendor-library baseline of the paper)
    n = 1024
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
    f = jax.jit(lambda x, y: x @ y)
    f(a, b).block_until_ready()
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        f(a, b).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    frac = 2 * n ** 3 / best / HOST_CPU.peak_for(jnp.float32)
    rows.append(("relative_peak/host-xla/float32/measured", best * 1e6, frac))
    return rows
