"""Paper Figs. 3/4 + Tab. 4: tile-size tuning sweeps per backend.

Reproduces the paper's tuning methodology:
  * fixed problem size (paper: N=10240, control N=7168),
  * sweep tile size (paper: powers of two; here the VMEM-feasible
    (bm, bk, bn) space, plus the paper-faithful square-T subsweep),
  * keep the best-of-repeats timing per candidate (paper §2.3),
  * report the optimum per (backend, dtype) — the Tab. 4 analogue —
    and the guided search's evaluated/total fraction (autotuner v2).

The model-scored sections target ONE hardware profile (``run(hardware=...)``,
threaded from ``benchmarks.run --hardware`` / ``$REPRO_HARDWARE`` — the CI
backend matrix runs this suite once per profile); the measured section always
times pallas-interpret on this host under the ``cpu-interpret`` profile, the
only backend a CPU container can genuinely measure.

``run(smoke=True)`` shrinks every problem so the whole suite finishes in
seconds — the CI fast tier runs it and uploads the JSON as the repo's
benchmark trajectory artifact.
"""
from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp

from repro.core import (CPU_INTERPRET, INTERPRET_SPACE, SEARCH_EXHAUSTIVE,
                        SEARCH_GUIDED, TPU_V5E, sweep_gemm)
from repro.core.hardware import HardwareProfile, resolve_profile
from repro.core.tile_config import square
from repro.core.cost_model import gemm_cost

N_PAPER = 10240        # paper's tuning size
N_CONTROL = 7168       # paper's control size
N_SMOKE = 512          # CI smoke size


def _target(hardware) -> HardwareProfile:
    """The profile the model-scored sections tune for.  ``benchmarks.run``
    always passes the resolved per-backend name (env/flag/detection); a
    direct call with ``hardware=None`` pins the paper's TPU target."""
    return resolve_profile(hardware, default=TPU_V5E)


def tune_target_model(n: int = N_PAPER, dtype=jnp.bfloat16,
                      hardware=None) -> List[tuple]:
    """Figs. 3/4 analogue on the target hardware via the cost model."""
    hw = _target(hardware)
    rows = []
    res = sweep_gemm(n, n, n, dtype=dtype, mode="model",
                     search=SEARCH_EXHAUSTIVE, hardware=hw, record=False)
    for p in sorted(res.points, key=lambda p: p.seconds):
        rows.append((f"gemm_tune/{hw.name}/{jnp.dtype(dtype).name}/N{n}/"
                     f"{p.config.label}", p.seconds * 1e6, p.gflops))
    return rows


def guided_vs_exhaustive(n: int = N_PAPER, dtype=jnp.bfloat16,
                         hardware=None) -> List[tuple]:
    """Autotuner v2 headline: guided search evaluates a fraction of the space
    and its winner is checked against the exhaustive sweep's.

    derived = evaluated/total fraction; the name records whether the guided
    winner matched (winner-match) or how far off it landed (regression
    ratio), so the CI trajectory catches ranking drift.
    """
    hw = _target(hardware)
    kw = dict(dtype=dtype, mode="model", hardware=hw, record=False)
    guided = sweep_gemm(n, n, n, search=SEARCH_GUIDED, **kw)
    full = sweep_gemm(n, n, n, search=SEARCH_EXHAUSTIVE, **kw)
    frac = guided.evaluated / max(guided.candidates_total, 1)
    if guided.best.config == full.best.config:
        verdict = "winner-match"
    else:
        verdict = f"winner-off-{guided.best.seconds / full.best.seconds:.3f}x"
    return [(f"gemm_tune_guided/{hw.name}/N{n}/"
             f"eval{guided.evaluated}of{guided.candidates_total}/{verdict}",
             guided.best.seconds * 1e6, frac)]


def tune_square_paper_faithful(n: int = N_PAPER, dtype=jnp.bfloat16,
                               hardware=None):
    """The paper's exact 1-parameter sweep: square tiles T (Fig. 3)."""
    hw = _target(hardware)
    rows = []
    for t in (128, 256, 512):
        cfg = square(t)
        if not cfg.fits(hw, dtype):
            continue
        c = gemm_cost(n, n, n, cfg, hw, dtype)
        rows.append((f"gemm_tune_square/{hw.name}/T{t}/N{n}",
                     c.total_s * 1e6, c.tflops * 1000))
    return rows


def tune_host_measured(n: int = 256, dtype=jnp.float32, repeats: int = 2):
    """Measured wall-clock sweep on this host (pallas-interpret, small N)."""
    res = sweep_gemm(n, n, n, dtype=dtype, mode="measure",
                     space=INTERPRET_SPACE, hardware=CPU_INTERPRET,
                     backend="pallas-interpret", repeats=repeats, record=False)
    rows = []
    for p in sorted(res.points, key=lambda p: p.seconds)[:5]:
        rows.append((f"gemm_tune/{CPU_INTERPRET.name}/measured/N{n}/"
                     f"{p.config.label}", p.seconds * 1e6, p.gflops))
    return rows


def tab4_optima(sizes=(N_PAPER, N_CONTROL), hardware=None):
    """Tab. 4 analogue: per-(hardware, dtype, N) optimum tile."""
    hw = _target(hardware)
    rows = []
    for dtype in (jnp.bfloat16, jnp.float32):
        for n in sizes:
            res = sweep_gemm(n, n, n, dtype=dtype, mode="model",
                             hardware=hw, record=False)
            b = res.best
            rows.append((f"tab4/{hw.name}/{jnp.dtype(dtype).name}/N{n}/"
                         f"best={b.config.label}", b.seconds * 1e6, b.gflops))
    return rows


def run(smoke: bool = False, hardware: Optional[str] = None) -> List[tuple]:
    rows = []
    if smoke:
        rows += tune_target_model(N_SMOKE, hardware=hardware)[:6]
        rows += guided_vs_exhaustive(N_SMOKE, hardware=hardware)
        rows += tune_square_paper_faithful(N_SMOKE, hardware=hardware)
        rows += tune_host_measured(64, repeats=1)
        rows += tab4_optima(sizes=(N_SMOKE,), hardware=hardware)
        return rows
    rows += tune_target_model(hardware=hardware)[:6]
    rows += guided_vs_exhaustive(hardware=hardware)
    rows += tune_square_paper_faithful(hardware=hardware)
    rows += tune_host_measured()
    rows += tab4_optima(hardware=hardware)
    return rows
