"""Paper Figs. 3/4 + Tab. 4: tile-size tuning sweeps per backend.

Reproduces the paper's tuning methodology:
  * fixed problem size (paper: N=10240, control N=7168),
  * sweep tile size (paper: powers of two; here the VMEM-feasible
    (bm, bk, bn) space, plus the paper-faithful square-T subsweep),
  * keep the best-of-repeats timing per candidate (paper §2.3),
  * report the optimum per (backend, dtype) — the Tab. 4 analogue.

Backends: tpu-v5e (analytic cost model — the TARGET hardware, this container
is CPU-only), host measured XLA, host measured pallas-interpret (small N).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import (HOST_CPU, INTERPRET_SPACE, TPU_V5E, TuningSpace,
                        sweep_gemm)
from repro.core.tile_config import square
from repro.core.cost_model import gemm_cost

N_PAPER = 10240        # paper's tuning size
N_CONTROL = 7168       # paper's control size


def tune_tpu_model(n: int = N_PAPER, dtype=jnp.bfloat16) -> List[str]:
    """Figs. 3/4 analogue on the target hardware via the cost model."""
    rows = []
    res = sweep_gemm(n, n, n, dtype=dtype, mode="model", hardware=TPU_V5E)
    for p in sorted(res.points, key=lambda p: p.seconds):
        rows.append((f"gemm_tune/tpu-v5e/{jnp.dtype(dtype).name}/N{n}/"
                     f"{p.config.label}", p.seconds * 1e6, p.gflops))
    return rows


def tune_square_paper_faithful(n: int = N_PAPER, dtype=jnp.bfloat16):
    """The paper's exact 1-parameter sweep: square tiles T (Fig. 3)."""
    rows = []
    for t in (128, 256, 512):
        cfg = square(t)
        if not cfg.fits(TPU_V5E, dtype):
            continue
        c = gemm_cost(n, n, n, cfg, TPU_V5E, dtype)
        rows.append((f"gemm_tune_square/tpu-v5e/T{t}/N{n}",
                     c.total_s * 1e6, c.tflops * 1000))
    return rows


def tune_host_measured(n: int = 256, dtype=jnp.float32):
    """Measured wall-clock sweep on this host (pallas-interpret, small N)."""
    res = sweep_gemm(n, n, n, dtype=dtype, mode="measure",
                     space=INTERPRET_SPACE, hardware=HOST_CPU,
                     backend="pallas-interpret", repeats=2, record=False)
    rows = []
    for p in sorted(res.points, key=lambda p: p.seconds)[:5]:
        rows.append((f"gemm_tune/host-interpret/N{n}/{p.config.label}",
                     p.seconds * 1e6, p.gflops))
    return rows


def tab4_optima():
    """Tab. 4 analogue: per-(hardware, dtype, N) optimum tile."""
    rows = []
    for dtype in (jnp.bfloat16, jnp.float32):
        for n in (N_PAPER, N_CONTROL):
            res = sweep_gemm(n, n, n, dtype=dtype, mode="model",
                             hardware=TPU_V5E)
            b = res.best
            rows.append((f"tab4/tpu-v5e/{jnp.dtype(dtype).name}/N{n}/"
                         f"best={b.config.label}", b.seconds * 1e6, b.gflops))
    return rows


def run() -> List[tuple]:
    rows = []
    rows += tune_tpu_model()[:6]
    rows += tune_square_paper_faithful()
    rows += tune_host_measured()
    rows += tab4_optima()
    return rows
