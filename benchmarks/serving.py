"""Serving-engine throughput: fused device-resident decode vs per-token sync.

The tentpole claim of the serving engine is that keeping the decode loop on
device (one host transfer per ``generate`` call) beats the seed engine's
execution model (one ``jax.device_get`` per decoded token).  This suite
measures both on the same model/params and reports:

  * prefill tokens/s (prompt tokens through the batched prefill),
  * decode tokens/s for the fused engine,
  * decode tokens/s for the per-token-sync baseline,
  * their ratio (the headline row — CI tracks it in ``BENCH_serving.json``).

``run(smoke=True)`` shrinks the workload for the CI fast tier.
"""
from __future__ import annotations

import time
from typing import List

import jax

from repro.configs.catalog import get_config
from repro.models import build_model
from repro.serve import Engine, PerTokenSyncEngine, ServeConfig

ARCH = "llama3.2-1b"


def _best_interleaved(fns, repeats: int):
    """Run every ``fn`` (returning a (prefill_s, decode_s) pair) once per
    round, ``repeats`` rounds; keep each fn's pair from its fastest-decode
    round.  Interleaving the engines round-robin (instead of timing all of
    one then all of the other) exposes both to the same machine drift, so
    the fused/sync ratio is a same-conditions comparison."""
    best = [None] * len(fns)
    for _ in range(repeats):
        for i, fn in enumerate(fns):
            pair = fn()
            if best[i] is None or pair[1] < best[i][1]:
                best[i] = pair
    return best


def run(smoke: bool = False, hardware=None, mesh=None) -> List[tuple]:
    batch = 8
    plen = 16
    max_new = 16 if smoke else 48
    # Mesh runs take more best-of repeats: the forced-multi-device host
    # interleaves 8 device threads on shared cores, so per-run wall-clock
    # noise is far above the single-device case and a best-of-2 ratio can
    # swing past the bench gate's tolerance in either direction.
    repeats = (4 if mesh else 2) if smoke else (6 if mesh else 3)
    # Warmup waves are SEPARATE from the measured ones: the first generate
    # compiles prefill + the fused loop (and, on a mesh, resolves the tuned
    # decode unroll and re-places params/cache by the sharding rules); the
    # second exercises the slot-reuse path so every measured repeat below is
    # a steady-state wave.  Engine construction/compile therefore never
    # leaks into the fused/sync ratio — matching how the 1-device rows
    # measure.
    warmup = 2

    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [[(7 * i + j) % cfg.vocab_size for j in range(plen)]
               for i in range(batch)]

    # This suite isolates the fused-loop-vs-per-token-sync claim, so it pins
    # the wave scheduler; benchmarks/serving_sustained.py carries the
    # continuous-vs-wave comparison.
    eng = Engine(model, params,
                 ServeConfig(max_batch=batch, max_len=256, profile=True,
                             hardware=hardware, mesh=mesh,
                             scheduler="wave"))
    # The sync baseline runs on the SAME topology as the fused engine, so
    # the headline ratio isolates the execution model (per-token host syncs
    # vs one device-resident loop) at fixed placement.  Off-mesh, mesh=None
    # keeps it the plain single-device seed loop.
    sync_eng = PerTokenSyncEngine(model, params, max_len=256, profile=True,
                                  mesh=mesh)
    for _ in range(warmup):
        eng.generate(prompts, max_new)
        sync_eng.generate(prompts, max_new)

    # Both engines split prefill/decode wall time the same way (block after
    # prefill dispatch), so the headline ratio compares decode to decode.
    def fused():
        s0 = eng.stats()
        eng.generate(prompts, max_new)
        s1 = eng.stats()
        return (s1["prefill_seconds"] - s0["prefill_seconds"],
                s1["decode_seconds"] - s0["decode_seconds"])

    def sync():
        sync_eng.generate(prompts, max_new)
        return sync_eng.last_prefill_s, sync_eng.last_decode_s

    ((fused_prefill_s, fused_decode_s),
     (sync_prefill_s, sync_decode_s)) = _best_interleaved((fused, sync),
                                                          repeats)

    new_toks = batch * max_new
    fused_tok_s = new_toks / max(fused_decode_s, 1e-9)
    prefill_tok_s = batch * plen / max(fused_prefill_s, 1e-9)
    sync_tok_s = new_toks / max(sync_decode_s, 1e-9)

    speedup = fused_tok_s / max(sync_tok_s, 1e-9)
    stats = eng.stats()
    lookups = stats["decode_tile_lookups"] or {}
    sources = sorted({v["source"] for v in lookups.values()}) or ["none"]

    mesh_info = stats["mesh"]
    mesh_label = mesh_info["label"] or "none"
    return [
        # provenance rows: hardware profile + mesh topology keying the run
        (f"serving/{ARCH}/hardware/{stats['hardware']}", 0.0, 1.0),
        (f"serving/{ARCH}/mesh/{mesh_label}", 0.0,
         float(mesh_info["devices"])),
        (f"serving/{ARCH}/prefill_tok_s/B{batch}xP{plen}",
         fused_prefill_s / max(batch * plen, 1) * 1e6, prefill_tok_s),
        (f"serving/{ARCH}/decode_fused_tok_s/B{batch}xN{max_new}",
         fused_decode_s / new_toks * 1e6, fused_tok_s),
        (f"serving/{ARCH}/decode_per_token_sync_tok_s/B{batch}xN{max_new}",
         sync_decode_s / new_toks * 1e6, sync_tok_s),
        (f"serving/{ARCH}/decode_speedup_fused_vs_sync-{speedup:.2f}x",
         0.0, speedup),
        (f"serving/{ARCH}/decode_unroll/u{stats['decode_unroll']}/"
         f"{stats['decode_unroll_source']}", 0.0,
         float(stats["decode_unroll"] or 1)),
        (f"serving/{ARCH}/decode_tile_lookups/{len(lookups)}shapes/"
         f"{'+'.join(sources)}", 0.0, float(len(lookups))),
    ]
