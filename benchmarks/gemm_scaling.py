"""Paper Figs. 6/7: GEMM throughput vs matrix size N at fixed optimal
parameters (N = 1024 .. 20480, ΔN = 1024 — the paper's scaling protocol),
tuned-vs-untuned, on the TPU target (cost model) + host-measured small N."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import TPU_V5E, TileConfig, sweep_gemm
from repro.core.cost_model import gemm_cost
from repro.core.hardware import resolve_profile

UNTUNED = TileConfig(128, 128, 128)   # registry default = "20% of peak" case


def scaling_tpu(dtype=jnp.bfloat16, hardware=None) -> List[tuple]:
    hw = resolve_profile(hardware, default=TPU_V5E)
    rows = []
    # tune once at the paper's N=10240, then scale N with fixed params
    tuned = sweep_gemm(10240, 10240, 10240, dtype=dtype, mode="model",
                       hardware=hw, record=False).best.config
    for n in range(1024, 20481, 1024):
        c_t = gemm_cost(n, n, n, tuned, hw, dtype)
        c_u = gemm_cost(n, n, n, UNTUNED, hw, dtype)
        rows.append((f"gemm_scaling/{hw.name}/tuned/N{n}",
                     c_t.total_s * 1e6, c_t.tflops))
        rows.append((f"gemm_scaling/{hw.name}/untuned/N{n}",
                     c_u.total_s * 1e6, c_u.tflops))
    return rows


def scaling_host_measured() -> List[tuple]:
    """Wall-clock XLA GEMM on this host, N small (real execution)."""
    rows = []
    for n in (256, 512, 1024):
        a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
        f = jax.jit(lambda x, y: x @ y)
        f(a, b).block_until_ready()
        best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            f(a, b).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        rows.append((f"gemm_scaling/host-xla/N{n}", best * 1e6,
                     2 * n ** 3 / best / 1e9))
    return rows


def run(hardware=None) -> List[tuple]:
    rows = scaling_tpu(hardware=hardware)
    # thin the TPU rows for console readability: every 4th N + ends
    keep = [r for i, r in enumerate(rows)
            if (i // 2) % 4 == 0 or i >= len(rows) - 2]
    return keep + scaling_host_measured()
