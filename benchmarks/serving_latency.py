"""Per-request serving latency through the streaming front-end.

The throughput suites (``serving.py``, ``serving_sustained.py``) measure
drain wall-clock — the batch view.  This suite measures what one caller
sees: requests go through the threaded :class:`repro.serve.Server`, each
:class:`~repro.serve.GenerationResult` carries its own submit-to-first-
token (TTFT) and tokens/s, and the rows report percentiles across the
request population:

  * cold TTFT p50/p95 (prefix cache cleared — every prompt prefills),
  * warm TTFT p50 (same prompts again — full prefix hits skip prefill),
  * per-request decode tokens/s p50,
  * the prefix-cache saving on the warm pass: the fraction of prompt
    tokens whose prefill was skipped (from the versioned
    ``stats()["prefix_cache"]`` counters, so the row is deterministic).

The workload shares one seeded prompt prefix across every request (the
"same system prompt, different question" shape that motivates the cache)
with unique suffixes and mixed budgets.  ``run(smoke=True)`` shrinks the
population for the CI fast tier.
"""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from repro.configs.catalog import get_config
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig, Server

ARCH = "llama3.2-1b"
SEED = 4321
PREFIX_LEN = 32                 # shared prompt prefix (page-aligned at 16)


def _workload(n_requests: int, vocab: int):
    """One shared prefix, unique suffixes, heavy-tailed budgets."""
    rng = np.random.RandomState(SEED)
    prefix = [int(t) for t in rng.randint(1, vocab, PREFIX_LEN)]
    prompts, budgets = [], []
    for i in range(n_requests):
        suffix = [int(t) for t in rng.randint(1, vocab, 3 + i % 5)]
        prompts.append(prefix + suffix)
        budgets.append(int(rng.randint(12, 17)) if rng.rand() < 0.25
                       else int(rng.randint(3, 7)))
    return prompts, budgets


def _drive(eng: Engine, prompts, budgets):
    """One pass through the Server; returns the per-request results."""
    with Server(eng) as srv:
        handles = [srv.submit(Request(prompt=p, max_new_tokens=b))
                   for p, b in zip(prompts, budgets)]
        return [h.result(timeout=600) for h in handles]


def _pct(values, q) -> float:
    return float(np.percentile(np.asarray(values, dtype=np.float64), q))


def run(smoke: bool = False, hardware=None, mesh=None) -> List[tuple]:
    slots = 4
    max_len = 128
    n_requests = 12 if smoke else 24

    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts, budgets = _workload(n_requests, cfg.vocab_size)

    eng = Engine(model, params,
                 ServeConfig(max_batch=slots, max_len=max_len,
                             hardware=hardware, mesh=mesh))
    # two warmup passes: the first compiles every prefill/decode bucket the
    # workload touches, the second runs against its own warm cache so the
    # full-hit restore path (COW copy + snapshot restore) compiles too; the
    # measured passes below are steady-state scheduling + (cold|warm)
    # prefill only
    _drive(eng, prompts, budgets)
    _drive(eng, prompts, budgets)

    eng.clear_prefix_cache()
    saved_before = eng.stats()["prefix_cache"]["prefill_tokens_saved"]
    cold = _drive(eng, prompts, budgets)

    # same prompts again, cache warm from the cold pass: full prefix hits
    warm = _drive(eng, prompts, budgets)
    pc = eng.stats()["prefix_cache"]
    warm_prompt_tokens = sum(len(p) for p in prompts)
    saved_frac = ((pc["prefill_tokens_saved"] - saved_before)
                  / max(warm_prompt_tokens, 1))

    ttft_cold_p50 = _pct([r.ttft_s for r in cold], 50)
    ttft_cold_p95 = _pct([r.ttft_s for r in cold], 95)
    ttft_warm_p50 = _pct([r.ttft_s for r in warm], 50)
    tok_s_p50 = _pct([r.tok_per_s for r in cold], 50)

    st = eng.stats()
    return [
        (f"serving_latency/{ARCH}/hardware/{st['hardware']}", 0.0, 1.0),
        (f"serving_latency/{ARCH}/workload/n{n_requests}xS{slots}",
         0.0, float(sum(budgets))),
        (f"serving_latency/{ARCH}/ttft_cold_p50",
         ttft_cold_p50 * 1e6, 1.0 / max(ttft_cold_p50, 1e-9)),
        (f"serving_latency/{ARCH}/ttft_cold_p95",
         ttft_cold_p95 * 1e6, 1.0 / max(ttft_cold_p95, 1e-9)),
        (f"serving_latency/{ARCH}/ttft_warm_p50",
         ttft_warm_p50 * 1e6, 1.0 / max(ttft_warm_p50, 1e-9)),
        (f"serving_latency/{ARCH}/request_tok_s_p50",
         1e6 / max(tok_s_p50, 1e-9), tok_s_p50),
        (f"serving_latency/{ARCH}/prefix_saved_frac/"
         f"hits{pc['hits_full']}", 0.0, saved_frac),
    ]
