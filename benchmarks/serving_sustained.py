"""Sustained serving throughput: continuous batching vs wave scheduling.

The wave engine holds a finished row's slot until every request in its wave
exhausts its budget, so a mixed workload pays for the *longest* budget per
wave; the continuous engine evicts at chunk boundaries and refills the slot
from the queue, so it pays roughly for the *sum* of work.  This suite drives
both schedulers through the SAME saturated open-queue workload — a seeded
Poisson mix of prompt lengths and decode budgets, every request enqueued via
``submit()`` before one ``run()`` drains the backlog (the arrival process
stays saturated throughout, which is the regime where scheduling policy
matters) — and reports:

  * sustained tokens/s for the wave engine,
  * sustained tokens/s for the continuous engine,
  * their ratio (the headline row — CI gates it with
    ``--require-improvement``: continuous must beat wave),
  * paged-cache provenance (tuned page size + source, pool utilization).

``run(smoke=True)`` shrinks the workload for the CI fast tier.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.configs.catalog import get_config
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig

ARCH = "llama3.2-1b"
SEED = 1234


def _workload(n_requests: int, vocab: int, max_len: int):
    """Seeded request mix: Poisson prompt lengths, heavy-tailed budgets
    (3/4 short chat turns, 1/4 long completions).

    Budget variance is the point of the comparison — a wave pays its max
    member budget for every slot it holds, continuous pays each row only
    its own and refills the slot from the queue.
    """
    rng = np.random.RandomState(SEED)
    plens = np.clip(rng.poisson(6, n_requests), 2, 8)
    budgets = np.where(rng.rand(n_requests) < 0.25,
                       rng.randint(40, 49, n_requests),
                       rng.randint(3, 9, n_requests))
    prompts = [[int(t) for t in rng.randint(1, vocab, p)] for p in plens]
    return prompts, [int(b) for b in budgets]


def _drain(eng: Engine, prompts, budgets) -> float:
    t0 = time.perf_counter()
    for p, b in zip(prompts, budgets):
        eng.submit(Request(prompt=p, max_new_tokens=b))
    eng.run()
    return time.perf_counter() - t0


def run(smoke: bool = False, hardware=None, mesh=None) -> List[tuple]:
    slots = 4
    max_len = 128
    n_requests = 16 if smoke else 32
    repeats = 3 if smoke else 4

    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts, budgets = _workload(n_requests, cfg.vocab_size, max_len)
    total_new = sum(budgets)

    # chunk 16: boundary work (view gather/scatter, admission dispatch) is
    # amortized over twice the tokens of the default, while slots still
    # refill an order of magnitude faster than a wave turns over
    cont = Engine(model, params,
                  ServeConfig(max_batch=slots, max_len=max_len,
                              hardware=hardware, mesh=mesh,
                              decode_chunk=16))
    wave = Engine(model, params,
                  ServeConfig(max_batch=slots, max_len=max_len,
                              hardware=hardware, mesh=mesh,
                              scheduler="wave"))
    # Warmup drains compile every (plen, width) bucket the workload touches;
    # the measured repeats below are steady-state scheduling only.
    _drain(cont, prompts, budgets)
    _drain(wave, prompts, budgets)

    # Interleave the engines round-robin so both see the same machine drift,
    # and keep each engine's fastest drain (same policy as benchmarks/
    # serving.py).
    best_cont = best_wave = float("inf")
    for _ in range(repeats):
        best_cont = min(best_cont, _drain(cont, prompts, budgets))
        best_wave = min(best_wave, _drain(wave, prompts, budgets))

    # EOS-free greedy decode: every request emits its full budget, so both
    # engines moved exactly ``total_new`` tokens per drain.
    cont_tok_s = total_new / max(best_cont, 1e-9)
    wave_tok_s = total_new / max(best_wave, 1e-9)
    speedup = cont_tok_s / max(wave_tok_s, 1e-9)

    st = cont.stats()
    pages = st.get("pages") or {}
    return [
        (f"serving_sustained/{ARCH}/hardware/{st['hardware']}", 0.0, 1.0),
        (f"serving_sustained/{ARCH}/workload/n{n_requests}xS{slots}",
         0.0, float(total_new)),
        (f"serving_sustained/{ARCH}/decode_wave_tok_s/N{total_new}",
         best_wave / total_new * 1e6, wave_tok_s),
        (f"serving_sustained/{ARCH}/decode_continuous_tok_s/N{total_new}",
         best_cont / total_new * 1e6, cont_tok_s),
        (f"serving_sustained/{ARCH}/"
         f"sustained_speedup_continuous_vs_wave-{speedup:.2f}x",
         0.0, speedup),
        (f"serving_sustained/{ARCH}/page_size/p{st['page_size']}/"
         f"{st['page_size_source']}", 0.0, float(st["page_size"] or 0)),
        (f"serving_sustained/{ARCH}/page_high_water/"
         f"{pages.get('high_water_pages', 0)}of{pages.get('usable_pages', 0)}",
         0.0, float(pages.get("high_water_pages", 0))),
        (f"serving_sustained/{ARCH}/sched_events/"
         f"a{st['admissions']}e{st['evictions']}p{st['preemptions']}",
         0.0, float(st["admissions"])),
    ]
