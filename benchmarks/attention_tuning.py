"""Attention-op edition of the paper's tuning sweeps (Figs. 3/4 for flash).

The paper's methodology applied to the second kernel family of the tuning
framework: fix an attention problem (sq, skv, head_dim), sweep the
(bq, bk) block space under the VMEM feasibility predicate, keep the
best-of-repeats per candidate, and report the per-(hardware, dtype) optimum
— plus the guided search's evaluated/total fraction, exactly as for GEMM.

The model-scored sections target one hardware profile (``run(hardware=...)``,
set per CI-matrix backend via ``benchmarks.run --hardware``); the measured
section times pallas-interpret on this host under ``cpu-interpret``.

``run(smoke=True)`` shrinks every problem so the whole suite finishes in
seconds — the CI fast tier runs it and uploads ``BENCH_attention_tuning.json``
as a trajectory artifact next to the GEMM and serving benches.
"""
from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp

from repro.core import (CPU_INTERPRET, FLASH_INTERPRET_SPACE,
                        SEARCH_EXHAUSTIVE, SEARCH_GUIDED, TPU_V5E,
                        sweep_flash_attention)
from repro.core.cost_model import flash_cost
from repro.core.hardware import HardwareProfile, resolve_profile
from repro.core.tile_config import FlashAttentionConfig

S_LONG = 8192          # long-prefill sequence
S_MED = 2048
S_SMOKE = 256
HEAD_DIM = 128


def _target(hardware) -> HardwareProfile:
    """The profile the model-scored sections tune for.  ``benchmarks.run``
    always passes the resolved per-backend name (env/flag/detection); a
    direct call with ``hardware=None`` pins the paper's TPU target."""
    return resolve_profile(hardware, default=TPU_V5E)


def tune_target_model(s: int = S_LONG, d: int = HEAD_DIM,
                      dtype=jnp.bfloat16, hardware=None) -> List[tuple]:
    """Figs. 3/4 analogue for flash attention via the cost model."""
    hw = _target(hardware)
    rows = []
    res = sweep_flash_attention(s, s, d, dtype=dtype, mode="model",
                                search=SEARCH_EXHAUSTIVE, hardware=hw,
                                record=False)
    for p in sorted(res.points, key=lambda p: p.seconds):
        rows.append((f"attn_tune/{hw.name}/{jnp.dtype(dtype).name}/S{s}/"
                     f"{p.config.label}", p.seconds * 1e6, p.gflops))
    return rows


def guided_vs_exhaustive(s: int = S_LONG, d: int = HEAD_DIM,
                         dtype=jnp.bfloat16, hardware=None) -> List[tuple]:
    """Guided-search check for the attention op: fraction evaluated plus a
    winner-match verdict against the exhaustive sweep (ranking drift gate)."""
    hw = _target(hardware)
    kw = dict(dtype=dtype, mode="model", hardware=hw, record=False)
    guided = sweep_flash_attention(s, s, d, search=SEARCH_GUIDED, **kw)
    full = sweep_flash_attention(s, s, d, search=SEARCH_EXHAUSTIVE, **kw)
    frac = guided.evaluated / max(guided.candidates_total, 1)
    if guided.best.config == full.best.config:
        verdict = "winner-match"
    else:
        verdict = f"winner-off-{guided.best.seconds / full.best.seconds:.3f}x"
    return [(f"attn_tune_guided/{hw.name}/S{s}/"
             f"eval{guided.evaluated}of{guided.candidates_total}/{verdict}",
             guided.best.seconds * 1e6, frac)]


def bq_intensity_curve(s: int = S_LONG, d: int = HEAD_DIM,
                       dtype=jnp.bfloat16, hardware=None) -> List[tuple]:
    """The attention Eq.-7 analogue: doubling bq halves the K/V re-reads,
    so modelled HBM bytes fall until the VMEM cliff."""
    hw = _target(hardware)
    rows = []
    for bq in (64, 128, 256, 512):
        cfg = FlashAttentionConfig(bq=bq, bk=512)
        if not cfg.fits(hw, d, dtype):
            continue
        c = flash_cost(s, s, d, cfg, hw, dtype)
        rows.append((f"attn_intensity/{hw.name}/bq{bq}/S{s}",
                     c.total_s * 1e6, c.arithmetic_intensity))
    return rows


def tune_host_measured(s: int = 64, d: int = 16, repeats: int = 2):
    """Measured wall-clock sweep on this host (pallas-interpret, tiny S)."""
    res = sweep_flash_attention(s, s, d, dtype=jnp.float32, mode="measure",
                                space=FLASH_INTERPRET_SPACE,
                                hardware=CPU_INTERPRET,
                                repeats=repeats, record=False)
    rows = []
    for p in sorted(res.points, key=lambda p: p.seconds)[:5]:
        rows.append((f"attn_tune/{CPU_INTERPRET.name}/measured/S{s}/"
                     f"{p.config.label}", p.seconds * 1e6, p.gflops))
    return rows


def tab4_optima(sizes=(S_LONG, S_MED), d: int = HEAD_DIM, hardware=None):
    """Tab. 4 analogue: per-(hardware, dtype, S) optimum flash blocks."""
    hw = _target(hardware)
    rows = []
    for dtype in (jnp.bfloat16, jnp.float32):
        for s in sizes:
            res = sweep_flash_attention(s, s, d, dtype=dtype, mode="model",
                                        hardware=hw, record=False)
            b = res.best
            rows.append((f"attn_tab4/{hw.name}/{jnp.dtype(dtype).name}/S{s}/"
                         f"best={b.config.label}", b.seconds * 1e6, b.gflops))
    return rows


def run(smoke: bool = False, hardware: Optional[str] = None) -> List[tuple]:
    rows = []
    if smoke:
        rows += tune_target_model(S_SMOKE, hardware=hardware)[:6]
        rows += guided_vs_exhaustive(S_SMOKE, hardware=hardware)
        rows += bq_intensity_curve(S_SMOKE, hardware=hardware)
        rows += tune_host_measured(32, repeats=1)
        rows += tab4_optima(sizes=(S_SMOKE,), hardware=hardware)
        return rows
    rows += tune_target_model(hardware=hardware)[:6]
    rows += guided_vs_exhaustive(hardware=hardware)
    rows += bq_intensity_curve(hardware=hardware)
    rows += tune_host_measured()
    rows += tab4_optima(hardware=hardware)
    return rows
