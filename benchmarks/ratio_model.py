"""Paper Eqs. 5-7 validation: the compute-to-memory-ratio model
R(N, T) = 2NT/(2N+T) against the cost model's measured arithmetic intensity,
and K(S,T) = 2T^2 S against TileConfig.vmem_working_set."""
from __future__ import annotations

from typing import List

import jax.numpy as jnp

from repro.core import TPU_V5E
from repro.core.cost_model import gemm_cost, ratio_model
from repro.core.tile_config import square


def run() -> List[tuple]:
    rows = []
    for n in (4096, 10240):
        for t in (128, 256, 512):
            cfg = square(t)
            if not cfg.fits(TPU_V5E, jnp.float32):
                continue
            c = gemm_cost(n, n, n, cfg, TPU_V5E, jnp.float32)
            r_pred = ratio_model(n, t)            # flops per element
            r_meas = c.arithmetic_intensity * 4   # bytes -> elements (f32)
            rows.append((f"ratio_model/N{n}/T{t}/pred", 0.0, r_pred))
            rows.append((f"ratio_model/N{n}/T{t}/measured", 0.0, r_meas))
            # Eq. 5: K(S,T) = 2 T^2 S  (A+B tiles, f32)
            k_pred = 2 * t * t * 4
            ab = (cfg.bm * cfg.bk + cfg.bk * cfg.bn) * 4
            rows.append((f"eq5_cache/T{t}/bytes", 0.0, float(ab == k_pred)))
    return rows
