"""Measured wall-clock micro-benchmarks of the framework's hot paths on this
host (reduced configs — real executions, not estimates): train step, prefill,
decode per architecture family."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.configs.catalog import ARCHITECTURES
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.optim import AdamW
from repro.train import init_train_state, make_train_step

FAMILIES = ["llama3.2-1b", "olmoe-1b-7b", "mamba2-130m", "zamba2-2.7b",
            "whisper-large-v3", "llama-3.2-vision-11b"]


def _time(fn, *args, repeats=3):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> List[tuple]:
    rows = []
    for arch in FAMILIES:
        cfg = ARCHITECTURES[arch].reduced()
        model = build_model(cfg)
        opt = AdamW(learning_rate=1e-3)
        state = init_train_state(model, opt, jax.random.PRNGKey(0))
        pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=32, global_batch=4))
        batch = pipe(0)
        for k, sds in model.extra_inputs(4).items():
            batch[k] = jnp.zeros(sds.shape, sds.dtype)
        step = jax.jit(make_train_step(model, opt))
        t = _time(step, state, batch)
        toks = 4 * 32
        rows.append((f"train_step/{arch}/reduced", t * 1e6, toks / t))

        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(2, 64)
        pre_batch = {"tokens": batch["tokens"][:2]}
        for k, sds in model.extra_inputs(2).items():
            pre_batch[k] = jnp.zeros(sds.shape, sds.dtype)
        pf = jax.jit(model.prefill)
        t = _time(pf, params, pre_batch, cache)
        rows.append((f"prefill/{arch}/reduced", t * 1e6, 2 * 32 / t))

        _, cache2 = pf(params, pre_batch, cache)
        dec = jax.jit(model.decode_step)
        tok = jnp.zeros((2, 1), jnp.int32)
        t = _time(dec, params, tok, cache2, jnp.int32(32))
        rows.append((f"decode_step/{arch}/reduced", t * 1e6, 2 / t))
    return rows
