"""Cross-entropy loss with token-chunked unembedding.

For the large-vocab archs (moonshot: 163 840), materializing full
(B, S, V) f32 logits dominates activation memory.  ``chunked_ce`` streams
the unembed GEMM + CE over sequence chunks under ``jax.checkpoint``, so peak
logits memory is (B, chunk, V) in both fwd and bwd — a memory-roofline
optimization recorded in §Perf.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import matmul
from repro.distributed.ctx import constrain

Z_LOSS_WEIGHT = 1e-4
MOE_AUX_WEIGHT = 1e-2


def _ce_block(x, w, labels):
    """x: (B, C, D) final-normed hidden; w: (D, V); labels: (B, C)."""
    logits = constrain(matmul(x, w.astype(x.dtype), out_dtype=jnp.float32),
                       "logits")
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (lse - picked).sum()
    z = jnp.square(lse).sum()
    return ce, z


def chunked_ce(x, w, labels, *, chunk: int = 0) -> Tuple[jax.Array, jax.Array]:
    """-> (sum CE over tokens, sum z-loss).  chunk=0 -> single pass."""
    b, s, d = x.shape
    if chunk <= 0 or s <= chunk or s % chunk != 0:
        return _ce_block(x, w, labels)
    n = s // chunk
    xs = x.reshape(b, n, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(b, n, chunk).swapaxes(0, 1)

    def body(carry, xs_t):
        ce_acc, z_acc = carry
        xc, lc = xs_t
        ce, z = jax.checkpoint(_ce_block)(xc, w, lc)
        return (ce_acc + ce, z_acc + z), None

    (ce, z), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (xs, ls))
    return ce, z


def lm_loss(model, params, batch, *, logit_chunk: Optional[int] = None):
    """Next-token LM loss.  batch['tokens'] (B, S), batch['labels'] (B, S).

    -> (loss scalar, metrics dict)."""
    hidden, aux = model.forward_hidden(params, batch)
    hidden = model.final_norm(params, hidden)
    w = model.unembed_weight(params)
    chunk = model.cfg.logit_chunk if logit_chunk is None else logit_chunk
    ce_sum, z_sum = chunked_ce(hidden, w, batch["labels"], chunk=chunk)
    ntok = batch["labels"].size
    ce = ce_sum / ntok
    z = z_sum / ntok
    loss = ce + Z_LOSS_WEIGHT * z + MOE_AUX_WEIGHT * aux
    return loss, {"ce": ce, "z_loss": z, "moe_aux": aux,
                  "perplexity": jnp.exp(ce)}
