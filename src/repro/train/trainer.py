"""Training step construction + the training loop.

``make_train_step`` builds the pure step function (grad accumulation over
microbatches, optional int8 gradient compression with error feedback,
AdamW with f32 masters); ``Trainer`` wires it to the data pipeline,
checkpointing and fault-tolerance policies.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as sh
from repro.models.model import Model
from repro.optim import compression as comp
from repro.optim.adamw import AdamW, AdamWState
from repro.train.loss import lm_loss


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: AdamWState
    compression: Optional[comp.CompressionState]


def init_train_state(model: Model, optimizer: AdamW, key,
                     use_compression: bool = False) -> TrainState:
    params = model.init(key)
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt=optimizer.init(params),
        compression=comp.init_state(params) if use_compression else None)


def abstract_train_state(model: Model, optimizer: AdamW,
                         use_compression: bool = False) -> TrainState:
    ap = model.abstract()
    return TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=ap,
        opt=optimizer.abstract_state(ap),
        compression=comp.abstract_state(ap) if use_compression else None)


def state_shardings(mesh: Mesh, rules: sh.ShardingRules, model: Model,
                    use_compression: bool = False) -> TrainState:
    ps = sh.param_shardings(mesh, rules, model.template)
    rep = NamedSharding(mesh, P())
    return TrainState(
        step=rep,
        params=ps,
        opt=AdamWState(count=rep, m=ps, v=ps, master=ps),
        compression=comp.CompressionState(residual=ps) if use_compression else None)


def make_train_step(model: Model, optimizer: AdamW, *,
                    microbatches: int = 1,
                    use_compression: bool = False) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return lm_loss(model, params, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        # Gradient accumulation: split the global batch along dim 0 and scan.
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        micro = jax.tree_util.tree_map(split, batch)
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, _), grads = grad_fn(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches, acc, grads)
            return (acc, loss_acc + loss / microbatches), None

        (grads, loss), _ = jax.lax.scan(body, (zeros, jnp.float32(0)), micro)
        return loss, {}, grads

    def train_step(state: TrainState, batch) -> tuple:
        loss, metrics, grads = compute_grads(state.params, batch)
        new_comp = state.compression
        if use_compression:
            grads, new_comp = comp.compress_grads(grads, state.compression)
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, state.opt, state.params)
        new_state = TrainState(state.step + 1, new_params, new_opt, new_comp)
        return new_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


# ---------------------------------------------------------------------------
# Serving steps (used by the dry-run and serve/engine.py)
# ---------------------------------------------------------------------------

def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)
    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, tokens, cache, offset):
        return model.decode_step(params, tokens, cache, offset)
    return decode_step


# ---------------------------------------------------------------------------
# Training loop with fault-tolerance hooks
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    microbatches: int = 1
    use_compression: bool = False
    step_deadline_s: Optional[float] = None   # straggler watchdog


class Trainer:
    def __init__(self, model: Model, optimizer: AdamW, data_iter,
                 cfg: TrainerConfig, mesh: Optional[Mesh] = None,
                 rules: Optional[sh.ShardingRules] = None,
                 checkpointer=None):
        self.model = model
        self.optimizer = optimizer
        self.data_iter = data_iter
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules
        self.checkpointer = checkpointer
        step = make_train_step(model, optimizer,
                               microbatches=cfg.microbatches,
                               use_compression=cfg.use_compression)
        if mesh is not None:
            from repro.distributed.ctx import activation_policy
            shardings = state_shardings(mesh, rules, model, cfg.use_compression)

            def step_with_policy(state, batch):
                with activation_policy(mesh, rules):
                    return step(state, batch)

            self._step = jax.jit(step_with_policy,
                                 in_shardings=(shardings, None),
                                 out_shardings=(shardings, None),
                                 donate_argnums=(0,))
        else:
            self._step = jax.jit(step, donate_argnums=(0,))

    def run(self, state: TrainState, start_step: int = 0):
        """Run to total_steps; returns (state, history).  Deterministic data
        (keyed by step) makes restart-after-failure exactly replayable.

        Logged losses stay on device while the loop runs; one batched
        transfer at the end materializes the history, so logging never
        serializes the dispatch pipeline mid-run."""
        logged_steps = []
        logged_losses = []                     # device scalars until the end
        from repro.profiling import annotate
        for step_idx in range(start_step, self.cfg.total_steps):
            batch = self.data_iter(step_idx)
            t0 = time.perf_counter()
            with annotate("train.step"):
                state, metrics = self._step(state, batch)
            if self.cfg.step_deadline_s is not None:
                # deliberate sync: the straggler watchdog measures the real
                # step wall time, which requires the step to have finished
                jax.block_until_ready(metrics["loss"])   # analysis: allow(TP001)
                dt = time.perf_counter() - t0
                if dt > self.cfg.step_deadline_s:
                    # Straggler policy: surface the event; the launcher decides
                    # whether to evict the slow host and re-shard (elastic).
                    metrics = dict(metrics)
                    metrics["straggler_flag"] = jnp.float32(dt)
            if (step_idx + 1) % self.cfg.log_every == 0:
                logged_steps.append(step_idx + 1)
                logged_losses.append(metrics["loss"])
            if (self.checkpointer is not None
                    and (step_idx + 1) % self.cfg.checkpoint_every == 0):
                self.checkpointer.save(step_idx + 1, state)
        # the ONE host transfer of the run: batched history materialization
        losses = jax.device_get(logged_losses)   # analysis: allow(TP001)
        return state, [(s, float(l)) for s, l in zip(logged_steps, losses)]
