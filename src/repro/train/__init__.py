from repro.train.loss import chunked_ce, lm_loss  # noqa: F401
from repro.train.trainer import (  # noqa: F401
    Trainer, TrainerConfig, TrainState, abstract_train_state, init_train_state,
    make_decode_step, make_prefill_step, make_train_step, state_shardings,
)
