"""Token-choice top-k Mixture-of-Experts layer (OLMoE / Moonlight style).

Capacity-based dispatch (GShard lineage) chosen for SPMD-friendliness:
routing is computed *per sequence group* (the batch dim, which is
data-parallel sharded), so no routing decision crosses a device boundary;
expert weights are expert-parallel ("expert" logical axis -> "model" mesh
axis) and the dispatch/combine contractions lower to the all-to-all pattern
XLA inserts for EP.

Memory: dispatch buffers are (E, C, D) per group with
C = ceil(top_k * S * capacity_factor / E), i.e. ~top_k * cf * tokens * d
total — bounded, scan/remat friendly.  Dropped tokens (over capacity) fall
back to the residual stream, standard for capacity-factor MoE.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import einsum, matmul
from repro.distributed.ctx import constrain
from repro.models.params import ParamSpec


def moe_template(d_model: int, d_ff: int, num_experts: int):
    e = num_experts
    return {
        "router": ParamSpec((d_model, e), ("embed", None), scale=0.02),
        "w_gate": ParamSpec((e, d_model, d_ff), ("expert", "embed", "ff")),
        "w_up": ParamSpec((e, d_model, d_ff), ("expert", "embed", "ff")),
        "w_down": ParamSpec((e, d_ff, d_model), ("expert", "ff", "embed")),
    }


def capacity(seq_len: int, num_experts: int, top_k: int, cf: float) -> int:
    c = math.ceil(top_k * seq_len * cf / num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def _route_group(x, logits, *, top_k: int, num_experts: int, cap: int):
    """Route one sequence group.  x: (S, D), logits: (S, E)."""
    s, d = x.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)              # (S, K)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # Slot -> expert one-hot, position within expert buffer via cumsum.
    onehot = jax.nn.one_hot(idx, num_experts, dtype=jnp.int32)   # (S, K, E)
    flat = onehot.reshape(s * top_k, num_experts)
    pos = jnp.cumsum(flat, axis=0) * flat - flat                 # (S*K, E)
    slot_pos = pos.sum(-1)                                       # (S*K,)
    slot_exp = idx.reshape(s * top_k)
    keep = slot_pos < cap

    # Dispatch: scatter tokens (repeated per chosen expert) into (E, C, D).
    xk = jnp.repeat(x, top_k, axis=0)                            # (S*K, D)
    buf = jnp.zeros((num_experts * cap, d), x.dtype)
    tgt = jnp.where(keep, slot_exp * cap + slot_pos, num_experts * cap)
    buf = buf.at[tgt].add(xk * keep[:, None].astype(x.dtype),
                          mode="drop", indices_are_sorted=False)
    return buf.reshape(num_experts, cap, d), (slot_exp, slot_pos, keep,
                                              gate.reshape(s * top_k))


def _combine_group(expert_out, route, s: int, top_k: int, cap: int, dtype):
    slot_exp, slot_pos, keep, gate = route
    e, c, d = expert_out.shape
    flat = expert_out.reshape(e * c, d)
    src = jnp.clip(slot_exp * cap + slot_pos, 0, e * c - 1)
    # Combine in the activation dtype: the gather from the expert-sharded
    # buffer lowers to a masked-select + all-reduce over the EP axis, so
    # keeping it bf16 halves that collective's bytes (gate stays f32 for
    # routing; a k<=8-way weighted sum in bf16 is numerically benign).
    gathered = flat[src]                                          # (S*K, D)
    w = (gate * keep).astype(dtype)[:, None]
    out = (gathered * w).reshape(s, top_k, d).sum(1)
    return out.astype(dtype)


def moe_layer(params, x: jax.Array, *, top_k: int, num_experts: int,
              capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).  Routing vmapped over batch groups."""
    b, s, d = x.shape
    cap = capacity(s, num_experts, top_k, capacity_factor)
    logits = matmul(x, params["router"])                           # (B, S, E)

    bufs, routes = jax.vmap(
        lambda xg, lg: _route_group(xg, lg, top_k=top_k,
                                    num_experts=num_experts, cap=cap)
    )(x, logits)                                                   # (B, E, C, D)

    # EP pin: batch-sharded -> expert-sharded transition = all-to-all.
    bufs = constrain(bufs, "moe_dispatch")

    # Expert FFN: grouped GEMMs over the expert axis (EP-sharded).
    h = jax.nn.silu(einsum("becd,edf->becf", bufs, params["w_gate"]))
    h = h * einsum("becd,edf->becf", bufs, params["w_up"])
    out_e = einsum("becf,efd->becd", h.astype(x.dtype), params["w_down"])
    out_e = constrain(out_e, "moe_dispatch")

    out = jax.vmap(
        lambda eo, r: _combine_group(eo, r, s, top_k, cap, x.dtype)
    )(out_e, routes)
    out = constrain(out, "hidden")

    # Load-balance auxiliary loss (Switch-style): E * sum(f_e * p_e).
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    me = probs.mean((0, 1))
    onehot_top1 = jax.nn.one_hot(jnp.argmax(logits, -1), num_experts)
    ce = onehot_top1.mean((0, 1))
    aux = num_experts * jnp.sum(me * ce)
    return out, aux
