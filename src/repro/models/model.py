"""Model factory: one uniform functional bundle per architecture family."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import hybrid as H
from repro.models import transformer as T
from repro.models.params import (abstract_params, init_params, param_count,
                                 ParamSpec)


@dataclasses.dataclass(frozen=True)
class Model:
    """Functional model bundle (params are passed explicitly everywhere)."""
    cfg: ModelConfig
    template: Any                          # ParamSpec pytree

    def init(self, key: jax.Array, mesh=None, rules=None):
        """Random params; with ``mesh`` (+ optional ``rules``) every leaf is
        placed by the sharding rules — same values, sharded layout."""
        shardings = None
        if mesh is None:
            from repro.distributed import ctx
            mesh, rules = ctx.current_mesh(), rules or ctx.current_rules()
        if mesh is not None:
            from repro.distributed import sharding as sh
            rules = rules or sh.rules_for_mesh(mesh)
            shardings = sh.param_shardings(mesh, rules, self.template)
        return init_params(self.template, key, default_dtype=self.cfg.dtype,
                           shardings=shardings)

    def abstract(self):
        return abstract_params(self.template, default_dtype=self.cfg.dtype)

    def param_count(self) -> int:
        return param_count(self.template)

    # family dispatch ---------------------------------------------------
    def _mod(self):
        return H if self.cfg.family in ("ssm", "hybrid") else T

    def forward(self, params, batch: Dict[str, jax.Array]):
        """-> (logits (B, S, V) f32, aux_loss)."""
        return self._mod().forward(self.cfg, params, batch)

    def forward_hidden(self, params, batch: Dict[str, jax.Array]):
        """-> (final hidden pre-norm (B, S, D), aux_loss) — for chunked loss."""
        return self._mod().forward_hidden(self.cfg, params, batch)

    def unembed_weight(self, params):
        from repro.models import transformer as _T
        return _T.unembed_weight(self.cfg, params)

    def final_norm(self, params, x):
        from repro.models import layers as _L
        return _L.apply_norm(params["ln_f"], x, eps=self.cfg.norm_eps)

    def init_cache(self, batch: int, max_len: int, dtype=None):
        return self._mod().init_cache(self.cfg, batch, max_len, dtype)

    def prefill(self, params, batch, cache):
        """``batch`` may carry ``kv_start`` (B,) left-pad offsets for ragged
        batches; see transformer.prefill."""
        return self._mod().prefill(self.cfg, params, batch, cache)

    def decode_step(self, params, tokens, cache, offset, kv_start=None):
        return self._mod().decode_step(self.cfg, params, tokens, cache,
                                       offset, kv_start)

    # extra model inputs beyond tokens (modality-frontend STUBS) ---------
    def extra_inputs(self, batch_size: int) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        if cfg.family == "vlm":
            return {"image_embeds": jax.ShapeDtypeStruct(
                (batch_size, cfg.num_image_tokens, cfg.d_model), dt)}
        if cfg.family == "audio":
            return {"encoder_embeds": jax.ShapeDtypeStruct(
                (batch_size, cfg.encoder_len, cfg.d_model), dt)}
        return {}


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("ssm", "hybrid"):
        tpl = H.template(cfg)
    elif cfg.family in ("dense", "moe", "vlm", "audio"):
        tpl = T.template(cfg)
    else:
        raise ValueError(f"unknown family {cfg.family!r}")
    return Model(cfg=cfg, template=tpl)


def active_param_count(model: Model) -> int:
    """Per-token active parameters (MoE counts top-k experts only) — used
    for MODEL_FLOPS = 6 * N_active * D in the roofline."""
    cfg = model.cfg
    total = model.param_count()
    if not cfg.num_experts:
        return total
    # Expert weights: 3 * d_model * d_ff per expert per layer.
    per_layer_exp = 3 * cfg.d_model * cfg.d_ff
    inactive = cfg.num_layers * per_layer_exp * (cfg.num_experts - cfg.experts_per_token)
    return total - inactive
