"""Decoder-only transformer LM (dense / MoE / VLM cross-attn) + Whisper
enc-dec — all built from the shared layers and the single-source GEMM.

Layers are stacked (leading "layer" axis) and executed with ``jax.lax.scan``
(+ optional ``jax.checkpoint``), which keeps compile time flat across the
40-cell dry-run and is the memory-efficient choice on TPU.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import matmul
from repro.distributed.ctx import constrain
from repro.models import layers as L
from repro.models import moe as M
from repro.models.params import ParamSpec


def attn_dims(cfg: ModelConfig) -> L.AttnDims:
    return L.AttnDims(cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim)


def _stack_template(t, n: int):
    """Prepend a 'layer' axis of size n to every ParamSpec in ``t``."""
    def f(spec: ParamSpec):
        return ParamSpec((n,) + spec.shape, ("layer",) + spec.axes,
                         init=spec.init, scale=spec.scale, dtype=spec.dtype)
    return jax.tree_util.tree_map(f, t, is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------

def _dense_block_template(cfg: ModelConfig):
    qkv_bias = cfg.name.startswith("chatglm")  # ChatGLM uses QKV bias
    t = {
        "ln1": L.norm_template(cfg.d_model, cfg.norm),
        "attn": L.attention_template(cfg.d_model, attn_dims(cfg), qkv_bias),
        "ln2": L.norm_template(cfg.d_model, cfg.norm),
    }
    if cfg.num_experts:
        t["moe"] = M.moe_template(cfg.d_model, cfg.d_ff, cfg.num_experts)
    else:
        t["mlp"] = L.mlp_template(cfg.d_model, cfg.d_ff)
    return t


def _cross_block_template(cfg: ModelConfig):
    return {
        "ln1": L.norm_template(cfg.d_model, cfg.norm),
        "cross": L.attention_template(cfg.d_model, attn_dims(cfg)),
        "ln2": L.norm_template(cfg.d_model, cfg.norm),
        "mlp": L.mlp_template(cfg.d_model, cfg.d_ff),
    }


def template(cfg: ModelConfig):
    t: Dict[str, Any] = {
        "embedding": ParamSpec((cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"), scale=0.02),
        "ln_f": L.norm_template(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                 ("embed", "vocab"))
    if cfg.family == "vlm":
        units = cfg.num_layers // cfg.cross_attn_period
        per_unit = cfg.cross_attn_period - 1
        t["units"] = {
            "selfs": _stack_template(
                _stack_template(_dense_block_template(cfg), per_unit), units),
            "cross": _stack_template(_cross_block_template(cfg), units),
        }
    elif cfg.family == "audio":
        t["enc_blocks"] = _stack_template(_encoder_block_template(cfg),
                                          cfg.encoder_layers)
        t["enc_ln_f"] = L.norm_template(cfg.d_model, cfg.norm)
        t["dec_blocks"] = _stack_template(_whisper_dec_block_template(cfg),
                                          cfg.num_layers)
        t["pos_emb"] = ParamSpec((cfg.learned_positions, cfg.d_model),
                                 (None, "embed"), scale=0.02)
    else:
        t["blocks"] = _stack_template(_dense_block_template(cfg), cfg.num_layers)
    return t


def _encoder_block_template(cfg: ModelConfig):
    return {
        "ln1": L.norm_template(cfg.d_model, cfg.norm),
        "attn": L.attention_template(cfg.d_model, attn_dims(cfg), qkv_bias=True),
        "ln2": L.norm_template(cfg.d_model, cfg.norm),
        "mlp": L.mlp_gelu_template(cfg.d_model, cfg.d_ff),
    }


def _whisper_dec_block_template(cfg: ModelConfig):
    return {
        "ln1": L.norm_template(cfg.d_model, cfg.norm),
        "attn": L.attention_template(cfg.d_model, attn_dims(cfg), qkv_bias=True),
        "ln_x": L.norm_template(cfg.d_model, cfg.norm),
        "cross": L.attention_template(cfg.d_model, attn_dims(cfg), qkv_bias=True),
        "ln2": L.norm_template(cfg.d_model, cfg.norm),
        "mlp": L.mlp_gelu_template(cfg.d_model, cfg.d_ff),
    }


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _dense_block(cfg: ModelConfig, bp, x, positions, kv_cache=None,
                 cache_offset=None, kv_start=None):
    dims = attn_dims(cfg)
    h, new_cache = L.attention(
        bp["attn"], L.apply_norm(bp["ln1"], x, eps=cfg.norm_eps), dims,
        positions=positions,
        rope_theta=cfg.rope_theta if cfg.use_rope else 0.0,
        rope_fraction=cfg.rope_fraction,
        kv_cache=kv_cache, cache_offset=cache_offset,
        p_dtype=jnp.dtype(cfg.attn_p_dtype),
        attn_impl=cfg.attention_impl, kv_start=kv_start)
    x = x + h
    y_in = L.apply_norm(bp["ln2"], x, eps=cfg.norm_eps)
    if cfg.num_experts:
        y, aux = M.moe_layer(
            bp["moe"], y_in, top_k=cfg.experts_per_token,
            num_experts=cfg.num_experts,
            capacity_factor=cfg.moe_capacity_factor)
    else:
        y, aux = L.mlp(bp["mlp"], y_in), 0.0
    return x + y, new_cache, aux


def _cross_block(cfg: ModelConfig, bp, x, cross_kv_pair):
    dims = attn_dims(cfg)
    h, _ = L.attention(
        bp["cross"], L.apply_norm(bp["ln1"], x, eps=cfg.norm_eps), dims,
        kv_override=cross_kv_pair, p_dtype=jnp.dtype(cfg.attn_p_dtype))
    x = x + h
    y = L.mlp(bp["mlp"], L.apply_norm(bp["ln2"], x, eps=cfg.norm_eps))
    return x + y


def _maybe_remat(cfg: ModelConfig, fn):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        # keep every matmul output resident; recompute only cheap elementwise
        # ops in the backward — trades HBM capacity for HBM traffic.
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Decoder-only stacks (dense / moe)
# ---------------------------------------------------------------------------

def _run_dense_stack(cfg, blocks, x, positions, caches=None, cache_offset=None,
                     kv_start=None):
    """scan over stacked layer params (+ caches).  Returns (x, new_caches, aux)."""
    has_cache = caches is not None

    def body(carry, xs):
        x, aux = carry
        bp = xs[0] if has_cache else xs
        cache = xs[1] if has_cache else None
        x, new_cache, a = _dense_block(cfg, bp, x, positions,
                                       kv_cache=cache, cache_offset=cache_offset,
                                       kv_start=kv_start)
        return (constrain(x, "hidden"), aux + a), new_cache

    xs = (blocks, caches) if has_cache else blocks
    (x, aux), new_caches = jax.lax.scan(_maybe_remat(cfg, body), (x, 0.0), xs)
    return x, (new_caches if has_cache else None), aux


# ---------------------------------------------------------------------------
# Public API per family
# ---------------------------------------------------------------------------

def _embed(cfg, params, tokens):
    x = params["embedding"][tokens].astype(jnp.dtype(cfg.dtype))
    return constrain(x, "hidden")


def _unembed(cfg, params, x):
    x = L.apply_norm(params["ln_f"], x, eps=cfg.norm_eps)
    w = params["embedding"].T if cfg.tie_embeddings else params["lm_head"]
    return constrain(matmul(x, w.astype(x.dtype), out_dtype=jnp.float32),
                     "logits")


def _positions(batch: int, seq: int, offset=0):
    return offset + jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                                     (batch, seq))


def _ragged_positions(seq: int, kv_start):
    """Per-row positions for a left-padded ragged batch: the first real token
    of every row sits at position 0 (pad columns clamp to 0 — they're masked
    out of attention anyway)."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] - kv_start[:, None]
    return jnp.maximum(pos, 0)


def forward_hidden(cfg: ModelConfig, params, batch: Dict[str, jax.Array]):
    """Training/scoring trunk -> (final hidden pre-norm (B,S,D), aux_loss)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed(cfg, params, tokens)
    pos = _positions(b, s)
    if cfg.family == "vlm":
        x, _, aux = _run_vlm_stack(cfg, params, x, pos,
                                   image_embeds=batch["image_embeds"])
    elif cfg.family == "audio":
        enc = _run_encoder(cfg, params, batch["encoder_embeds"])
        x = x + params["pos_emb"][:s][None].astype(x.dtype)
        x, _, aux = _run_whisper_decoder(cfg, params, x, pos, enc)
    else:
        x, _, aux = _run_dense_stack(cfg, params["blocks"], x, pos)
    return x, aux


def unembed_weight(cfg: ModelConfig, params):
    return params["embedding"].T if cfg.tie_embeddings else params["lm_head"]


def forward(cfg: ModelConfig, params, batch: Dict[str, jax.Array]):
    """Training/scoring forward -> (logits_f32 (B,S,V), aux_loss)."""
    x, aux = forward_hidden(cfg, params, batch)
    return _unembed(cfg, params, x), aux


# -- caches -----------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """KV cache pytree for decode.  Leading 'layer' axis matches the scans."""
    dtype = jnp.dtype(dtype or cfg.dtype)
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    kv_plain = lambda n, s: (jnp.zeros((n, batch, s, kvh, hd), dtype),
                             jnp.zeros((n, batch, s, kvh, hd), dtype))
    if cfg.kv_quant:
        def kv(n, s):
            one = {"q": jnp.zeros((n, batch, s, kvh, hd), jnp.int8),
                   "s": jnp.zeros((n, batch, s, kvh), jnp.float32)}
            return (one, jax.tree_util.tree_map(jnp.copy, one))
    else:
        kv = kv_plain
    if cfg.family == "vlm":
        units = cfg.num_layers // cfg.cross_attn_period
        per_unit = cfg.cross_attn_period - 1
        return {
            "self": (jnp.zeros((units, per_unit, batch, max_len, kvh, hd), dtype),
                     jnp.zeros((units, per_unit, batch, max_len, kvh, hd), dtype)),
            # cross caches hold projections recomputed at prefill — plain dtype
            "cross": kv_plain(units, cfg.num_image_tokens),
        }
    if cfg.family == "audio":
        return {"self": kv(cfg.num_layers, max_len),
                "cross": kv_plain(cfg.num_layers, cfg.encoder_len)}
    return {"self": kv(cfg.num_layers, max_len)}


def prefill(cfg: ModelConfig, params, batch, cache):
    """Run the prompt through the model, filling ``cache``.
    Returns (last-token logits (B, V), new_cache).

    ``batch["kv_start"]`` (optional, (B,) int32) marks per-row left-pad
    lengths for ragged batches: pad columns are masked out of attention and
    positions restart at 0 at each row's first real token, so every row
    computes exactly what it would alone (prompts are right-aligned, so the
    shared last column is each row's final prompt token)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    kv_start = batch.get("kv_start")
    x = _embed(cfg, params, tokens)
    pos = _positions(b, s) if kv_start is None else _ragged_positions(s, kv_start)
    offset = jnp.int32(0)
    if cfg.family == "vlm":
        cache = dict(cache)
        cache["cross"] = _vlm_cross_cache(cfg, params, batch["image_embeds"])
        x, new_self, _ = _run_vlm_stack(cfg, params, x, pos,
                                        cross_cache=cache["cross"],
                                        self_caches=cache["self"],
                                        cache_offset=offset,
                                        kv_start=kv_start)
        new_cache = {"self": new_self, "cross": cache["cross"]}
    elif cfg.family == "audio":
        enc = _run_encoder(cfg, params, batch["encoder_embeds"])
        cross = _whisper_cross_cache(cfg, params, enc)
        if kv_start is None:
            x = x + params["pos_emb"][:s][None].astype(x.dtype)
        else:  # per-row shifted learned positions
            x = x + params["pos_emb"][pos].astype(x.dtype)
        x, new_self, _ = _run_whisper_decoder(cfg, params, x, pos,
                                              enc, cross_cache=cross,
                                              self_caches=cache["self"],
                                              cache_offset=offset,
                                              kv_start=kv_start)
        new_cache = {"self": new_self, "cross": cross}
    else:
        x, new_self, _ = _run_dense_stack(cfg, params["blocks"], x, pos,
                                          caches=cache["self"],
                                          cache_offset=offset,
                                          kv_start=kv_start)
        new_cache = {"self": new_self}
    logits = _unembed(cfg, params, x[:, -1:, :])[:, 0]
    return logits, new_cache


def decode_step(cfg: ModelConfig, params, tokens, cache, offset, kv_start=None):
    """One token step.  tokens: (B, 1); offset: scalar int32 = current length.
    ``kv_start``: optional (B,) pad offsets for ragged batches (see prefill).
    Returns (logits (B, V), new_cache)."""
    b = tokens.shape[0]
    x = _embed(cfg, params, tokens)
    if kv_start is None:
        pos = jnp.broadcast_to(offset.astype(jnp.int32), (b, 1))
    else:
        pos = jnp.maximum(offset.astype(jnp.int32) - kv_start, 0)[:, None]
    if cfg.family == "vlm":
        x, new_self, _ = _run_vlm_stack(cfg, params, x, pos,
                                        cross_cache=cache["cross"],
                                        self_caches=cache["self"],
                                        cache_offset=offset,
                                        kv_start=kv_start)
        new_cache = {"self": new_self, "cross": cache["cross"]}
    elif cfg.family == "audio":
        if kv_start is None:
            x = x + jax.lax.dynamic_slice_in_dim(
                params["pos_emb"], offset, 1, 0)[None].astype(x.dtype)
        else:
            x = x + params["pos_emb"][pos[:, 0]][:, None].astype(x.dtype)
        x, new_self, _ = _run_whisper_decoder(cfg, params, x, pos, None,
                                              cross_cache=cache["cross"],
                                              self_caches=cache["self"],
                                              cache_offset=offset,
                                              kv_start=kv_start)
        new_cache = {"self": new_self, "cross": cache["cross"]}
    else:
        x, new_self, _ = _run_dense_stack(cfg, params["blocks"], x, pos,
                                          caches=cache["self"],
                                          cache_offset=offset,
                                          kv_start=kv_start)
        new_cache = {"self": new_self}
    logits = _unembed(cfg, params, x)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# VLM (llama-3.2-vision style): units of (P-1 self layers + 1 cross layer)
# ---------------------------------------------------------------------------

def _vlm_cross_cache(cfg, params, image_embeds):
    dims = attn_dims(cfg)
    def per_unit(cp):
        return L.cross_kv(cp["cross"], image_embeds.astype(jnp.dtype(cfg.dtype)), dims)
    ks, vs = jax.lax.map(per_unit, params["units"]["cross"])
    return ks, vs  # (U, B, n_img, kv, hd)


def _run_vlm_stack(cfg, params, x, positions, image_embeds=None,
                   cross_cache=None, self_caches=None, cache_offset=None,
                   kv_start=None):
    dims = attn_dims(cfg)
    if cross_cache is None:
        cross_cache = _vlm_cross_cache(cfg, params, image_embeds)
    has_cache = self_caches is not None

    def unit_body(carry, xs):
        x, aux = carry
        if has_cache:
            selfs, cross_p, ck, cv, scache = xs
        else:
            selfs, cross_p, ck, cv = xs
            scache = None

        def inner(c, ys):
            xx, a = c
            bp = ys[0] if has_cache else ys
            cache = ys[1] if has_cache else None
            xx, nc, da = _dense_block(cfg, bp, xx, positions, kv_cache=cache,
                                      cache_offset=cache_offset,
                                      kv_start=kv_start)
            return (constrain(xx, "hidden"), a + da), nc

        ys = (selfs, scache) if has_cache else selfs
        (x, aux), new_scache = jax.lax.scan(inner, (x, aux), ys)
        x = constrain(_cross_block(cfg, cross_p, x, (ck, cv)), "hidden")
        out = new_scache if has_cache else 0.0
        return (x, aux), out

    u = params["units"]
    ks, vs = cross_cache
    xs = (u["selfs"], u["cross"], ks, vs) + ((self_caches,) if has_cache else ())
    (x, aux), new_caches = jax.lax.scan(_maybe_remat(cfg, unit_body), (x, 0.0), xs)
    return x, (new_caches if has_cache else None), aux


# ---------------------------------------------------------------------------
# Whisper enc-dec
# ---------------------------------------------------------------------------

def _run_encoder(cfg, params, encoder_embeds):
    """encoder_embeds: (B, enc_len, D) — the conv-frontend STUB output."""
    x = encoder_embeds.astype(jnp.dtype(cfg.dtype))
    dims = attn_dims(cfg)

    def body(x, bp):
        h, _ = L.attention(bp["attn"], L.apply_norm(bp["ln1"], x, eps=cfg.norm_eps),
                           dims, causal=False,
                           p_dtype=jnp.dtype(cfg.attn_p_dtype),
                           attn_impl=cfg.attention_impl)
        x = x + h
        x = x + L.mlp_gelu(bp["mlp"], L.apply_norm(bp["ln2"], x, eps=cfg.norm_eps))
        return constrain(x, "hidden"), None

    x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, params["enc_blocks"])
    return L.apply_norm(params["enc_ln_f"], x, eps=cfg.norm_eps)


def _whisper_cross_cache(cfg, params, enc):
    dims = attn_dims(cfg)
    ks, vs = jax.lax.map(lambda bp: L.cross_kv(bp["cross"], enc, dims),
                         params["dec_blocks"])
    return ks, vs


def _run_whisper_decoder(cfg, params, x, positions, enc, cross_cache=None,
                         self_caches=None, cache_offset=None, kv_start=None):
    dims = attn_dims(cfg)
    if cross_cache is None:
        cross_cache = _whisper_cross_cache(cfg, params, enc)
    has_cache = self_caches is not None

    def body(carry, xs):
        x, aux = carry
        if has_cache:
            bp, ck, cv, cache = xs
        else:
            bp, ck, cv = xs
            cache = None
        h, new_cache = L.attention(
            bp["attn"], L.apply_norm(bp["ln1"], x, eps=cfg.norm_eps), dims,
            positions=positions, kv_cache=cache, cache_offset=cache_offset,
            p_dtype=jnp.dtype(cfg.attn_p_dtype),
            attn_impl=cfg.attention_impl, kv_start=kv_start)
        x = x + h
        h, _ = L.attention(bp["cross"],
                           L.apply_norm(bp["ln_x"], x, eps=cfg.norm_eps),
                           dims, kv_override=(ck, cv),
                           p_dtype=jnp.dtype(cfg.attn_p_dtype))
        x = x + h
        x = x + L.mlp_gelu(bp["mlp"], L.apply_norm(bp["ln2"], x, eps=cfg.norm_eps))
        return (constrain(x, "hidden"), aux), (new_cache if has_cache else 0.0)

    ks, vs = cross_cache
    xs = (params["dec_blocks"], ks, vs) + ((self_caches,) if has_cache else ())
    (x, aux), new_caches = jax.lax.scan(_maybe_remat(cfg, body), (x, 0.0), xs)
    return x, (new_caches if has_cache else None), aux
