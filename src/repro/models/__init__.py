from repro.models.model import Model, active_param_count, build_model  # noqa: F401
