"""Shared neural-net layers: norms, RoPE, GQA attention (chunked), MLP.

Every dense projection routes through ``core.matmul`` — the paper's
single-source GEMM — so per-architecture tile tuning applies to the whole
model zoo without touching this file.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import einsum, matmul
from repro.models.params import ParamSpec

logger = logging.getLogger(__name__)

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_template(d: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": ParamSpec((d,), ("embed",), init="ones")}
    if kind == "layernorm":
        return {"scale": ParamSpec((d,), ("embed",), init="ones"),
                "bias": ParamSpec((d,), ("embed",), init="zeros")}
    raise ValueError(kind)


def apply_norm(params, x, *, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        xf = xf - mu
        var = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    else:  # rmsnorm
        var = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (with partial-dim fraction, as in ChatGLM / StableLM)
# ---------------------------------------------------------------------------

def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float,
               fraction: float = 1.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    rot = int(d * fraction) // 2 * 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if rot < d else out


# ---------------------------------------------------------------------------
# Attention (GQA, query-chunked for O(S * chunk) score memory, KV cache)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnDims:
    num_heads: int
    num_kv_heads: int
    head_dim: int

    @property
    def group(self) -> int:
        return self.num_heads // self.num_kv_heads


def attention_template(d_model: int, dims: AttnDims, qkv_bias: bool = False):
    h, kv, hd = dims.num_heads, dims.num_kv_heads, dims.head_dim
    t = {
        "wq": ParamSpec((d_model, h * hd), ("embed", "ff")),
        "wk": ParamSpec((d_model, kv * hd), ("embed", "ff")),
        "wv": ParamSpec((d_model, kv * hd), ("embed", "ff")),
        "wo": ParamSpec((h * hd, d_model), ("ff", "embed")),
    }
    if qkv_bias:
        t["bq"] = ParamSpec((h * hd,), ("ff",), init="zeros")
        t["bk"] = ParamSpec((kv * hd,), ("ff",), init="zeros")
        t["bv"] = ParamSpec((kv * hd,), ("ff",), init="zeros")
    return t


def _sdpa_chunked(q, k, v, *, causal: bool, q_offset, kv_len: Optional[jax.Array],
                  chunk: int = 1024, p_dtype=jnp.float32,
                  kv_start: Optional[jax.Array] = None) -> jax.Array:
    """Grouped scaled-dot-product attention, chunked over queries.

    q: (B, Sq, KV, G, hd);  k, v: (B, Skv, KV, hd)
    q_offset: scalar int — absolute position of q[0] (decode: cache length).
    kv_len: optional scalar — number of valid cache entries (<= Skv).
    kv_start: optional (B,) int32 — first valid cache column per row, for
      left-padded ragged batches (columns < kv_start[b] are pad and masked).
    """
    b, sq, kvh, g, hd = q.shape
    skv = k.shape[1]
    scale = hd ** -0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(p_dtype)
    col_ids = jnp.arange(skv)

    def one_chunk(q_c, row0):
        # q_c: (B, C, KV, G, hd)
        s = einsum("bqkgd,btkd->bqkgt", q_c.astype(jnp.float32) * scale, kf)
        mask = jnp.ones((q_c.shape[1], skv), jnp.bool_)
        if causal:
            rows = row0 + q_offset + jnp.arange(q_c.shape[1])
            mask &= col_ids[None, :] <= rows[:, None]
        if kv_len is not None:
            mask &= col_ids[None, :] < kv_len
        if kv_start is None:
            s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        else:  # per-row pad mask -> (B, C, Skv)
            maskb = mask[None] & (col_ids[None, None, :] >= kv_start[:, None, None])
            s = jnp.where(maskb[:, :, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(p_dtype)
        return einsum("bqkgt,btkd->bqkgd", p, vf).astype(q.dtype)

    if sq <= chunk:
        return one_chunk(q, 0)
    while sq % chunk:  # largest divisor <= chunk (e.g. whisper enc_len=1500)
        chunk -= 1
    n = sq // chunk
    qs = q.reshape(b, n, chunk, kvh, g, hd).swapaxes(0, 1)
    row0s = jnp.arange(n) * chunk
    out = jax.lax.map(lambda args: one_chunk(*args), (qs, row0s))
    return out.swapaxes(0, 1).reshape(b, sq, kvh, g, hd)


def kv_quantize(x: jax.Array):
    """Per-(token, head) symmetric int8 quantization of a (B,S,KV,hd) slab."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale[..., 0]


def kv_dequantize(q: jax.Array, scale: jax.Array, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# Attention-impl routing (chunked jnp vs Pallas flash kernel)
# ---------------------------------------------------------------------------

#: fallback reasons already logged this process (each is logged once)
_FLASH_FALLBACKS_LOGGED = set()


def _is_static_zero(x) -> bool:
    """True iff ``x`` is a compile-time-known zero (None counts).

    Traced values (tracers) raise on ``int()`` — broad except because the
    exact error type varies across JAX versions — and are treated as
    not-statically-zero.
    """
    if x is None:
        return True
    try:
        return int(x) == 0
    except Exception:
        return False


def flash_fallback_reason(*, causal: bool, seq_len: int,
                          cross_attention: bool,
                          cache_offset_static_zero: bool = True
                          ) -> Optional[str]:
    """Why a flash-requested attention call must use the chunked path.

    Returns ``None`` when the flash kernel applies.  The documented
    fallbacks (each logged once per process by :func:`attention`):

    * ``cross-attention`` — precomputed non-causal KV (``kv_override``);
      the flash kernel covers causal self-attention.
    * ``non-causal``      — e.g. encoder self-attention.
    * ``decode-step``     — single-query steps read the whole KV cache; the
      chunked path's cache-masked softmax is the decode kernel.
    * ``cached-continuation`` — multi-token step into a cache at an offset
      not statically known to be zero: it must attend the whole cache
      prefix, which the flash path (fresh prefill columns only) does not
      cover.

    Note what is *not* here: ``kv_cache is not None`` alone.  Prefill runs
    with a cache to fill (at offset 0), but attends over exactly the tokens
    it just projected — the flash kernel handles it (ragged rows included
    via ``kv_start``).  The old routing silently fell back whenever a cache
    was present, which excluded serving prefill entirely.
    """
    if cross_attention:
        return "cross-attention"
    if not causal:
        return "non-causal"
    if seq_len == 1:
        return "decode-step"
    if not cache_offset_static_zero:
        return "cached-continuation"
    return None


def _log_flash_fallback(reason: str) -> None:
    if reason not in _FLASH_FALLBACKS_LOGGED:
        _FLASH_FALLBACKS_LOGGED.add(reason)
        logger.info("flash attention requested but falling back to the "
                    "chunked path: %s (logged once)", reason)


def cross_kv(params, src: jax.Array, dims: AttnDims):
    """Project encoder/image embeddings to the (static) cross K/V once."""
    b = src.shape[0]
    k = matmul(src, params["wk"], bias=params.get("bk")).reshape(b, -1, dims.num_kv_heads, dims.head_dim)
    v = matmul(src, params["wv"], bias=params.get("bv")).reshape(b, -1, dims.num_kv_heads, dims.head_dim)
    return k, v


def attention(
    params,
    x: jax.Array,
    dims: AttnDims,
    *,
    positions: Optional[jax.Array] = None,
    rope_theta: float = 0.0,
    rope_fraction: float = 1.0,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_offset: Optional[jax.Array] = None,
    causal: bool = True,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
    q_chunk: int = 1024,
    p_dtype=jnp.float32,
    attn_impl: str = "chunked",
    kv_start: Optional[jax.Array] = None,
):
    """Returns (out, new_kv_cache_or_None).

    * self-attention: KV projected from ``x``; if ``kv_cache`` is given the
      new KV is written at ``cache_offset`` and attention runs on the cache.
    * cross-attention: pass precomputed ``kv_override`` (from ``cross_kv``);
      non-causal, cache untouched.
    * ragged batches: ``kv_start`` (B,) marks the first non-pad column per
      row (left padding); pad columns are excluded from every softmax.
    * ``attn_impl="flash"`` routes every eligible call — causal
      self-attention with more than one query, i.e. training forwards AND
      serving/scoring prefill (cache present, ragged rows included) —
      through the tuned Pallas flash kernel
      (:func:`repro.core.flash_attention`).  Ineligible calls fall back to
      the chunked path with the reason logged once
      (:func:`flash_fallback_reason`).
    """
    b, s, _ = x.shape
    h, kvh, hd = dims.num_heads, dims.num_kv_heads, dims.head_dim

    use_flash = False
    if attn_impl == "flash":
        reason = flash_fallback_reason(
            causal=causal, seq_len=s,
            cross_attention=kv_override is not None,
            cache_offset_static_zero=(kv_cache is None
                                      or _is_static_zero(cache_offset)))
        if reason is None:
            use_flash = True
        else:
            _log_flash_fallback(reason)

    q = matmul(x, params["wq"], bias=params.get("bq"))
    q = q.reshape(b, s, h, hd)

    if kv_override is not None:
        k, v = kv_override
        qg = q.reshape(b, s, kvh, dims.group, hd)
        out = _sdpa_chunked(qg, k, v, causal=False, q_offset=0,
                            kv_len=None, chunk=q_chunk, p_dtype=p_dtype)
        return matmul(out.reshape(b, s, h * hd), params["wo"]), None

    k = matmul(x, params["wk"], bias=params.get("bk")).reshape(b, s, kvh, hd)
    v = matmul(x, params["wv"], bias=params.get("bv")).reshape(b, s, kvh, hd)
    if rope_theta:
        q = apply_rope(q, positions, theta=rope_theta, fraction=rope_fraction)
        k = apply_rope(k, positions, theta=rope_theta, fraction=rope_fraction)

    new_cache = None
    kv_len = None
    q_offset = 0
    if kv_cache is not None:
        ck, cv = kv_cache
        if isinstance(ck, dict):   # int8-quantized cache: {"q": i8, "s": f32}
            kq, ks = kv_quantize(k)
            vq, vs = kv_quantize(v)
            ck = {"q": jax.lax.dynamic_update_slice(ck["q"], kq, (0, cache_offset, 0, 0)),
                  "s": jax.lax.dynamic_update_slice(ck["s"], ks, (0, cache_offset, 0))}
            cv = {"q": jax.lax.dynamic_update_slice(cv["q"], vq, (0, cache_offset, 0, 0)),
                  "s": jax.lax.dynamic_update_slice(cv["s"], vs, (0, cache_offset, 0))}
            k = kv_dequantize(ck["q"], ck["s"], k.dtype)
            v = kv_dequantize(cv["q"], cv["s"], v.dtype)
        else:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_offset, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_offset, 0, 0))
            k, v = ck, cv
        q_offset = cache_offset
        kv_len = cache_offset + s
        new_cache = (ck, cv)

    if use_flash:
        # Tuned Pallas flash kernel (training forward or prefill).  With a
        # cache present the routing above guarantees cache_offset is a
        # static 0 (prefill): attend over exactly the s freshly-written
        # columns — sliced from the cache so a quantized cache's
        # dequantization round-trip matches the chunked path bit-for-bit.
        # Ragged left-padded rows mask via kv_start.
        kf, vf = (k[:, :s], v[:, :s]) if kv_cache is not None else (k, v)
        from repro.core import flash_attention as tuned_flash
        out = tuned_flash(q, kf, vf, causal=causal, kv_start=kv_start)
        return matmul(out.reshape(b, s, h * hd), params["wo"]), new_cache

    qg = q.reshape(b, s, kvh, dims.group, hd)
    out = _sdpa_chunked(qg, k, v, causal=causal, q_offset=q_offset,
                        kv_len=kv_len, chunk=q_chunk, p_dtype=p_dtype,
                        kv_start=kv_start)
    out = out.reshape(b, s, h * hd)
    return matmul(out, params["wo"]), new_cache


# ---------------------------------------------------------------------------
# Gated MLP (llama-style SwiGLU) — fused activation epilogues via the kernel
# ---------------------------------------------------------------------------

def mlp_template(d_model: int, d_ff: int):
    return {
        "w_gate": ParamSpec((d_model, d_ff), ("embed", "ff")),
        "w_up": ParamSpec((d_model, d_ff), ("embed", "ff")),
        "w_down": ParamSpec((d_ff, d_model), ("ff", "embed")),
    }


def mlp(params, x: jax.Array) -> jax.Array:
    gate = matmul(x, params["w_gate"], activation="silu")
    up = matmul(x, params["w_up"])
    return matmul(gate * up, params["w_down"])


def mlp_gelu_template(d_model: int, d_ff: int):
    """Whisper-style 2-matrix GELU MLP (with biases)."""
    return {
        "w_up": ParamSpec((d_model, d_ff), ("embed", "ff")),
        "b_up": ParamSpec((d_ff,), ("ff",), init="zeros"),
        "w_down": ParamSpec((d_ff, d_model), ("ff", "embed")),
        "b_down": ParamSpec((d_model,), ("embed",), init="zeros"),
    }


def mlp_gelu(params, x: jax.Array) -> jax.Array:
    h = matmul(x, params["w_up"], bias=params["b_up"], activation="gelu")
    return matmul(h, params["w_down"], bias=params["b_down"])
