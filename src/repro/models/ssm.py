"""Mamba2 (SSD — state-space duality) block, chunked matmul formulation.

The SSD block decomposition (arXiv:2405.21060) recasts the selective-SSM
recurrence as *block matrix multiplications* — intra-chunk dense GEMMs plus
a tiny inter-chunk recurrence — which is exactly the regime the paper's
tunable-GEMM thesis targets (DESIGN.md §4): the hot ops here are the chunked
contractions, lowered through core.einsum / XLA dot and MXU-friendly.

Convention (h = state, per head):
    h_s = exp(dt_s * A) * h_{s-1} + dt_s * B_s * x_s ;   y_l = C_l . h_l + D x_l
n_groups = 1 (B, C shared across heads), as in the released Mamba2 models.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import einsum, matmul
from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec


def ssm_dims(cfg: ModelConfig):
    d_inner = cfg.d_inner
    n_heads = cfg.ssm_heads
    conv_dim = d_inner + 2 * cfg.ssm_state
    d_in_proj = 2 * d_inner + 2 * cfg.ssm_state + n_heads
    return d_inner, n_heads, conv_dim, d_in_proj


def ssm_template(cfg: ModelConfig):
    d_inner, n_heads, conv_dim, d_in_proj = ssm_dims(cfg)
    return {
        "in_proj": ParamSpec((cfg.d_model, d_in_proj), ("embed", "ff")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_dim), (None, "ff"), scale=0.5),
        "conv_b": ParamSpec((conv_dim,), ("ff",), init="zeros"),
        "A_log": ParamSpec((n_heads,), (None,), init="zeros"),
        "D": ParamSpec((n_heads,), (None,), init="ones"),
        "dt_bias": ParamSpec((n_heads,), (None,), init="zeros"),
        "norm": ParamSpec((d_inner,), ("ff",), init="ones"),
        "out_proj": ParamSpec((d_inner, cfg.d_model), ("ff", "embed")),
    }


def _split_zxbcdt(cfg: ModelConfig, zxbcdt):
    d_inner, n_heads, _, _ = ssm_dims(cfg)
    n = cfg.ssm_state
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * n]
    dt = zxbcdt[..., 2 * d_inner + 2 * n:]
    return z, xBC, dt


def _gated_norm(scale, y, z, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = (yf * yf).mean(-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def _causal_conv(params, xBC, cfg: ModelConfig):
    """Depthwise causal conv over the sequence: xBC (B, S, C)."""
    k = cfg.ssm_conv
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * params["conv_w"][i]
              for i in range(k))
    return jax.nn.silu(out + params["conv_b"])


def ssm_block(params, x: jax.Array, cfg: ModelConfig,
              return_state: bool = False, valid_mask=None):
    """Full-sequence SSD forward.  x: (B, S, D) with S % ssm_chunk == 0.

    ``return_state=True`` additionally returns the recurrent state after the
    last position — {"conv", "ssm"} — so prefill can hand off to the
    single-token decode path exactly.

    ``valid_mask`` (B, S) bool marks real tokens in a left-padded ragged
    batch.  Pad columns are zeroed both pre-conv (so early real tokens see
    the same zero conv left-context a lone prompt would) and post-conv (so
    pad positions contribute nothing to the recurrent state — every decay
    span between real tokens covers only real tokens, making the state
    entering the first real token exactly the zero init).
    """
    b, s, _ = x.shape
    d_inner, n_heads, _, _ = ssm_dims(cfg)
    p = cfg.ssm_head_dim
    n = cfg.ssm_state
    # Chunk length: the largest divisor of S not exceeding ssm_chunk, so any
    # sequence length is exact (production shapes are powers of two and use
    # the configured chunk; odd test lengths degrade gracefully).
    l = min(cfg.ssm_chunk, s)
    while s % l:
        l -= 1
    nc = s // l

    z, xBC, dt = _split_zxbcdt(cfg, matmul(x, params["in_proj"]))
    if valid_mask is not None:
        xBC = jnp.where(valid_mask[..., None], xBC, 0)
    xBC_pre = xBC
    xBC = _causal_conv(params, xBC, cfg)
    xs, bs, cs = xBC[..., :d_inner], xBC[..., d_inner:d_inner + n], xBC[..., d_inner + n:]
    if valid_mask is not None:
        xs = jnp.where(valid_mask[..., None], xs, 0)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])      # (B,S,H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))                     # (H,)

    xc = xs.reshape(b, nc, l, n_heads, p).astype(jnp.float32)
    bc = bs.reshape(b, nc, l, n).astype(jnp.float32)
    cc = cs.reshape(b, nc, l, n).astype(jnp.float32)
    dtc = dt.reshape(b, nc, l, n_heads)

    da = dtc * a                                                          # (B,nc,L,H)
    cum = jnp.cumsum(da, axis=2)

    # --- intra-chunk (dense GEMM part of SSD) --------------------------
    cb = einsum("bcln,bcsn->bcls", cc, bc)                                # (B,nc,L,L)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]                   # (B,nc,L,S,H)
    causal = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = cb[..., None] * decay * dtc[:, :, None, :, :]                # (B,nc,L,S,H)
    y_diag = einsum("bclsh,bcshp->bclhp", scores, xc)

    # --- chunk boundary states -----------------------------------------
    state_decay = jnp.exp(cum[:, :, -1:, :] - cum)                        # (B,nc,L,H)
    states = einsum("bcln,bclh,bclhp->bchnp", bc, dtc * state_decay, xc)  # (B,nc,H,N,P)

    # --- inter-chunk recurrence (associative scan over chunks) ---------
    chunk_decay = jnp.exp(cum[:, :, -1, :])                               # (B,nc,H)

    def combine(left, right):
        d1, s1 = left
        d2, s2 = right
        return d1 * d2, s1 * d2[..., None, None] + s2

    _, inc = jax.lax.associative_scan(combine, (chunk_decay, states), axis=1)
    prev = jnp.concatenate(
        [jnp.zeros_like(inc[:, :1]), inc[:, :-1]], axis=1)                # states entering chunk c

    y_off = einsum("bcln,bchnp,bclh->bclhp", cc, prev, jnp.exp(cum))
    y = (y_diag + y_off).reshape(b, s, n_heads, p)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xs.reshape(b, s, n_heads, p).astype(jnp.float32)

    y = _gated_norm(params["norm"], y.reshape(b, s, d_inner).astype(x.dtype), z, cfg.norm_eps)
    out = matmul(y, params["out_proj"])
    if not return_state:
        return out
    final_state = {
        "conv": xBC_pre[:, s - (cfg.ssm_conv - 1):, :],   # last K-1 pre-conv inputs
        "ssm": inc[:, -1],                                 # state after position S
    }
    return out, final_state


def ssm_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner, n_heads, conv_dim, _ = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, n_heads, cfg.ssm_state, cfg.ssm_head_dim),
                         jnp.float32),
    }


def ssm_decode_step(params, x: jax.Array, state, cfg: ModelConfig):
    """Single-token recurrent step.  x: (B, 1, D) -> (y (B,1,D), new state)."""
    b = x.shape[0]
    d_inner, n_heads, conv_dim, _ = ssm_dims(cfg)
    p, n = cfg.ssm_head_dim, cfg.ssm_state

    z, xBC, dt = _split_zxbcdt(cfg, matmul(x[:, 0], params["in_proj"]))
    window = jnp.concatenate([state["conv"], xBC[:, None, :]], axis=1)    # (B,K,C)
    conv_out = jax.nn.silu((window * params["conv_w"][None]).sum(1) + params["conv_b"])
    new_conv = window[:, 1:]

    xs, bs, cs = conv_out[..., :d_inner], conv_out[..., d_inner:d_inner + n], conv_out[..., d_inner + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])      # (B,H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)                                                  # (B,H)

    xh = xs.reshape(b, n_heads, p).astype(jnp.float32)
    new_ssm = state["ssm"] * da[..., None, None] + einsum(
        "bn,bh,bhp->bhnp", bs.astype(jnp.float32), dt, xh)
    y = einsum("bn,bhnp->bhp", cs.astype(jnp.float32), new_ssm)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh

    y = _gated_norm(params["norm"], y.reshape(b, d_inner).astype(x.dtype), z, cfg.norm_eps)
    out = matmul(y, params["out_proj"])[:, None, :]
    return out, {"conv": new_conv, "ssm": new_ssm}
