"""Pure-SSM (Mamba2) and hybrid (Zamba2-style) language models.

Zamba2 topology: units of ``attn_period`` Mamba2 blocks, with ONE
shared-weight attention block applied at the start of every unit (weights
shared across applications, distinct KV per application — so the decode
cache carries a leading 'unit' axis).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.params import ParamSpec


# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------

def mamba_lm_template(cfg: ModelConfig):
    return {
        "embedding": ParamSpec((cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"), scale=0.02),
        "blocks": T._stack_template(_mamba_block_template(cfg), cfg.num_layers),
        "ln_f": L.norm_template(cfg.d_model, cfg.norm),
    } | ({} if cfg.tie_embeddings else
         {"lm_head": ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))})


def _mamba_block_template(cfg: ModelConfig):
    return {"ln": L.norm_template(cfg.d_model, cfg.norm),
            "ssm": S.ssm_template(cfg)}


def zamba_template(cfg: ModelConfig):
    units = cfg.num_layers // cfg.attn_period
    return {
        "embedding": ParamSpec((cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"), scale=0.02),
        "shared_attn": {   # ONE copy — applied at every unit boundary
            "ln1": L.norm_template(cfg.d_model, cfg.norm),
            "attn": L.attention_template(cfg.d_model, T.attn_dims(cfg)),
            "ln2": L.norm_template(cfg.d_model, cfg.norm),
            "mlp": L.mlp_template(cfg.d_model, cfg.d_ff),
        },
        "units": T._stack_template(
            T._stack_template(_mamba_block_template(cfg), cfg.attn_period),
            units),
        "ln_f": L.norm_template(cfg.d_model, cfg.norm),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }


def template(cfg: ModelConfig):
    return zamba_template(cfg) if cfg.family == "hybrid" else mamba_lm_template(cfg)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _mamba_block(cfg, bp, x):
    x = x + S.ssm_block(bp["ssm"], L.apply_norm(bp["ln"], x, eps=cfg.norm_eps), cfg)
    return constrain(x, "hidden")


def _mamba_block_prefill(cfg, bp, x, valid=None):
    y, state = S.ssm_block(bp["ssm"], L.apply_norm(bp["ln"], x, eps=cfg.norm_eps),
                           cfg, return_state=True, valid_mask=valid)
    return constrain(x + y, "hidden"), state


def _mamba_block_step(cfg, bp, x, state):
    y, new_state = S.ssm_decode_step(
        bp["ssm"], L.apply_norm(bp["ln"], x, eps=cfg.norm_eps), state, cfg)
    return x + y, new_state


def _shared_attn_apply(cfg, sp, x, positions, kv_cache=None, cache_offset=None,
                       kv_start=None):
    h, new_cache = L.attention(
        sp["attn"], L.apply_norm(sp["ln1"], x, eps=cfg.norm_eps),
        T.attn_dims(cfg), positions=positions,
        rope_theta=cfg.rope_theta if cfg.use_rope else 0.0,
        kv_cache=kv_cache, cache_offset=cache_offset,
        p_dtype=jnp.dtype(cfg.attn_p_dtype),
        attn_impl=cfg.attention_impl, kv_start=kv_start)
    x = x + h
    x = x + L.mlp(sp["mlp"], L.apply_norm(sp["ln2"], x, eps=cfg.norm_eps))
    return constrain(x, "hidden"), new_cache


# ---------------------------------------------------------------------------
# Forward (train / scoring)
# ---------------------------------------------------------------------------

def forward_hidden(cfg: ModelConfig, params, batch: Dict[str, jax.Array]):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = T._embed(cfg, params, tokens)

    if cfg.family == "ssm":
        def body(x, bp):
            return _mamba_block(cfg, bp, x), None
        x, _ = jax.lax.scan(T._maybe_remat(cfg, body), x, params["blocks"])
    else:
        pos = T._positions(b, s)

        def unit_body(x, unit_params):
            x, _ = _shared_attn_apply(cfg, params["shared_attn"], x, pos)

            def inner(xx, bp):
                return _mamba_block(cfg, bp, xx), None
            x, _ = jax.lax.scan(inner, x, unit_params)
            return x, None

        x, _ = jax.lax.scan(T._maybe_remat(cfg, unit_body), x, params["units"])
    return x, jnp.float32(0.0)


def forward(cfg: ModelConfig, params, batch: Dict[str, jax.Array]):
    x, aux = forward_hidden(cfg, params, batch)
    return T._unembed(cfg, params, x), aux


# ---------------------------------------------------------------------------
# Decode (recurrent states; hybrid adds shared-attn KV per unit)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = jnp.dtype(dtype or cfg.dtype)
    if cfg.family == "ssm":
        states = S.ssm_state_init(cfg, batch, dtype)
        return {"ssm": jax.tree_util.tree_map(
            lambda z: jnp.broadcast_to(z, (cfg.num_layers,) + z.shape).copy(), states)}
    units = cfg.num_layers // cfg.attn_period
    states = S.ssm_state_init(cfg, batch, dtype)
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "ssm": jax.tree_util.tree_map(
            lambda z: jnp.broadcast_to(z, (units, cfg.attn_period) + z.shape).copy(),
            states),
        "self": (jnp.zeros((units, batch, max_len, kvh, hd), dtype),
                 jnp.zeros((units, batch, max_len, kvh, hd), dtype)),
    }


def prefill(cfg: ModelConfig, params, batch, cache):
    tokens = batch["tokens"]
    b, s = tokens.shape
    kv_start = batch.get("kv_start")
    x = T._embed(cfg, params, tokens)
    pos = (T._positions(b, s) if kv_start is None
           else T._ragged_positions(s, kv_start))
    # Ragged batches: left-pad columns must not perturb the recurrent state.
    # SSD contributions are linear in the (post-conv) inputs, so zeroing the
    # pad columns inside the SSM block makes the state entering the first
    # real token exactly the zero init — see ssm_block(valid_mask=...).
    valid = None if kv_start is None else (
        jnp.arange(s, dtype=jnp.int32)[None, :] >= kv_start[:, None])
    offset = jnp.int32(0)

    if cfg.family == "ssm":
        # Full-sequence SSD pass; the chunked kernel also yields the exact
        # recurrent state after the last position for decode hand-off.
        def body(x, bp):
            x, state = _mamba_block_prefill(cfg, bp, x, valid=valid)
            return x, state
        x, new_states = jax.lax.scan(body, x, params["blocks"])
        logits = T._unembed(cfg, params, x[:, -1:, :])[:, 0]
        return logits, {"ssm": jax.tree_util.tree_map(
            lambda old, new: new.astype(old.dtype), cache["ssm"], new_states)}

    def unit_body(carry, xs):
        x = carry
        unit_params, (ck, cv) = xs
        x, new_kv = _shared_attn_apply(cfg, params["shared_attn"], x, pos,
                                       kv_cache=(ck, cv), cache_offset=offset,
                                       kv_start=kv_start)

        def inner(xx, bp):
            return _mamba_block_prefill(cfg, bp, xx, valid=valid)
        x, states = jax.lax.scan(inner, x, unit_params)
        return x, (states, new_kv)

    x, (new_states, new_self) = jax.lax.scan(
        T._maybe_remat(cfg, unit_body), x, (params["units"], cache["self"]))
    logits = T._unembed(cfg, params, x[:, -1:, :])[:, 0]
    new_states = jax.tree_util.tree_map(
        lambda old, new: new.astype(old.dtype), cache["ssm"], new_states)
    return logits, {"ssm": new_states, "self": new_self}


def decode_step(cfg: ModelConfig, params, tokens, cache, offset, kv_start=None):
    b = tokens.shape[0]
    x = T._embed(cfg, params, tokens)
    if kv_start is None:
        pos = jnp.broadcast_to(offset.astype(jnp.int32), (b, 1))
    else:
        pos = jnp.maximum(offset.astype(jnp.int32) - kv_start, 0)[:, None]

    if cfg.family == "ssm":
        def body(x, xs):
            bp, state = xs
            x, new_state = _mamba_block_step(cfg, bp, x, state)
            return x, new_state
        x, new_states = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]))
        logits = T._unembed(cfg, params, x)[:, 0]
        return logits, {"ssm": new_states}

    def unit_body(carry, xs):
        x = carry
        unit_params, states, (ck, cv) = xs
        x, new_kv = _shared_attn_apply(cfg, params["shared_attn"], x, pos,
                                       kv_cache=(ck, cv), cache_offset=offset,
                                       kv_start=kv_start)

        def inner(xx, ys):
            bp, st = ys
            xx, new_st = _mamba_block_step(cfg, bp, xx, st)
            return xx, new_st
        x, new_states = jax.lax.scan(inner, x, (unit_params, states))
        return x, (new_states, new_kv)

    x, (new_states, new_self) = jax.lax.scan(
        unit_body, x, (params["units"], cache["ssm"], cache["self"]))
    logits = T._unembed(cfg, params, x)[:, 0]
    return logits, {"ssm": new_states, "self": new_self}
