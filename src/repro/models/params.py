"""Parameter templates: single source of truth for shapes, shardings, init.

Every model module builds a pytree of ``ParamSpec`` (shape + logical axes +
init rule).  From that one template we derive
  * randomly initialized parameters        (``init_params``)
  * ``jax.ShapeDtypeStruct`` stand-ins     (``abstract_params`` — dry-run)
  * ``PartitionSpec`` sharding pytrees     (``distributed.sharding``)
so shapes/shardings can never drift apart.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis name per dim (or None)
    init: str = "normal"                 # normal | zeros | ones
    scale: Optional[float] = None        # stddev; None -> 1/sqrt(fan_in)
    dtype: Optional[str] = None          # override model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _leaf_key(key: jax.Array, path: str) -> jax.Array:
    digest = int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "big")
    return jax.random.fold_in(key, digest)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def init_params(template, key: jax.Array, default_dtype: str = "float32",
                shardings=None):
    """Materialize random parameters from a template pytree.

    ``shardings`` (a pytree of ``NamedSharding`` aligned with the template,
    e.g. from ``distributed.sharding.param_shardings``) places every leaf on
    its mesh shards — values are bit-identical to the unsharded init, only
    the layout differs, which is what keeps 1-device vs N-device runs
    token-for-token comparable.
    """
    def init_leaf(path, spec: ParamSpec):
        dtype = jnp.dtype(spec.dtype or default_dtype)
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
        scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
        k = _leaf_key(key, _path_str(path))
        return (scale * jax.random.normal(k, spec.shape, jnp.float32)).astype(dtype)

    params = jax.tree_util.tree_map_with_path(init_leaf, template,
                                              is_leaf=is_spec)
    if shardings is not None:
        params = jax.device_put(params, shardings)
    return params


def abstract_params(template, default_dtype: str = "float32"):
    """ShapeDtypeStruct pytree (no allocation) — dry-run input."""
    def leaf(spec: ParamSpec):
        return jax.ShapeDtypeStruct(spec.shape, jnp.dtype(spec.dtype or default_dtype))
    return jax.tree_util.tree_map(leaf, template, is_leaf=is_spec)


def param_count(template) -> int:
    leaves = jax.tree_util.tree_leaves(template, is_leaf=is_spec)
    return sum(math.prod(l.shape) for l in leaves)


# ---------------------------------------------------------------------------
# Logical-axis vocabulary used across the model zoo (consumed by
# distributed/sharding.py):
#   "vocab"   embedding / logits vocabulary dim  -> tensor-parallel
#   "embed"   residual-stream d_model dim        -> FSDP ("data") when enabled
#   "ff"      hidden dims that want TP (ffn hidden, q/kv head dim products)
#   "expert"  MoE expert dim                     -> expert-parallel
#   "layer"   stacked-layer leading dim          -> never sharded
#   None      replicated
# ---------------------------------------------------------------------------
