"""Int8 gradient compression with error feedback (beyond-paper distributed
optimization trick for slow inter-pod links).

Applied to the DP gradient reduction path: quantize each leaf to int8 with a
per-leaf f32 scale before the cross-pod all-reduce, dequantize after, and
carry the quantization residual forward into the next step's gradient
(error feedback keeps the scheme unbiased in the long run — Seide et al.,
Karimireddy et al. 2019).

Under pjit the all-reduce itself is inserted by XLA; compressing the tensor
the reduction runs over shrinks the collective's operand bytes 4x (f32->i8),
directly attacking the collective roofline term measured in §Roofline.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: Any     # per-leaf error-feedback carry (f32)


def init_state(params) -> CompressionState:
    return CompressionState(residual=jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def abstract_state(abstract_params) -> CompressionState:
    return CompressionState(residual=jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params))


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads, state: CompressionState):
    """-> (dequantized grads to feed the optimizer, new state).

    The int8 tensor is what crosses the network; the residual (quantization
    error) stays local and is added to the next step's gradient.
    """
    def leaf(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = _quantize(gf)
        deq = q.astype(jnp.float32) * scale
        return deq, gf - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual)
    out = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    deq = treedef.unflatten([o[0] for o in out])
    res = treedef.unflatten([o[1] for o in out])
    return deq, CompressionState(residual=res)
