"""AdamW with f32 master weights, built as a pytree-functional optimizer.

State layout (per parameter leaf):
  m, v     — f32 moments
  master   — f32 master copy IF the param dtype is lower precision (bf16);
             otherwise the param itself is the master (no copy stored).

All state leaves inherit the parameter's PartitionSpec, so FSDP sharding of
the optimizer state (ZeRO-style) falls out of the param sharding rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    m: Any
    v: Any
    master: Any      # f32 masters (same tree; equals params when f32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0

    def _lr(self, count):
        lr = self.learning_rate
        return lr(count) if callable(lr) else jnp.float32(lr)

    def init(self, params) -> AdamWState:
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        master = jax.tree_util.tree_map(
            lambda p: jnp.copy(p.astype(jnp.float32)), params)  # never alias params
        return AdamWState(count=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree_util.tree_map(jnp.copy, zeros),
                          master=master)

    def abstract_state(self, abstract_params) -> AdamWState:
        f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return AdamWState(
            count=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree_util.tree_map(f32, abstract_params),
            v=jax.tree_util.tree_map(f32, abstract_params),
            master=jax.tree_util.tree_map(f32, abstract_params))

    def update(self, grads, state: AdamWState, params):
        """-> (new_params, new_state, metrics)."""
        count = state.count + 1
        gf = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)

        gnorm = global_norm(gf)
        if self.grad_clip_norm is not None:
            scale = jnp.minimum(1.0, self.grad_clip_norm / (gnorm + 1e-9))
            gf = jax.tree_util.tree_map(lambda g: g * scale, gf)

        b1, b2 = self.b1, self.b2
        m = jax.tree_util.tree_map(lambda mm, g: b1 * mm + (1 - b1) * g,
                                   state.m, gf)
        v = jax.tree_util.tree_map(lambda vv, g: b2 * vv + (1 - b2) * g * g,
                                   state.v, gf)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        lr = self._lr(count)

        def upd(master, mm, vv):
            step = (mm / c1) / (jnp.sqrt(vv / c2) + self.eps)
            if self.weight_decay and master.ndim >= 2:  # no decay on norms/bias
                step = step + self.weight_decay * master
            return master - lr * step

        master = jax.tree_util.tree_map(upd, state.master, m, v)
        new_params = jax.tree_util.tree_map(
            lambda ms, p: ms.astype(p.dtype), master, params)
        return new_params, AdamWState(count, m, v, master), {
            "grad_norm": gnorm, "lr": lr}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
