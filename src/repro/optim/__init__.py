from repro.optim.adamw import AdamW, AdamWState, global_norm  # noqa: F401
from repro.optim.schedules import constant, warmup_cosine  # noqa: F401
