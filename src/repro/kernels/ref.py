"""Pure-jnp oracles for every kernel in this package.

These are the ground truth the Pallas kernels are validated against
(``tests/test_gemm_kernel.py`` sweeps shapes/dtypes and asserts allclose).
They intentionally share the *semantics* of the paper's GEMM (Eq. 1):

    C = alpha * A @ B + beta * C      (+ optional bias / activation epilogue)

accumulating in float32 regardless of input dtype, mirroring MXU behaviour.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_ACTIVATIONS = {
    None: lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}


def apply_epilogue(out_f32, bias=None, activation: Optional[str] = None):
    if bias is not None:
        out_f32 = out_f32 + bias.astype(jnp.float32)
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    return _ACTIVATIONS[activation](out_f32)


def gemm_ref(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    alpha: float = 1.0,
    beta: float = 0.0,
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    """Reference GEMM: ``alpha * A @ B + beta * C`` with f32 accumulation."""
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"gemm_ref expects 2-D operands, got {a.shape} @ {b.shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    acc = alpha * acc
    if c is not None:
        acc = acc + beta * c.astype(jnp.float32)
    acc = apply_epilogue(acc, bias=bias, activation=activation)
    return acc.astype(out_dtype)


def batched_gemm_ref(a, b, **kw):
    """Oracle for the batched wrapper: contracts the last dim of ``a`` with
    the second-to-last of ``b`` over shared leading batch dims."""
    fn = lambda x, y: gemm_ref(x, y, **kw)
    for _ in range(a.ndim - 2):
        fn = jax.vmap(fn)
    return fn(a, b)


def gemm_flops(m: int, k: int, n: int, with_beta: bool = False) -> int:
    """Paper Eq. 2 generalized to rectangular operands: 2MKN (+ epilogue)."""
    flops = 2 * m * k * n
    if with_beta:
        flops += 3 * m * n  # alpha scale + beta scale + add, as in 3N^2
    return flops


def attention_ref(q, k, v, *, causal: bool = True, scale=None) -> jax.Array:
    """Naive softmax attention oracle.  q: (B, S, H, d); k, v: (B, T, KV, d)."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    if kvh != h:
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = d ** -0.5 if scale is None else scale
    s = jnp.einsum("bqhd,bthd->bhqt", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqt,bthd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
