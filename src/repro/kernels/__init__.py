"""Pallas TPU kernels for the perf-critical hot spots (the paper's GEMM)."""
from repro.kernels.ops import (  # noqa: F401
    BACKEND_PALLAS_INTERPRET, BACKEND_PALLAS_TPU, BACKEND_REF, BACKEND_XLA,
    BACKENDS, batched_gemm, gemm,
)
from repro.kernels.paged import (  # noqa: F401
    flatten_pool, paged_gather, paged_scatter,
)
