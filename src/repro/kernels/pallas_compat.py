"""Pallas API compatibility across jax versions.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
kernels import the resolved name from here so the single-source code runs on
both old and new toolchains.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
