"""Jit'd wrappers around the single-source Pallas GEMM.

Responsibilities kept OUT of the kernel (so the kernel stays single-source):
  * padding arbitrary operand shapes up to block multiples,
  * backend execution choice (pallas-tpu / pallas-interpret / xla / ref),
  * batching over leading dims.

This is the layer where Alpaka's "back end" concept lives: the same logical
GEMM runs through whichever execution engine the registry selects — exactly
like the paper compiling one source with nvcc / icc / gcc / xlc.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.gemm import gemm_pallas

# Execution backends (paper Tab. 3 analogue).
BACKEND_PALLAS_TPU = "pallas-tpu"          # target hardware path
BACKEND_PALLAS_INTERPRET = "pallas-interpret"  # CPU validation of the kernel
BACKEND_XLA = "xla"                         # vendor-library analogue (cuBLAS/MKL)
BACKEND_REF = "ref"                         # pure-jnp oracle
BACKENDS = (BACKEND_PALLAS_TPU, BACKEND_PALLAS_INTERPRET, BACKEND_XLA, BACKEND_REF)


def _pad_to(x: jax.Array, multiples) -> jax.Array:
    pads = []
    needs = False
    for dim, mult in zip(x.shape, multiples):
        pad = (-dim) % mult
        pads.append((0, pad))
        needs = needs or pad
    return jnp.pad(x, pads) if needs else x


def gemm(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    config=None,            # core.tile_config.TileConfig | None
    backend: str = BACKEND_XLA,
    alpha: float = 1.0,
    beta: float = 0.0,
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    out_dtype=None,
    bf16_partials: bool = False,
) -> jax.Array:
    """2-D GEMM with automatic padding to the tile grid.

    ``config`` carries the architecture-tuned block sizes; it is required for
    the pallas backends and ignored by xla/ref (which have no exposed tiles —
    the "vendor library" case of the paper).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if backend == BACKEND_REF:
        return _ref.gemm_ref(a, b, c, alpha=alpha, beta=beta, bias=bias,
                             activation=activation, out_dtype=out_dtype)
    if backend == BACKEND_XLA:
        return _xla_gemm(a, b, c, alpha=alpha, beta=beta, bias=bias,
                         activation=activation, out_dtype=out_dtype,
                         bf16_partials=bf16_partials)

    if config is None:
        raise ValueError("pallas backends need a TileConfig (use core.registry)")
    m, k = a.shape
    _, n = b.shape
    bm, bk, bn = config.bm, config.bk, config.bn
    a_p = _pad_to(a, (bm, bk))
    b_p = _pad_to(b, (bk, bn))
    c_p = _pad_to(c, (bm, bn)) if c is not None else None
    bias_p = _pad_to(bias, (bn,)) if bias is not None else None
    out = gemm_pallas(
        a_p, b_p, c_p,
        bm=bm, bk=bk, bn=bn,
        alpha=alpha, beta=beta, bias=bias_p, activation=activation,
        out_dtype=out_dtype,
        interpret=(backend == BACKEND_PALLAS_INTERPRET),
    )
    if out.shape != (m, n):
        out = out[:m, :n]
    return out


def _xla_gemm(a, b, c=None, *, alpha, beta, bias, activation, out_dtype,
              bf16_partials=False):
    """XLA dot path — same semantics, tiling delegated to the XLA compiler.

    This is the baseline the paper calls "vendor library": no exposed tuning
    parameters.  Still forces f32 MXU accumulation for parity (per shard;
    see ExecutionContext.bf16_partials for the cross-shard reduction dtype).
    """
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    pref = jnp.float32
    if bf16_partials and a.dtype.itemsize <= 2 and b.dtype.itemsize <= 2 \
            and bias is None and activation is None and c is None:
        pref = jnp.bfloat16
    acc = jnp.dot(a, b, preferred_element_type=pref)
    if alpha != 1.0:
        acc = alpha * acc
    if c is not None:
        acc = acc + beta * c.astype(jnp.float32)
    acc = _ref.apply_epilogue(acc, bias=bias, activation=activation)
    return acc.astype(out_dtype)


def batched_gemm(a: jax.Array, b: jax.Array, **kw) -> jax.Array:
    """GEMM over shared leading batch dims via vmap of the single source."""
    if a.ndim != b.ndim:
        raise ValueError(f"rank mismatch {a.shape} vs {b.shape}")
    fn = functools.partial(gemm, **kw)
    for _ in range(a.ndim - 2):
        fn = jax.vmap(fn)
    return fn(a, b)


jit_gemm = jax.jit(gemm, static_argnames=(
    "config", "backend", "alpha", "beta", "activation", "out_dtype"))
