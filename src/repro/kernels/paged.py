"""Paged KV-cache gather/scatter — the data-movement op behind paged
attention (op = ``paged_attn`` in the tuning DB).

The paged pool stores each "self"-attention KV leaf with its batch and
sequence dims collapsed into one flat token axis of ``num_pages *
page_size`` entries; a request's logically-contiguous KV lives wherever its
block table says.  The serve engine's fused decode chunk then needs exactly
two data movements per chunk:

* :func:`paged_gather` — materialize a dense, right-aligned ``(B, W)`` view
  of every live row's KV from the flat pool (the attention kernels consume
  the view unchanged, which is what keeps the model source single-source:
  the paged layout is invisible above this op);
* :func:`paged_scatter` — write the chunk's freshly-decoded KV columns back
  to their block-table homes.

Both are one XLA gather/scatter on the flat token axis — index arrays come
precomputed from the host block tables (``repro.serve.kv_pages``), so the
jitted chunk never sees a page table, only flat ``int32`` indices.  The
tuned ``page_size`` is a pure *layout* parameter: it shapes the index
streams and the pool's memory granularity without changing this op's code —
the paper's thesis (tuning knobs outside the kernel) applied to memory
layout rather than a compute tile.

Out-of-range behavior is load-bearing: gathers of the NULL page read zeros
(masked by attention), scatters aimed at slot indices ``>= B`` are dropped
by JAX's default out-of-bounds scatter mode (used for admission's dummy
rows), and TRASH-page writes may collide freely because nothing reads them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flatten_pool(leaf: jnp.ndarray) -> jnp.ndarray:
    """Collapse a pool leaf's (num_pages, page_size) dims into the flat
    token axis the gather/scatter ops index: (..., P, S, kvh, hd) ->
    (..., P*S, kvh, hd)."""
    shape = leaf.shape
    return leaf.reshape(shape[:-4] + (shape[-4] * shape[-3],) + shape[-2:])


def paged_gather(pool_flat: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather a dense KV view from the flat pool.

    Args:
      pool_flat: ``(..., num_pages * page_size, kvh, hd)`` pool leaf.
      idx: ``(B, W)`` int32 flat token indices (0 = the NULL page's zeros).

    Returns:
      ``(..., B, W, kvh, hd)`` dense view, batch dim at axis -4 — the same
      layout ``model.init_cache`` gives a contiguous cache leaf.
    """
    # take on the token axis: (..., B*W, kvh, hd) -> split back to (B, W)
    flat = jnp.take(pool_flat, idx.reshape(-1), axis=-3)
    lead = pool_flat.shape[:-3]
    return flat.reshape(lead + idx.shape + pool_flat.shape[-2:])


def paged_copy(pool_flat: jnp.ndarray, src_page, dst_page,
               page_size: int) -> jnp.ndarray:
    """Copy one page's token rows to another page (prefix-cache COW).

    A full-prompt prefix hit shares its full pages read-only but must own
    the page that straddles the divergence point — subsequent decode writes
    land there.  This copies the cached page's ``page_size`` token rows into
    the hit row's freshly-allocated page.  ``src_page`` / ``dst_page`` are
    traced int32 scalars (page ids vary per hit; the copy compiles once),
    ``page_size`` is static layout.

    Bit-exactness note: this is a pure memcpy on the token axis — the copied
    KV is bit-identical to what prefill scattered into the source page, so
    the shared-prefix read path stays bit-identical to the cold path.
    """
    src = jax.lax.dynamic_slice_in_dim(
        pool_flat, src_page * page_size, page_size, axis=pool_flat.ndim - 3)
    start = [0] * pool_flat.ndim
    start[pool_flat.ndim - 3] = dst_page * page_size
    return jax.lax.dynamic_update_slice(pool_flat, src, tuple(start))


def paged_scatter(pool_flat: jnp.ndarray, idx: jnp.ndarray,
                  cols: jnp.ndarray) -> jnp.ndarray:
    """Scatter freshly-decoded KV columns back into the flat pool.

    Args:
      pool_flat: ``(..., num_pages * page_size, kvh, hd)`` pool leaf.
      idx: ``(B, chunk)`` int32 flat token indices (TRASH-page indices for
        writes with no allocated home).
      cols: ``(..., B, chunk, kvh, hd)`` new KV columns (the view's last
        ``chunk`` columns after the fused loop ran).

    Returns:
      The updated pool leaf.
    """
    lead = pool_flat.shape[:-3]
    flat_cols = cols.reshape(lead + (-1,) + cols.shape[-2:])
    return pool_flat.at[..., idx.reshape(-1), :, :].set(flat_cols)
