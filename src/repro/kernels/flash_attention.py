"""Flash attention (online-softmax) Pallas kernel — beyond-paper kernel.

Motivation from the roofline (§Perf): attention-heavy train cells are
memory-term dominated because materialized (S x S) score tensors round-trip
HBM.  This kernel streams KV blocks through VMEM with the online-softmax
recurrence (Dao et al.), so scores never touch HBM: per (bq x d) output tile
the HBM traffic is q + k + v + o — the same "bigger tile => higher arithmetic
intensity" argument as the paper's Eq. 7, applied to attention.

Single-source discipline as for GEMM: block sizes (bq, bk) arrive from
outside; the kernel body is architecture-agnostic.  Validated in interpret
mode against ``ref.attention_ref`` (tests/test_flash_attention.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, n_kv: int, scale: float, causal: bool,
                  bq: int, bk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)            # (bk, d)

    s = jnp.dot(q * scale, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
    if causal:
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_scr[...]                          # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                       # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)              # (bq, 1)

    m_scr[...] = m_new
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / l_scr[...]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, bq: int = 128, bk: int = 128,
    scale: Optional[float] = None, interpret: bool = False,
) -> jax.Array:
    """q, k, v: (BH, S, d) with S % bq == 0 == S_kv % bk.  One head-batch
    per grid row; online softmax over kv blocks (the 'arbitrary' grid dim)."""
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    scale = d ** -0.5 if scale is None else scale
    n_kv = skv // bk
    grid = (bh, sq // bq, n_kv)

    kernel = functools.partial(
        _flash_kernel, n_kv=n_kv, scale=scale, causal=causal, bq=bq, bk=bk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = False) -> jax.Array:
    """GQA front end: q (B, S, H, d); k, v (B, S_kv, KV, d) -> (B, S, H, d)."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    if kvh != h:  # expand grouped KV heads (wrapper-level; kernel stays pure)
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kb = k.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    vb = v.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    out = flash_attention_bhsd(qb, kb, vb, causal=causal, bq=min(bq, sq),
                               bk=min(bk, skv), interpret=interpret)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
