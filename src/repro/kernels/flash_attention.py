"""Flash attention (online-softmax) Pallas kernel — beyond-paper kernel.

Motivation from the roofline (§Perf): attention-heavy train cells are
memory-term dominated because materialized (S x S) score tensors round-trip
HBM.  This kernel streams KV blocks through VMEM with the online-softmax
recurrence (Dao et al.), so scores never touch HBM: per (bq x d) output tile
the HBM traffic is q + k + v + o — the same "bigger tile => higher arithmetic
intensity" argument as the paper's Eq. 7, applied to attention.

Single-source discipline as for GEMM: block sizes (bq, bk) arrive from
outside — callers get tuned values via
:func:`repro.core.attention_api.flash_attention`, which resolves the
op="flash_attention" entry of the tuning registry; this module never reads
tuning state.  The kernel body is architecture-agnostic.

Ragged / prefill support (the serve-engine path):

* ``kv_start`` — optional per-batch-row ``(B,)`` int32 giving the first
  *valid* KV column of a left-padded ragged batch.  Columns before
  ``kv_start[b]`` are excluded from every softmax, matching the chunked
  reference path (`models/layers._sdpa_chunked`) and the engine's
  right-aligned prompt layout.
* Non-divisible sequence lengths — ``S % bq != 0`` or ``S_kv % bk != 0`` is
  handled by **left-padding** q/k/v up to the next block multiple and
  widening ``kv_start`` by the pad, so padding reuses exactly the ragged
  masking logic; pad query rows are sliced off the output.  Fully-masked
  score blocks contribute exactly zero to the online recurrence (an explicit
  guard keeps ``exp(-inf - -inf)`` from polluting the accumulator), so the
  padded result is numerically identical to the unpadded one.

Validated in interpret mode against ``ref.attention_ref``
(tests/test_flash_attention.py), including ragged and non-divisible cases.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

NEG_INF = -1e30
#: scores at/below this are treated as masked when guarding exp() — far below
#: any reachable logit, far above NEG_INF
_MASKED_BELOW = -1e28


def _flash_kernel(q_ref, k_ref, v_ref, kvs_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, n_kv: int, scale: float, causal: bool,
                  causal_offset: int, bq: int, bk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)            # (bk, d)

    s = jnp.dot(q * scale, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if causal:
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        s = jnp.where(cols <= rows + causal_offset, s, NEG_INF)
    # ragged left-padding: columns before this row's kv_start are invalid
    s = jnp.where(cols >= kvs_ref[0, 0], s, NEG_INF)

    m_prev = m_scr[...]                          # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    # Guard fully-masked prefixes: while every score so far is NEG_INF,
    # m_new == NEG_INF and exp(s - m_new) would be exp(0) = 1 for masked
    # entries — force their contribution to exactly zero instead.
    p = jnp.where(s > _MASKED_BELOW, jnp.exp(s - m_new), 0.0)  # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)              # (bq, 1); 1 while masked

    m_scr[...] = m_new
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        # rows with an empty softmax (pad query rows) would divide by zero;
        # their output is sliced off by the wrapper, any finite value works
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)
                    ).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, bq: int = 128, bk: int = 128,
    scale: Optional[float] = None, interpret: bool = False,
    kv_start: Optional[jax.Array] = None,
) -> jax.Array:
    """Head-batched flash attention: q (BH, S, d); k, v (BH, S_kv, d).

    One head-batch per grid row; online softmax over KV blocks (the
    'arbitrary' grid dim).  ``kv_start`` is an optional (BH,) int32 of
    first-valid KV columns (left-padded ragged rows).  Sequence lengths not
    divisible by the block sizes are left-padded internally; see the module
    docstring for why padding is exact.
    """
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    scale = d ** -0.5 if scale is None else scale
    bq = max(1, min(bq, sq))
    bk = max(1, min(bk, skv))

    if kv_start is None:
        kv_start = jnp.zeros((bh,), jnp.int32)
    kv_start = kv_start.astype(jnp.int32)

    # Left-pad to block multiples; the pad columns fold into kv_start.
    pq = (-sq) % bq
    pk = (-skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (pq, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (pk, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (pk, 0), (0, 0)))
        kv_start = kv_start + pk
    sq_p, skv_p = sq + pq, skv + pk

    n_kv = skv_p // bk
    grid = (bh, sq_p // bq, n_kv)

    kernel = functools.partial(
        _flash_kernel, n_kv=n_kv, scale=scale, causal=causal,
        causal_offset=skv_p - sq_p, bq=bq, bk=bk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, 1), lambda b, i, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, kv_start[:, None])
    return out[:, pq:, :] if pq else out


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = False,
                    kv_start: Optional[jax.Array] = None,
                    scale: Optional[float] = None) -> jax.Array:
    """GQA front end: q (B, S, H, d); k, v (B, S_kv, KV, d) -> (B, S, H, d).

    Grouped KV heads are expanded at this wrapper level (the kernel stays
    pure); ``kv_start`` (B,) marks each row's first valid KV column for
    left-padded ragged batches and is broadcast across heads.
    """
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    if kvh != h:  # expand grouped KV heads (wrapper-level; kernel stays pure)
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kb = k.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    vb = v.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    ks = None if kv_start is None else jnp.repeat(kv_start.astype(jnp.int32), h)
    out = flash_attention_bhsd(qb, kb, vb, causal=causal, bq=bq, bk=bk,
                               scale=scale, interpret=interpret, kv_start=ks)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
