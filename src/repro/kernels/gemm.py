"""Single-source tiled GEMM Pallas kernel (the paper's Fig. 2 algorithm).

This file is the TPU-native re-expression of the Alpaka GEMM of Listing 1.1 /
Fig. 2: one kernel body, *zero* architecture-specific lines.  All tuning
parameters (``bm``, ``bk``, ``bn`` — the generalization of the paper's square
tile size ``T`` — plus grid dimension semantics) arrive from outside via
``core.tile_config.TileConfig`` / ``core.registry``, exactly like Alpaka's
``OptimalVectorSize<T_Acc>`` trait.  Changing hardware never touches this
file.

Mapping of the paper's hierarchy onto Pallas:
  * grid            -> ``pl.pallas_call`` grid (i, j, k) over output tiles
  * block           -> one program instance computing a (bm, bn) C tile
  * thread/element  -> VPU/MXU lanes inside ``jnp.dot`` (the "element layer";
                       on TPU vectorization is structural, not pragma-driven)
  * tile loop over A/B (purple tiles of Fig. 2) -> the ``k`` grid dimension,
    accumulating into a float32 VMEM scratch tile (the orange C tile)

The VMEM working set is (bm*bk + bk*bn + bm*bn) * sizeof(dtype) + bm*bn*4,
the rectangular generalization of the paper's K(S,T) = 2*T^2*S (Eq. 5).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

from repro.kernels.ref import apply_epilogue


def _gemm_kernel(*refs, n_k: int, alpha: float, beta: float,
                 activation: Optional[str], has_c: bool, has_bias: bool):
    """Kernel body. refs = (a, b[, c][, bias], out, acc_scratch)."""
    idx = 0
    a_ref = refs[idx]; idx += 1
    b_ref = refs[idx]; idx += 1
    c_ref = None
    bias_ref = None
    if has_c:
        c_ref = refs[idx]; idx += 1
    if has_bias:
        bias_ref = refs[idx]; idx += 1
    o_ref = refs[idx]; idx += 1
    acc_ref = refs[idx]

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The performance-critical inner tile product (paper Fig. 2, green):
    # MXU matmul with forced f32 accumulation (the TPU analogue of the
    # paper's FMA autovectorization in Listing 1.2).
    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        out = acc_ref[...]
        if alpha != 1.0:
            out = alpha * out
        if c_ref is not None:
            out = out + beta * c_ref[...].astype(jnp.float32)
        bias = bias_ref[...] if bias_ref is not None else None
        out = apply_epilogue(out, bias=bias, activation=activation)
        o_ref[...] = out.astype(o_ref.dtype)


def gemm_pallas(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    bm: int,
    bk: int,
    bn: int,
    alpha: float = 1.0,
    beta: float = 0.0,
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Tiled GEMM ``alpha * A @ B + beta * C`` via ``pl.pallas_call``.

    Operand shapes must be multiples of the block shape — the ``ops.gemm``
    wrapper pads arbitrary shapes before calling this (tiles never straddle
    the matrix edge, as in the paper where N is a multiple of T).
    """
    m, k_dim = a.shape
    k2, n = b.shape
    assert k_dim == k2, (a.shape, b.shape)
    assert m % bm == 0 and k_dim % bk == 0 and n % bn == 0, (
        f"shape {(m, k_dim, n)} not a multiple of block {(bm, bk, bn)}")
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    n_k = k_dim // bk
    grid = (m // bm, n // bn, n_k)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    operands = [a, b]
    has_c = c is not None
    if has_c:
        assert c.shape == (m, n), c.shape
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
        operands.append(c)
    has_bias = bias is not None
    if has_bias:
        assert bias.shape == (n,), bias.shape
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, kk: (j,)))
        operands.append(bias)

    kernel = functools.partial(
        _gemm_kernel, n_k=n_k, alpha=alpha, beta=beta,
        activation=activation, has_c=has_c, has_bias=has_bias,
    )

    # Grid iteration order: k innermost (revisits the same C tile) so the
    # accumulator scratch carries across k steps; i/j are parallel.
    compiler_params = pallas_compat.CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary"),
    )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params,
        interpret=interpret,
    )(*operands)
