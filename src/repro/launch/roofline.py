"""Roofline analysis over dry-run records (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, all in seconds-per-step against
one hardware profile's peaks (default: the TPU target — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI; pick another with ``--hardware``):

  compute    = HLO_FLOPs_per_device / peak
  memory     = HLO_traffic_bytes_per_device / HBM_bw
  collective = per-device link bytes (ring model) / link_bw

HLO_* come from the trip-count-corrected analyzer (hlo_stats.py), since
cost_analysis() counts scan bodies once.  MODEL_FLOPS = 6*N*D (train) or
2*N*D (inference), N = active params.  The MODEL/HLO ratio flags
remat/redundant compute; dominant term = the bottleneck the perf loop
iterates on.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline --results results/dryrun.json
  ... --emit markdown   (table for EXPERIMENTS.md)
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

from repro.core.hardware import HardwareProfile, TPU_V5E, get_profile

# Legacy module-level constants (the TPU target); roofline_row() now reads
# from whichever profile it is handed instead of these.
PEAK_BF16 = TPU_V5E.peak_flops["bfloat16"]     # 197e12
HBM_BW = TPU_V5E.hbm_bandwidth                  # 819e9
LINK_BW = TPU_V5E.ici_link_bandwidth            # 50e9


def roofline_row(rec: dict,
                 profile: HardwareProfile = TPU_V5E) -> Optional[dict]:
    if rec.get("status") != "OK":
        return None
    peak = profile.peak_flops["bfloat16"]
    hs = rec["hlo_stats"]
    chips = rec["chips"]
    compute_s = hs["flops"] / peak
    memory_s = hs["traffic_bytes"] / profile.hbm_bandwidth
    collective_s = hs["collective_link_bytes"] / profile.ici_link_bandwidth
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    est_step = max(terms.values())
    model_flops_dev = rec["model_flops"] / chips
    ratio = model_flops_dev / hs["flops"] if hs["flops"] else 0.0
    # MFU proxy: useful model flops per second vs peak, at the estimated
    # bottleneck-bound step time (the "fraction of roofline" score).
    mfu = model_flops_dev / est_step / peak if est_step else 0.0
    hw_util = hs["flops"] / est_step / peak if est_step else 0.0
    return {
        "hardware": profile.name,
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"], "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "est_step_s": est_step, "model_flops": rec["model_flops"],
        "model_hlo_ratio": ratio, "mfu_proxy": mfu, "hw_util": hw_util,
        "collective_count": hs["collective_count"],
    }


_ADVICE = {
    "compute": ("reduce issued FLOPs: lighter remat policy (save attn/ffn "
                "outputs), cast residual compute to bf16, larger fused GEMMs "
                "for better MXU occupancy"),
    "memory": ("raise arithmetic intensity: bigger effective GEMM tiles "
               "(paper Eq. 7), fuse epilogues, chunk the vocab unembed, "
               "keep KV/states in bf16"),
    "collective": ("cut link bytes: reduce-scatter+all-gather instead of "
                   "all-reduce, int8 gradient compression on the DP axis, "
                   "overlap TP collectives with the next block's GEMMs"),
}


def advice(row: dict) -> str:
    return _ADVICE[row["dominant"]]


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def markdown_table(rows: List[dict], skips: List[dict]) -> str:
    out = ["| arch | shape | mesh | kind | compute | memory | collective | "
           "dominant | MODEL/HLO | MFU-proxy |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['model_hlo_ratio']:.2f} | {r['mfu_proxy'] * 100:.1f}% |")
    for s in sorted(skips, key=lambda r: (r["arch"], r["shape"])):
        out.append(f"| {s['arch']} | {s['shape']} | {s['mesh']} | — | SKIP | "
                   f"| | | | |")
    return "\n".join(out)


def load_rows(path: str, mesh: Optional[str] = None,
              profile: HardwareProfile = TPU_V5E):
    with open(path) as f:
        results = json.load(f)
    rows, skips = [], []
    for key, rec in results.items():
        if mesh and rec.get("mesh") != mesh:
            continue
        if "#" in key or "tag" in rec:   # perf-iteration runs live in §Perf
            continue
        if rec.get("status") == "SKIP":
            skips.append(rec)
            continue
        row = roofline_row(rec, profile)
        if row:
            rows.append(row)
    return rows, skips


def perf_compare(path: str, profile: HardwareProfile = TPU_V5E) -> str:
    """§Perf view: baseline vs tagged (hillclimb) runs of the same cell."""
    with open(path) as f:
        results = json.load(f)
    by_cell: Dict[str, List] = {}
    for key, rec in results.items():
        if rec.get("status") != "OK":
            continue
        cell, _, tag = key.partition("#")
        by_cell.setdefault(cell, []).append((tag or "baseline", rec))
    out = []
    for cell, entries in sorted(by_cell.items()):
        if len(entries) < 2:
            continue
        out.append(f"\n== {cell} ==")
        entries.sort(key=lambda e: (e[0] != "baseline", e[0]))
        base = None
        for tag, rec in entries:
            r = roofline_row(rec, profile)
            line = (f"  {tag:16s} C={fmt_s(r['compute_s']):>8s} "
                    f"M={fmt_s(r['memory_s']):>8s} X={fmt_s(r['collective_s']):>8s}"
                    f" dom={r['dominant']:10s} step={fmt_s(r['est_step_s']):>8s}"
                    f" mfu={r['mfu_proxy'] * 100:5.1f}%")
            if base is None:
                base = r
            else:
                line += f"  [step x{base['est_step_s'] / r['est_step_s']:.2f}]"
            out.append(line)
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun.json")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--emit", default="text",
                    choices=["text", "markdown", "json", "perf"])
    ap.add_argument("--hardware", default=TPU_V5E.name,
                    help="hardware profile whose peaks bound the roofline "
                         "(default: the TPU tuning target)")
    args = ap.parse_args()
    profile = get_profile(args.hardware)

    if args.emit == "perf":
        print(perf_compare(args.results, profile))
        return

    rows, skips = load_rows(args.results, args.mesh, profile)
    if args.emit == "json":
        print(json.dumps(rows, indent=1))
        return
    if args.emit == "markdown":
        print(markdown_table(rows, skips))
        return
    for r in sorted(rows, key=lambda r: r["est_step_s"], reverse=True):
        print(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:6s} "
              f"C={fmt_s(r['compute_s']):>8s} M={fmt_s(r['memory_s']):>8s} "
              f"X={fmt_s(r['collective_s']):>8s} dom={r['dominant']:10s} "
              f"ratio={r['model_hlo_ratio']:.2f} mfu={r['mfu_proxy'] * 100:5.1f}%")
        print(f"{'':26s} -> {advice(r)}")
    for s in skips:
        print(f"{s['arch']:26s} {s['shape']:12s} SKIP: {s['reason'][:80]}")


if __name__ == "__main__":
    main()
