"""Structural HLO analyzer for the roofline.

``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies ONCE —
verified by calibration (a scan of 10 matmuls reports 1 matmul of flops).
Our models scan over layers, so every per-layer dot/collective would be
undercounted ~L-fold.  This module parses ``compiled.as_text()`` and
propagates while-loop trip counts through the call graph to produce:

  * flops            — 2 * numel(out) * contracted for every dot, x trips
  * traffic_bytes    — HBM-traffic proxy: top-level instruction outputs +
                       parameter reads (fusion internals excluded), x trips
  * collectives      — per-op result bytes and estimated per-device link
                       bytes (ring model using replica_groups sizes), x trips

Validated against known-flop cases in tests/test_hlo_stats.py.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s+([\w\-]+)\(")
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_REPLICA_GROUPS = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_REPLICA_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_numel_bytes(tok: str) -> Tuple[int, int]:
    """(numel, bytes) summed over all dtype[shape] tokens in ``tok``."""
    numel = 0
    nbytes = 0
    for dtype, dims in _SHAPE_TOKEN.findall(tok):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        numel += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return numel, nbytes


def _shape_dims(tok: str) -> List[int]:
    m = _SHAPE_TOKEN.search(tok)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Instr:
    name: str
    type_tok: str
    op: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    instrs: List[_Instr]
    shapes: Dict[str, str]
    int_consts: List[int]


def _parse_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{"):
                m = _COMP_START.match(line.replace("ENTRY ", "").strip())
                if m:
                    cur = _Computation(m.group(1), [], {}, [])
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            name, type_tok, op = m.group(1), m.group(2), m.group(3)
            cur.shapes[name] = type_tok
            cur.instrs.append(_Instr(name, type_tok, op, line))
            cm = _CONST_INT.search(line)
            if cm and op == "constant":
                cur.int_consts.append(int(cm.group(1)))
        else:
            # constants may appear as "%c = s32[] constant(48)" matched above;
            # also catch parameter lines for shape table
            pm = re.match(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s+parameter", line)
            if pm:
                cur.shapes[pm.group(1)] = pm.group(2)
                cur.instrs.append(_Instr(pm.group(1), pm.group(2), "parameter", line))
    return comps


def _trip_count(cond: _Computation) -> int:
    """jax scans compare a counter to a constant bound (direction=LT)."""
    best = None
    for ins in cond.instrs:
        if "direction=LT" in ins.line or "direction=GT" in ins.line:
            c = _CONST_INT.search(ins.line)
            if c:
                best = max(best or 0, int(c.group(1)))
    if best is None and cond.int_consts:
        best = max(cond.int_consts)
    # also: bound may live in a fused compare computation — handled by caller
    return best if best and best > 0 else 1


_SKIP_BYTES_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast",
                   "constant", "after-all", "copy", "copy-start", "copy-done",
                   "partition-id", "replica-id", "iota"}


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_result_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {op: 0.0 for op in COLLECTIVE_OPS})
    collective_link_bytes: float = 0.0
    collective_count: float = 0.0
    dot_count: float = 0.0
    while_trips: List[int] = dataclasses.field(default_factory=list)
    # (result_bytes, op, shape, computation) of the largest collectives —
    # unscaled by trips; computation name identifies loop bodies
    top_collectives: List[tuple] = dataclasses.field(default_factory=list)

    def add(self, other: "HloStats", mult: float = 1.0):
        self.flops += mult * other.flops
        self.traffic_bytes += mult * other.traffic_bytes
        for k in COLLECTIVE_OPS:
            self.collective_result_bytes[k] += mult * other.collective_result_bytes[k]
        self.collective_link_bytes += mult * other.collective_link_bytes
        self.collective_count += mult * other.collective_count
        self.dot_count += mult * other.dot_count
        for b, op, shp, cn in other.top_collectives:
            self.top_collectives.append((b * mult, op, shp, cn))
        self.top_collectives.sort(reverse=True)
        del self.top_collectives[12:]


def _group_size(line: str, default: int) -> int:
    m = _REPLICA_GROUPS.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _REPLICA_GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    return default


def _link_bytes(op: str, result_bytes: float, g: int) -> float:
    """Ring-algorithm per-device link-byte estimate."""
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if op == "all-reduce":
        return 2.0 * result_bytes * frac          # reduce-scatter + all-gather
    if op == "all-gather":
        return result_bytes * frac                # result = gathered buffer
    if op == "reduce-scatter":
        return result_bytes * (g - 1)             # result = one shard
    if op == "all-to-all":
        return result_bytes * frac
    if op == "collective-permute":
        return result_bytes
    return result_bytes


def analyze_hlo(text: str, default_group: int = 1) -> HloStats:
    comps = _parse_computations(text)
    memo: Dict[str, HloStats] = {}

    # entry = last ENTRY computation in file; find via text marker
    entry_name = None
    for line in text.splitlines():
        if line.startswith("ENTRY "):
            m = _COMP_START.match(line[len("ENTRY "):].strip())
            if m:
                entry_name = m.group(1)

    def cond_trip(cond_name: str) -> int:
        cond = comps.get(cond_name)
        if cond is None:
            return 1
        t = _trip_count(cond)
        if t == 1:
            # bound might sit inside a fused compare computation
            for ins in cond.instrs:
                cm = _CALLS.search(ins.line)
                if cm and cm.group(1) in comps:
                    t = max(t, _trip_count(comps[cm.group(1)]))
            # or be passed as a constant operand to the fusion
            if cond.int_consts:
                t = max(t, max(cond.int_consts))
        return t

    def visit(name: str, in_fusion: bool = False) -> HloStats:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        stats = HloStats()
        if comp is None:
            return stats
        memo[name] = stats  # guard cycles (none expected)
        for ins in comp.instrs:
            op = ins.op
            if op == "dot":
                out_numel, _ = _shape_numel_bytes(ins.type_tok)
                cd = _LHS_CDIMS.search(ins.line)
                csize = 1
                if cd:
                    # operand list: text between '(' and ')': first operand = lhs
                    args = ins.line.split("(", 1)[1]
                    ops_ = _OPERANDS.findall(args.split(")", 1)[0])
                    if ops_:
                        lhs_shape = _shape_dims(comp.shapes.get(ops_[0], ""))
                        idxs = [int(i) for i in cd.group(1).split(",") if i]
                        for i in idxs:
                            if i < len(lhs_shape):
                                csize *= lhs_shape[i]
                stats.flops += 2.0 * out_numel * csize
                stats.dot_count += 1
            elif op == "convolution":
                out_numel, _ = _shape_numel_bytes(ins.type_tok)
                stats.flops += 2.0 * out_numel  # lower bound; convs are stubs here
            elif op == "while":
                b = _BODY.search(ins.line)
                c = _COND.search(ins.line)
                trips = cond_trip(c.group(1)) if c else 1
                stats.while_trips.append(trips)
                if b:
                    stats.add(visit(b.group(1)), mult=trips)
            elif op in ("fusion", "call", "conditional", "async-start"):
                cm = _CALLS.search(ins.line)
                if cm:
                    sub = visit(cm.group(1), in_fusion=(op == "fusion"))
                    # fusion internals: flops count, bytes do NOT (stay in regs)
                    fstats = HloStats()
                    fstats.flops = sub.flops
                    fstats.dot_count = sub.dot_count
                    fstats.collective_result_bytes = dict(sub.collective_result_bytes)
                    fstats.collective_link_bytes = sub.collective_link_bytes
                    fstats.collective_count = sub.collective_count
                    if op != "fusion":
                        fstats.traffic_bytes = sub.traffic_bytes
                    stats.add(fstats)
            else:
                base = op.replace("-start", "")
                if base in COLLECTIVE_OPS and not op.endswith("-done"):
                    _, rbytes = _shape_numel_bytes(ins.type_tok)
                    if op.endswith("-start") and base in ("all-gather", "all-reduce"):
                        rbytes /= 2  # start returns (operand, result) tuple
                    g = _group_size(ins.line, default_group)
                    stats.collective_result_bytes[base] += rbytes
                    stats.collective_link_bytes += _link_bytes(base, rbytes, g)
                    stats.collective_count += 1
                    stats.top_collectives.append(
                        (rbytes, base, ins.type_tok[:64], name))
                    stats.top_collectives.sort(reverse=True)
                    del stats.top_collectives[12:]

            # HBM traffic: outputs of non-trivial top-level instrs + param reads
            if not in_fusion and op not in _SKIP_BYTES_OPS:
                if op == "dynamic-update-slice":
                    # in-place aliased update: traffic = the update slice,
                    # not the whole buffer
                    args = ins.line.split("(", 1)[1]
                    ops_ = _OPERANDS.findall(args.split(")", 1)[0])
                    upd = comp.shapes.get(ops_[1], "") if len(ops_) > 1 else ""
                    _, obytes = _shape_numel_bytes(upd or ins.type_tok)
                else:
                    _, obytes = _shape_numel_bytes(ins.type_tok)
                stats.traffic_bytes += obytes
            if op == "parameter" and not in_fusion:
                _, pbytes = _shape_numel_bytes(ins.type_tok)
                stats.traffic_bytes += pbytes
        return stats

    if entry_name is None:
        return HloStats()
    # do not memo-share entry with fusion variants: simple approach is fine
    return visit(entry_name)
