"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 100 --seq-len 64 --batch 8 --ckpt-dir /tmp/ckpt

On a real cluster this runs once per host under the usual multi-host jax
bootstrap (jax.distributed.initialize); the mesh/rules/elastic-restore logic
is identical.  ``--resume`` restarts from the latest checkpoint (the
fault-tolerance path: deterministic data + atomic checkpoints = exact
replay).  ``--mesh data=N,model=M`` (or ``--mesh auto``) builds a device
mesh when the host exposes multiple devices; the train step is then
jit-sharded — params by the sharding rules, the batch over the data axes.
The retired ``--mesh-data``/``--mesh-model`` pair still parses: it warns
and forwards onto ``--mesh``.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import Checkpointer
from repro.configs.catalog import get_config
from repro.core import execution_context, tuning_db
from repro.core.hardware import resolve_hardware
from repro.core.registry import GLOBAL_REGISTRY
from repro.data import DataConfig, TokenPipeline
from repro.distributed import sharding as sh
from repro.launch.common import add_common_args, deprecated_flag
from repro.launch.mesh import build_mesh, describe_mesh
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.train import (Trainer, TrainerConfig, abstract_train_state,
                         init_train_state, state_shardings)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-topology config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--step-deadline-s", type=float, default=None)
    add_common_args(ap)
    # retired in favour of the unified --mesh spec; warn + forward
    deprecated_flag(ap, "--mesh-data", "--mesh", type=int)
    deprecated_flag(ap, "--mesh-model", "--mesh", type=int)
    args = ap.parse_args()
    used = getattr(args, "_deprecated_used", set())
    if {"mesh_data", "mesh_model"} & used and not args.mesh:
        data = args.mesh_data or 1
        model_ax = args.mesh_model or 1
        if data * model_ax > 1:
            args.mesh = f"data={data},model={model_ax}"

    hardware = resolve_hardware(args.hardware)
    print(f"[hw] profile={hardware} "
          f"({'flag' if args.hardware else 'detected'})")

    loaded = tuning_db.load_all(GLOBAL_REGISTRY, args.tuned_dir)
    for path, count in loaded.items():
        print(f"[tuned] {count} configs from {path}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={model.param_count() / 1e6:.1f}M")

    opt = AdamW(learning_rate=warmup_cosine(args.lr, 10, args.steps))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq_len,
                                    global_batch=args.batch))

    mesh = rules = None
    if args.mesh:
        # hardware= applies the profile's latency-hiding XLA flags before
        # the first device touch (overlap grad all-reduces with compute)
        mesh = build_mesh(args.mesh, hardware=hardware)
        rules = sh.rules_for_mesh(mesh)
        print(f"[mesh] {describe_mesh(mesh)} rules={rules}")

    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    tcfg = TrainerConfig(total_steps=args.steps, log_every=10,
                         checkpoint_every=args.ckpt_every,
                         microbatches=args.microbatches,
                         use_compression=args.compress_grads,
                         step_deadline_s=args.step_deadline_s)
    trainer = Trainer(model, opt, pipe, tcfg, mesh=mesh, rules=rules,
                      checkpointer=ck)

    start = 0
    if args.resume and ck is not None and ck.latest_step() is not None:
        start = ck.latest_step()
        template = abstract_train_state(model, opt, args.compress_grads)
        shardings = (state_shardings(mesh, rules, model, args.compress_grads)
                     if mesh is not None else None)
        state = ck.restore(start, template, shardings)
        print(f"resumed from step {start}")
    else:
        state = init_train_state(model, opt, jax.random.PRNGKey(0),
                                 args.compress_grads)

    from repro.profiling import trace
    t0 = time.perf_counter()
    with execution_context(hardware=hardware), \
            trace(args.trace_dir, enabled=bool(args.trace_dir)):
        state, history = trainer.run(state, start_step=start)
    wall = time.perf_counter() - t0
    for step, loss in history:
        print(f"step {step:6d}  loss {loss:.4f}")
    print(f"done at step {int(state.step)}")
    if args.stats:
        steps_run = max(int(state.step) - start, 1)
        toks = steps_run * args.batch * args.seq_len
        print(f"[stats] hw={hardware}, {steps_run} step(s) in {wall:.1f}s "
              f"({steps_run / wall:.2f} step/s, {toks / wall:.0f} tok/s), "
              f"mesh={describe_mesh(mesh)}")


if __name__ == "__main__":
    main()
