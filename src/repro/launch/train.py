"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 100 --seq-len 64 --batch 8 --ckpt-dir /tmp/ckpt

On a real cluster this runs once per host under the usual multi-host jax
bootstrap (jax.distributed.initialize); the mesh/rules/elastic-restore logic
is identical.  ``--resume`` restarts from the latest checkpoint (the
fault-tolerance path: deterministic data + atomic checkpoints = exact
replay).  ``--mesh data=N,model=M`` (or the legacy
``--mesh-data/--mesh-model`` pair) builds a device mesh when the host
exposes multiple devices; the train step is then jit-sharded — params by
the sharding rules, the batch over the data axes.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.checkpoint import Checkpointer
from repro.configs.catalog import get_config
from repro.core import execution_context, tuning_db
from repro.core.hardware import resolve_hardware
from repro.core.registry import GLOBAL_REGISTRY
from repro.data import DataConfig, TokenPipeline
from repro.distributed import sharding as sh
from repro.launch.mesh import build_mesh, describe_mesh, make_host_mesh
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.train import (Trainer, TrainerConfig, abstract_train_state,
                         init_train_state, state_shardings)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-topology config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="device mesh spec: 'data=N,model=M' or 'auto' "
                         "(overrides --mesh-data/--mesh-model)")
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--step-deadline-s", type=float, default=None)
    ap.add_argument("--hardware", default=None,
                    help="hardware profile for tile lookups "
                         "(default: $REPRO_HARDWARE or auto-detect)")
    ap.add_argument("--tuned-dir", default=None,
                    help="tuning-DB dir (default: $REPRO_TUNED_DIR or repo tuned/)")
    ap.add_argument("--trace-dir", default=None,
                    help="capture a jax.profiler trace of the training run "
                         "into this dir (post-process: scripts/profile.py)")
    args = ap.parse_args()

    hardware = resolve_hardware(args.hardware)
    print(f"[hw] profile={hardware} "
          f"({'flag' if args.hardware else 'detected'})")

    loaded = tuning_db.load_all(GLOBAL_REGISTRY, args.tuned_dir)
    for path, count in loaded.items():
        print(f"[tuned] {count} configs from {path}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={model.param_count() / 1e6:.1f}M")

    opt = AdamW(learning_rate=warmup_cosine(args.lr, 10, args.steps))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq_len,
                                    global_batch=args.batch))

    mesh = rules = None
    if args.mesh:
        # hardware= applies the profile's latency-hiding XLA flags before
        # the first device touch (overlap grad all-reduces with compute)
        mesh = build_mesh(args.mesh, hardware=hardware)
    elif args.mesh_data * args.mesh_model > 1:
        mesh = make_host_mesh(data=args.mesh_data, model=args.mesh_model)
    if mesh is not None:
        rules = sh.rules_for_mesh(mesh)
        print(f"[mesh] {describe_mesh(mesh)} rules={rules}")

    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    tcfg = TrainerConfig(total_steps=args.steps, log_every=10,
                         checkpoint_every=args.ckpt_every,
                         microbatches=args.microbatches,
                         use_compression=args.compress_grads,
                         step_deadline_s=args.step_deadline_s)
    trainer = Trainer(model, opt, pipe, tcfg, mesh=mesh, rules=rules,
                      checkpointer=ck)

    start = 0
    if args.resume and ck is not None and ck.latest_step() is not None:
        start = ck.latest_step()
        template = abstract_train_state(model, opt, args.compress_grads)
        shardings = (state_shardings(mesh, rules, model, args.compress_grads)
                     if mesh is not None else None)
        state = ck.restore(start, template, shardings)
        print(f"resumed from step {start}")
    else:
        state = init_train_state(model, opt, jax.random.PRNGKey(0),
                                 args.compress_grads)

    from repro.profiling import trace
    with execution_context(hardware=hardware), \
            trace(args.trace_dir, enabled=bool(args.trace_dir)):
        state, history = trainer.run(state, start_step=start)
    for step, loss in history:
        print(f"step {step:6d}  loss {loss:.4f}")
    print(f"done at step {int(state.step)}")


if __name__ == "__main__":
    main()
