"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

``input_specs(cfg, shape)`` returns the abstract inputs of the step that
cell lowers (train_step / prefill_step / decode_step) — weak-type-correct,
shardable, zero allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.model import Model, build_model


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(model: Model, shape: ShapeSpec) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((b, s), jnp.int32),
             "labels": _sds((b, s), jnp.int32)}
    batch.update(model.extra_inputs(b))
    return batch


def prefill_batch_specs(model: Model, shape: ShapeSpec) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((b, s), jnp.int32)}
    batch.update(model.extra_inputs(b))
    return batch


def cache_specs(model: Model, shape: ShapeSpec):
    """Abstract KV/recurrent cache sized for the cell's sequence length."""
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    return cache


def decode_token_specs(shape: ShapeSpec):
    return _sds((shape.global_batch, 1), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[str, Dict[str, Any]]:
    """-> (step_kind, kwargs-of-abstract-arrays) for the cell."""
    model = build_model(cfg)
    if shape.kind == "train":
        return "train", {"batch": train_batch_specs(model, shape)}
    if shape.kind == "prefill":
        return "prefill", {"batch": prefill_batch_specs(model, shape),
                           "cache": cache_specs(model, shape)}
    if shape.kind == "decode":
        return "decode", {"tokens": decode_token_specs(shape),
                          "cache": cache_specs(model, shape),
                          "offset": _sds((), jnp.int32)}
    raise ValueError(shape.kind)
