"""Shared CLI surface of the launch drivers.

Every driver used to re-declare ``--hardware``/``--mesh``/``--tuned-dir``/
``--trace-dir`` by hand, and the copies drifted (names, defaults, help
text).  This module is the single declaration:

* :func:`add_common_args` — the tuning/topology flags every driver takes,
  with identical names and help everywhere;
* :func:`add_serving_args` — the serving-engine group (scheduler, paged-KV
  sizing, prefix cache) shared by ``serve.py`` and the benchmarks;
* :func:`deprecated_flag` — registers a retired flag that still parses:
  using it warns once and forwards its value onto the replacement, so old
  command lines keep working one release while printing their migration.

Drivers call these, then add their driver-specific flags on top.
"""
from __future__ import annotations

import argparse
import warnings


def add_common_args(ap: argparse.ArgumentParser) -> None:
    """The flags every launch driver shares, declared once."""
    ap.add_argument("--hardware", default=None,
                    help="hardware profile the engine tunes against "
                         "(default: $REPRO_HARDWARE or auto-detect)")
    ap.add_argument("--mesh", default=None,
                    help="device mesh spec: 'data=N,model=M' or 'auto' "
                         "(default: single-device)")
    ap.add_argument("--stats", action="store_true",
                    help="print engine/trainer stats (throughput, tile "
                         "provenance)")
    ap.add_argument("--tuned-dir", default=None,
                    help="tuning-DB dir (default: $REPRO_TUNED_DIR or "
                         "repo tuned/)")
    ap.add_argument("--trace-dir", default=None,
                    help="capture a jax.profiler trace of the run into "
                         "this dir (post-process: scripts/profile.py)")


def add_serving_args(ap: argparse.ArgumentParser) -> None:
    """The serving-engine knob group (ServeConfig surface)."""
    grp = ap.add_argument_group(
        "serving", "continuous-batching engine configuration")
    grp.add_argument("--scheduler", choices=["continuous", "wave"],
                     default="continuous",
                     help="continuous = paged KV + admit/evict at chunk "
                          "boundaries (default); wave = slot-per-request")
    grp.add_argument("--page-size", type=int, default=None,
                     help="paged-KV page size in tokens (default: tuned "
                          "paged_attn entry for this hardware/mesh)")
    grp.add_argument("--capacity-tokens", type=int, default=None,
                     help="paged-pool capacity in tokens (default: "
                          "max_batch * max_len)")
    grp.add_argument("--decode-chunk", type=int, default=8,
                     help="tokens per fused chunk between scheduling "
                          "boundaries (power of two)")
    grp.add_argument("--no-prefix-cache", action="store_true",
                     help="disable shared-prefix KV reuse (continuous "
                          "scheduler only; on by default)")


class _DeprecatedAction(argparse.Action):
    """Store the value, remember it was used, and warn at parse time."""

    def __call__(self, parser, namespace, values, option_string=None):
        warnings.warn(
            f"{option_string} is deprecated; use {self.const} instead "
            f"(value forwarded)", DeprecationWarning, stacklevel=2)
        print(f"[deprecated] {option_string} -> {self.const}")
        setattr(namespace, self.dest, values)
        used = getattr(namespace, "_deprecated_used", set())
        used.add(self.dest)
        setattr(namespace, "_deprecated_used", used)


def deprecated_flag(ap: argparse.ArgumentParser, old: str, new: str,
                    **kwargs) -> None:
    """Register retired flag ``old`` as a warn-and-forward alias.

    The parsed value lands on ``old``'s own dest;
    :func:`forward_deprecated` moves it onto ``new``'s dest afterwards
    (only when the modern flag was not given — the modern flag wins).
    """
    kwargs.setdefault("default", None)
    kwargs.setdefault("help", argparse.SUPPRESS)
    ap.add_argument(old, action=_DeprecatedAction, const=new, **kwargs)


def forward_deprecated(args: argparse.Namespace, mapping) -> None:
    """Resolve warn-and-forward aliases after parsing.

    ``mapping`` is ``{old_dest: (new_dest, convert)}``; each used alias
    whose modern dest is still at its default (None/falsy) gets the
    converted legacy value.
    """
    used = getattr(args, "_deprecated_used", set())
    for old_dest, (new_dest, convert) in mapping.items():
        if old_dest not in used:
            continue
        if getattr(args, new_dest, None):
            continue                      # the modern flag wins
        setattr(args, new_dest, convert(getattr(args, old_dest)))
