"""Serving driver: continuous-batching generation with the Engine.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --reduced \
      --prompts "1,2,3;4,5,6,7,8" --max-new 16

Ragged prompt lengths are handled natively (left-pad + masking); more
prompts than ``--max-batch`` are served in waves over the fixed slot pool.
``--mesh data=4,model=2`` (or ``--mesh auto``) shards params/KV-cache/batch
over a device mesh — token-for-token identical to the single-device run:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --mesh data=4,model=2 --stats
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.catalog import get_config
from repro.core import tuning_db
from repro.core.hardware import find_profile, resolve_hardware
from repro.core.registry import GLOBAL_REGISTRY
from repro.models import build_model
from repro.serve import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompts", default="1,2,3;7,8,9",
                    help="';'-separated comma-token prompts")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=None,
                    help="KV-cache slots (default: number of prompts)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--scheduler", choices=["continuous", "wave"],
                    default="continuous",
                    help="continuous = paged KV + admit/evict at chunk "
                         "boundaries (default); wave = slot-per-request")
    ap.add_argument("--page-size", type=int, default=None,
                    help="paged-KV page size in tokens (default: tuned "
                         "paged_attn entry for this hardware/mesh)")
    ap.add_argument("--capacity-tokens", type=int, default=None,
                    help="paged-pool capacity in tokens (default: "
                         "max_batch * max_len)")
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="tokens per fused chunk between scheduling "
                         "boundaries (power of two)")
    ap.add_argument("--attn-impl", choices=["chunked", "flash"], default=None,
                    help="override the config's attention implementation "
                         "(flash = tuned Pallas kernel for prefill)")
    ap.add_argument("--stats", action="store_true",
                    help="print engine stats (throughput, tile provenance)")
    ap.add_argument("--hardware", default=None,
                    help="hardware profile the engine tunes against "
                         "(default: $REPRO_HARDWARE or auto-detect)")
    ap.add_argument("--mesh", default=None,
                    help="device mesh spec: 'data=N,model=M' or 'auto' "
                         "(default: single-device)")
    ap.add_argument("--tuned-dir", default=None,
                    help="tuning-DB dir (default: $REPRO_TUNED_DIR or repo tuned/)")
    ap.add_argument("--trace-dir", default=None,
                    help="capture a jax.profiler trace of the generate call "
                         "into this dir (post-process: scripts/profile.py)")
    args = ap.parse_args()

    hardware = resolve_hardware(args.hardware)
    prof = find_profile(hardware)
    print(f"[hw] profile={hardware} "
          f"platform={prof.platform if prof else 'unknown'} "
          f"({'flag' if args.hardware else 'detected'})")
    mesh = None
    if args.mesh:
        from repro.launch.mesh import build_mesh, describe_mesh
        # hardware= applies the profile's latency-hiding XLA flags before
        # the first device touch (async collectives for the decode loop)
        mesh = build_mesh(args.mesh, hardware=hardware)
        print(f"[mesh] {describe_mesh(mesh)}")

    loaded = tuning_db.load_all(GLOBAL_REGISTRY, args.tuned_dir)
    for path, count in loaded.items():
        print(f"[tuned] {count} configs from {path}")
    if not loaded:
        print("[tuned] no tuning DB found; using built-in default tiles")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.attn_impl:
        import dataclasses
        cfg = dataclasses.replace(cfg, attention_impl=args.attn_impl)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    prompts = [[int(t) % cfg.vocab_size for t in p.split(",")]
               for p in args.prompts.split(";")]
    extra = {}
    for k, sds in model.extra_inputs(len(prompts)).items():
        extra[k] = jnp.zeros(sds.shape, sds.dtype)

    eng = Engine(model, params,
                 ServeConfig(max_batch=args.max_batch or len(prompts),
                             temperature=args.temperature,
                             profile=args.stats,
                             hardware=hardware,
                             mesh=mesh,
                             scheduler=args.scheduler,
                             page_size=args.page_size,
                             capacity_tokens=args.capacity_tokens,
                             decode_chunk=args.decode_chunk))
    from repro.profiling import trace
    with trace(args.trace_dir, enabled=bool(args.trace_dir)) as session:
        outs = eng.generate(prompts, args.max_new, extra_inputs=extra or None)
    if session.enabled:
        print(f"[trace] captured {len(session.trace_files())} trace file(s) "
              f"under {args.trace_dir}")
    for p, o in zip(prompts, outs):
        print(f"prompt={p} -> {o}")

    if args.stats:
        st = eng.stats()
        toks = st["tokens_generated"]
        dec_s = st["decode_seconds"] or 1e-9
        sched = st["scheduler"]
        unit = (f"{int(st['chunks'])} chunk(s)" if sched == "continuous"
                else f"{int(st['waves'])} wave(s)")
        forced = (f" (forced: {st['scheduler_forced']})"
                  if st.get("scheduler_forced") else "")
        print(f"[stats] hw={st['hardware']} ({st['hardware_platform']}), "
              f"scheduler={sched}{forced}, {int(toks)} tokens, {unit}, "
              f"{int(st['device_transfers'])} host transfer(s), "
              f"decode {toks / dec_s:.0f} tok/s")
        if sched == "continuous":
            pages = st.get("pages") or {}
            print(f"[stats] paged KV: page_size={st['page_size']} "
                  f"({st['page_size_source']}), "
                  f"capacity={st['capacity_tokens']} tokens, high water "
                  f"{pages.get('high_water_pages', 0)}/"
                  f"{pages.get('usable_pages', 0)} pages, "
                  f"admissions={st['admissions']} "
                  f"evictions={st['evictions']} "
                  f"preemptions={st['preemptions']}")
        print(f"[stats] mesh={st['mesh']}")
        if st["sharding"]:
            print(f"[stats] sharding rules={st['sharding']['rules']} "
                  f"params={st['sharding']['params']}")
        for shape, info in (st["decode_tile_lookups"] or {}).items():
            local = (f" local={info['local_shape']}"
                     if "local_shape" in info else "")
            print(f"[tiles] decode GEMM {shape:>16s} -> {info['tile']} "
                  f"({info['source']}){local}")
        for shape, info in (st["prefill_flash_lookups"] or {}).items():
            print(f"[tiles] prefill flash {shape:>14s} -> {info['tile']} "
                  f"({info['source']})")


if __name__ == "__main__":
    main()
