"""Serving driver: continuous-batching generation with the Engine.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --reduced \
      --prompts "1,2,3;4,5,6,7,8" --max-new 16

Ragged prompt lengths are handled natively (left-pad + masking); more
prompts than ``--max-batch`` are served in waves over the fixed slot pool.
``--mesh data=4,model=2`` (or ``--mesh auto``) shards params/KV-cache/batch
over a device mesh — token-for-token identical to the single-device run:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --mesh data=4,model=2 --stats

``--server`` runs the same prompts through the long-lived streaming
front-end instead of one batched call: requests are submitted from the
caller thread into a :class:`repro.serve.Server`, tokens print as they
become host-visible, and ``--stats`` then includes per-request TTFT /
tok-per-s percentiles.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.catalog import get_config
from repro.core import tuning_db
from repro.core.hardware import find_profile, resolve_hardware
from repro.core.registry import GLOBAL_REGISTRY
from repro.launch.common import add_common_args, add_serving_args
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig, Server


def _serve_streaming(eng, prompts, max_new):
    """--server mode: long-lived Server + per-token streaming prints."""
    streams = {i: [] for i in range(len(prompts))}

    def stream_for(i):
        def cb(ev):
            if ev.token is not None:
                streams[i].append(ev.token)
                print(f"[stream] prompt {i} token[{ev.index}] = {ev.token}")
            else:
                print(f"[stream] prompt {i} finished ({ev.finish_reason})")
        return cb

    with Server(eng) as srv:
        handles = [srv.submit(Request(prompt=p, max_new_tokens=max_new,
                                      stream=stream_for(i)))
                   for i, p in enumerate(prompts)]
        results = [h.result(timeout=600) for h in handles]
    for i, (p, res) in enumerate(zip(prompts, results)):
        assert res.tokens == streams[i]   # streamed == batch, by contract
        print(f"prompt={p} -> {res.tokens} "
              f"(ttft {res.ttft_s * 1e3:.1f} ms, {res.tok_per_s:.0f} tok/s"
              + (f", prefix hit: {res.prefix_hit}" if res.prefix_hit
                 else "") + ")")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompts", default="1,2,3;7,8,9",
                    help="';'-separated comma-token prompts")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=None,
                    help="KV-cache slots (default: number of prompts)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--attn-impl", choices=["chunked", "flash"], default=None,
                    help="override the config's attention implementation "
                         "(flash = tuned Pallas kernel for prefill)")
    ap.add_argument("--server", action="store_true",
                    help="serve through the long-lived streaming Server "
                         "(per-token callbacks + TTFT percentiles) instead "
                         "of one batched generate call")
    add_serving_args(ap)
    add_common_args(ap)
    args = ap.parse_args()

    hardware = resolve_hardware(args.hardware)
    prof = find_profile(hardware)
    print(f"[hw] profile={hardware} "
          f"platform={prof.platform if prof else 'unknown'} "
          f"({'flag' if args.hardware else 'detected'})")
    mesh = None
    if args.mesh:
        from repro.launch.mesh import build_mesh, describe_mesh
        # hardware= applies the profile's latency-hiding XLA flags before
        # the first device touch (async collectives for the decode loop)
        mesh = build_mesh(args.mesh, hardware=hardware)
        print(f"[mesh] {describe_mesh(mesh)}")

    loaded = tuning_db.load_all(GLOBAL_REGISTRY, args.tuned_dir)
    for path, count in loaded.items():
        print(f"[tuned] {count} configs from {path}")
    if not loaded:
        print("[tuned] no tuning DB found; using built-in default tiles")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.attn_impl:
        import dataclasses
        cfg = dataclasses.replace(cfg, attention_impl=args.attn_impl)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    prompts = [[int(t) % cfg.vocab_size for t in p.split(",")]
               for p in args.prompts.split(";")]
    extra = {}
    for k, sds in model.extra_inputs(len(prompts)).items():
        extra[k] = jnp.zeros(sds.shape, sds.dtype)

    eng = Engine(model, params,
                 ServeConfig(max_batch=args.max_batch or len(prompts),
                             temperature=args.temperature,
                             profile=args.stats,
                             hardware=hardware,
                             mesh=mesh,
                             scheduler=args.scheduler,
                             page_size=args.page_size,
                             capacity_tokens=args.capacity_tokens,
                             decode_chunk=args.decode_chunk,
                             prefix_cache=not args.no_prefix_cache))
    from repro.profiling import trace
    if args.server:
        if extra:
            ap.error("--server cannot carry extra-input models "
                     "(extras are positional per drain)")
        with trace(args.trace_dir, enabled=bool(args.trace_dir)) as session:
            _serve_streaming(eng, prompts, args.max_new)
    else:
        with trace(args.trace_dir, enabled=bool(args.trace_dir)) as session:
            outs = eng.generate(prompts, args.max_new,
                                extra_inputs=extra or None)
        for p, o in zip(prompts, outs):
            print(f"prompt={p} -> {o}")
    if session.enabled:
        print(f"[trace] captured {len(session.trace_files())} trace file(s) "
              f"under {args.trace_dir}")

    if args.stats:
        st = eng.stats()
        toks = st["tokens_generated"]
        dec_s = st["decode_seconds"] or 1e-9
        sched = st["scheduler"]
        unit = (f"{int(st['chunks'])} chunk(s)" if sched == "continuous"
                else f"{int(st['waves'])} wave(s)")
        forced = (f" (forced: {st['scheduler_forced']})"
                  if st.get("scheduler_forced") else "")
        print(f"[stats] hw={st['hardware']} ({st['hardware_platform']}), "
              f"scheduler={sched}{forced}, {int(toks)} tokens, {unit}, "
              f"{int(st['device_transfers'])} host transfer(s), "
              f"decode {toks / dec_s:.0f} tok/s")
        if sched == "continuous":
            pages = st.get("pages") or {}
            print(f"[stats] paged KV: page_size={st['page_size']} "
                  f"({st['page_size_source']}), "
                  f"capacity={st['capacity_tokens']} tokens, high water "
                  f"{pages.get('high_water_pages', 0)}/"
                  f"{pages.get('usable_pages', 0)} pages, "
                  f"admissions={st['admissions']} "
                  f"evictions={st['evictions']} "
                  f"preemptions={st['preemptions']}")
        pc = st["prefix_cache"]
        if pc["enabled"]:
            print(f"[stats] prefix cache: {pc['hits_full']} full / "
                  f"{pc['hits_partial']} partial hit(s), {pc['misses']} "
                  f"miss(es), {pc['prefill_tokens_saved']} prefill "
                  f"token(s) saved, {pc['pinned_pages']} page(s) pinned")
        lat = st["latency"]
        if lat["count"]:
            print(f"[stats] latency over {lat['count']} request(s): "
                  f"ttft p50 {lat['ttft_s']['p50'] * 1e3:.1f} ms / "
                  f"p99 {lat['ttft_s']['p99'] * 1e3:.1f} ms, "
                  f"tok/s p50 {lat['tok_per_s']['p50']:.0f}")
        print(f"[stats] mesh={st['mesh']}")
        if st["sharding"]:
            print(f"[stats] sharding rules={st['sharding']['rules']} "
                  f"params={st['sharding']['params']}")
        for shape, info in (st["decode_tile_lookups"] or {}).items():
            local = (f" local={info['local_shape']}"
                     if "local_shape" in info else "")
            print(f"[tiles] decode GEMM {shape:>16s} -> {info['tile']} "
                  f"({info['source']}){local}")
        for shape, info in (st["prefill_flash_lookups"] or {}).items():
            print(f"[tiles] prefill flash {shape:>14s} -> {info['tile']} "
                  f"({info['source']})")


if __name__ == "__main__":
    main()
