import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count on first init.
# The 512 host devices exist ONLY for this dry-run process (16x16 single-pod
# and 2x16x16 multi-pod production meshes); tests/benches see 1 device.
"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory/cost/collective analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --list

Every record lands incrementally in results/dryrun.json; SKIP rows are
emitted for long_500k on pure full-attention archs (DESIGN.md §4).
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs.base import SHAPES, LONG_500K, ModelConfig, ShapeSpec
from repro.configs.catalog import ARCHITECTURES, get_config
from repro.distributed import sharding as sh
from repro.launch import specs as specs_mod
from repro.launch.hlo_analysis import collective_bytes, op_histogram
from repro.launch.hlo_stats import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models.model import active_param_count, build_model
from repro.optim.adamw import AdamW
from repro.train import trainer as tr

RESULTS_DEFAULT = "results/dryrun.json"


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
               rules: Optional[sh.ShardingRules] = None,
               logit_chunk: Optional[int] = None,
               attn_p_dtype: Optional[str] = None,
               bf16_partials: bool = False,
               remat_policy: Optional[str] = None,
               kv_quant: bool = False):
    """Build + lower the cell's step function. Returns (lowered, meta)."""
    if logit_chunk is not None:
        cfg = dataclasses.replace(cfg, logit_chunk=logit_chunk)
    if attn_p_dtype is not None:
        cfg = dataclasses.replace(cfg, attn_p_dtype=attn_p_dtype)
    if remat_policy is not None:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    model = build_model(cfg)
    rules = rules or sh.rules_for_mesh(mesh)
    kind, specs = specs_mod.input_specs(cfg, shape)

    from repro.core.gemm_api import execution_context
    from repro.distributed.ctx import activation_policy
    with mesh, activation_policy(mesh, rules), \
            execution_context(bf16_partials=bf16_partials):
        if kind == "train":
            optimizer = AdamW(learning_rate=1e-4)
            state_abs = tr.abstract_train_state(model, optimizer)
            state_shard = tr.state_shardings(mesh, rules, model)
            batch_shard = sh.batch_shardings(mesh, rules, specs["batch"])
            step = tr.make_train_step(model, optimizer)
            jitted = jax.jit(step,
                             in_shardings=(state_shard, batch_shard),
                             out_shardings=(state_shard, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, specs["batch"])
        elif kind == "prefill":
            pshard = sh.param_shardings(mesh, rules, model.template)
            batch_shard = sh.batch_shardings(mesh, rules, specs["batch"])
            cache_shard = sh.cache_shardings(mesh, rules, specs["cache"])
            step = tr.make_prefill_step(model)
            jitted = jax.jit(step,
                             in_shardings=(pshard, batch_shard, cache_shard),
                             out_shardings=(None, cache_shard),
                             donate_argnums=(2,))
            lowered = jitted.lower(model.abstract(), specs["batch"], specs["cache"])
        else:  # decode
            pshard = sh.param_shardings(mesh, rules, model.template)
            tok_shard = sh.batch_shardings(mesh, rules, {"t": specs["tokens"]})["t"]
            cache_shard = sh.cache_shardings(mesh, rules, specs["cache"])
            from jax.sharding import NamedSharding, PartitionSpec as P
            rep = NamedSharding(mesh, P())
            step = tr.make_decode_step(model)
            jitted = jax.jit(step,
                             in_shardings=(pshard, tok_shard, cache_shard, rep),
                             out_shardings=(None, cache_shard),
                             donate_argnums=(2,))
            lowered = jitted.lower(model.abstract(), specs["tokens"],
                                   specs["cache"], specs["offset"])

    n_active = active_param_count(model)
    n_total = model.param_count()
    if kind == "train":
        model_flops = 6 * n_active * shape.tokens
    elif kind == "prefill":
        model_flops = 2 * n_active * shape.tokens
    else:
        model_flops = 2 * n_active * shape.global_batch
    meta = {"kind": kind, "params_total": n_total, "params_active": n_active,
            "model_flops": model_flops}
    return lowered, meta


def run_cell(arch: str, shape_name: str, mesh_name: str,
             logit_chunk: Optional[int] = None, fsdp: bool = True,
             keep_hlo: bool = False, sequence_parallel: bool = False,
             attn_p_dtype: Optional[str] = None,
             bf16_partials: bool = False,
             remat_policy: Optional[str] = None,
             kv_quant: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]

    if shape_name == "long_500k" and not cfg.supports_long_context:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "SKIP",
                "reason": "pure full-attention arch: 524k dense-attention "
                          "decode is out of operating envelope (DESIGN.md §4)"}

    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rules = sh.rules_for_mesh(mesh, fsdp=fsdp,
                              sequence_parallel=sequence_parallel)
    t0 = time.time()
    lowered, meta = lower_cell(cfg, shape, mesh, rules,
                               logit_chunk=logit_chunk,
                               attn_p_dtype=attn_p_dtype,
                               bf16_partials=bf16_partials,
                               remat_policy=remat_policy,
                               kv_quant=kv_quant)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement everything
        mem_rec = {"error": str(e)}

    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        cost_rec = {"flops": float(cost.get("flops", -1)),
                    "bytes_accessed": float(cost.get("bytes accessed", -1))}
    except Exception as e:
        cost_rec = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)            # raw (uncorrected) sums
    hist = op_histogram(hlo)
    stats = analyze_hlo(hlo, default_group=16)  # trip-count-corrected

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "OK", "chips": mesh.devices.size,
        "seconds_lower": round(t_lower, 1),
        "seconds_compile": round(t_compile, 1),
        "memory": mem_rec, "cost": cost_rec,
        "collectives": coll, "op_histogram": hist,
        "hlo_stats": {
            "flops": stats.flops,
            "traffic_bytes": stats.traffic_bytes,
            "collective_result_bytes": stats.collective_result_bytes,
            "collective_link_bytes": stats.collective_link_bytes,
            "collective_count": stats.collective_count,
            "dot_count": stats.dot_count,
            "while_trips": stats.while_trips,
            "top_collectives": stats.top_collectives,
        },
        "hlo_chars": len(hlo),
        **meta,
    }
    if keep_hlo:
        rec["hlo_text"] = hlo
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=RESULTS_DEFAULT)
    ap.add_argument("--logit-chunk", type=int, default=None)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--attn-p-dtype", default=None,
                    help="e.g. bfloat16 (halves the attention P buffer)")
    ap.add_argument("--bf16-partials", action="store_true",
                    help="bf16 cross-shard matmul reductions")
    ap.add_argument("--remat-policy", default=None, choices=["full", "dots"])
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (decode memory-term optimization)")
    ap.add_argument("--tag", default=None,
                    help="suffix results key with #<tag> (perf iterations)")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute existing")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHITECTURES)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.list:
        for a in archs:
            for s in shapes:
                print(f"{a} x {s}")
        return

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for a in archs:
        for s in shapes:
            for m in meshes:
                key = f"{a}/{s}/{m}"
                if args.tag:
                    key += f"#{args.tag}"
                if key in results and results[key].get("status") in ("OK", "SKIP") \
                        and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[run] {key} ...", flush=True)
                try:
                    rec = run_cell(a, s, m, logit_chunk=args.logit_chunk,
                                   fsdp=not args.no_fsdp,
                                   sequence_parallel=args.seq_parallel,
                                   attn_p_dtype=args.attn_p_dtype,
                                   bf16_partials=args.bf16_partials,
                                   remat_policy=args.remat_policy,
                                   kv_quant=args.kv_quant)
                    if args.tag:
                        rec["tag"] = args.tag
                except Exception as e:
                    rec = {"arch": a, "shape": s, "mesh": m,
                           "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "OK":
                    extra = (f" flops/dev={rec['cost'].get('flops', 0):.3g}"
                             f" coll={rec['collectives']['total']:.3g}B"
                             f" compile={rec['seconds_compile']}s")
                print(f"[{status}] {key}{extra}", flush=True)

    ok = sum(1 for r in results.values() if r["status"] == "OK")
    skip = sum(1 for r in results.values() if r["status"] == "SKIP")
    fail = sum(1 for r in results.values() if r["status"] == "FAIL")
    print(f"\nTotal: {ok} OK, {skip} SKIP, {fail} FAIL / {len(results)}")


if __name__ == "__main__":
    main()
