"""Mesh topology construction — the distribution layer's `--mesh` knob.

Functions (not module-level constants) so importing this module never
touches jax device state — required for the XLA_FLAGS trick in dryrun.py.

``build_mesh`` is the single entry point every launcher/engine/benchmark
uses to turn a ``--mesh`` flag into a :class:`jax.sharding.Mesh`:

  * ``"data=4,model=2"``  — explicit axis sizes (the paper's tuning-table
    discipline applied to topology: one spec string, zero model edits);
  * ``"auto"``            — all visible devices on the ``data`` axis;
  * ``None`` / ``""``     — no mesh (single-device execution).

CI exercises multi-device meshes on a CPU host via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np

#: axis names the sharding rules understand (distributed/sharding.py)
MESH_AXES = ("pod", "data", "model")


def parse_mesh_spec(spec: str) -> Dict[str, int]:
    """``"data=4,model=2"`` -> ``{"data": 4, "model": 2}`` (order kept).

    Axis names must come from :data:`MESH_AXES` (the vocabulary
    ``rules_for_mesh`` maps logical axes onto); sizes must be >= 1.
    """
    out: Dict[str, int] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(
                f"bad mesh spec {spec!r}: expected axis=size, got {part!r}")
        name, _, size_s = part.partition("=")
        name = name.strip()
        if name not in MESH_AXES:
            raise ValueError(
                f"bad mesh spec {spec!r}: unknown axis {name!r} "
                f"(choose from {', '.join(MESH_AXES)})")
        if name in out:
            raise ValueError(f"bad mesh spec {spec!r}: duplicate axis {name!r}")
        try:
            size = int(size_s)
        except ValueError:
            raise ValueError(
                f"bad mesh spec {spec!r}: size of {name!r} is not an int")
        if size < 1:
            raise ValueError(
                f"bad mesh spec {spec!r}: size of {name!r} must be >= 1")
        out[name] = size
    if not out:
        raise ValueError(f"bad mesh spec {spec!r}: no axes")
    return out


def build_mesh(spec: Optional[str], *, devices=None) -> Optional[jax.sharding.Mesh]:
    """Build a Mesh from a ``--mesh`` spec string (None/"" -> no mesh).

    ``"auto"`` puts every visible device on the ``data`` axis.  An explicit
    spec may use a *subset* of the visible devices (the first ``prod(sizes)``
    in ``jax.devices()`` order), so ``data=2`` works on an 8-device host.
    """
    if not spec:
        return None
    devices = list(devices if devices is not None else jax.devices())
    if spec.strip() == "auto":
        sizes = {"data": len(devices)}
    else:
        sizes = parse_mesh_spec(spec)
    n = int(np.prod(list(sizes.values())))
    if n > len(devices):
        raise ValueError(
            f"mesh {spec!r} needs {n} devices, only {len(devices)} visible "
            f"(hint: XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"forces a CPU host to expose {n})")
    dev_array = np.array(devices[:n]).reshape(tuple(sizes.values()))
    return jax.sharding.Mesh(dev_array, tuple(sizes))


def describe_mesh(mesh: Optional[jax.sharding.Mesh]) -> Dict[str, object]:
    """JSON-friendly mesh provenance for stats()/bench artifacts."""
    if mesh is None:
        return {"devices": 1, "axes": None}
    return {"devices": int(mesh.size),
            "axes": {name: int(mesh.shape[name]) for name in mesh.axis_names}}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model) — the 'pod' axis is
    pure data parallelism across the slow inter-pod (DCN) domain."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small CPU mesh for in-process smoke tests (requires the host platform
    to expose data*model devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
