"""Mesh topology construction — the distribution layer's `--mesh` knob.

Functions (not module-level constants) so importing this module never
touches jax device state — required for the XLA_FLAGS trick in dryrun.py.

``build_mesh`` is the single entry point every launcher/engine/benchmark
uses to turn a ``--mesh`` flag into a :class:`jax.sharding.Mesh`:

  * ``"data=4,model=2"``  — explicit axis sizes (the paper's tuning-table
    discipline applied to topology: one spec string, zero model edits);
  * ``"auto"``            — all visible devices on the ``data`` axis;
  * ``None`` / ``""``     — no mesh (single-device execution).

CI exercises multi-device meshes on a CPU host via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import os
import warnings
from typing import Dict, Optional, Tuple

import jax
import numpy as np

#: axis names the sharding rules understand (distributed/sharding.py)
MESH_AXES = ("pod", "data", "model")


def mesh_axis_label(mesh: Optional[jax.sharding.Mesh]) -> Optional[str]:
    """Compact topology label for keys/filenames: ``"data4xmodel2"``.

    This is the mesh coordinate of mesh-keyed tuned entries
    (``registry.mesh_hardware_key``) and of the per-mesh benchmark baseline
    filenames, so the same string means the same topology everywhere.
    None (no mesh) stays None.
    """
    if mesh is None:
        return None
    return "x".join(f"{name}{int(mesh.shape[name])}" for name in mesh.axis_names)


def _backends_initialized() -> bool:
    """True once jax has instantiated a backend (XLA_FLAGS edits are moot)."""
    try:
        from jax._src import xla_bridge
        return bool(xla_bridge.backends_are_initialized())
    except Exception:   # pragma: no cover - private-API drift
        return True     # can't tell -> assume too late, never lie "applied"


def apply_latency_hiding_flags(hardware: Optional[str] = None,
                               ) -> Dict[str, object]:
    """Append the hardware profile's latency-hiding XLA flags to XLA_FLAGS.

    XLA reads ``XLA_FLAGS`` once at backend init, so this only works before
    jax has built a backend — launchers call it (via :func:`build_mesh`)
    before touching devices.  Flags already present (user override) are left
    alone; if the backend is already live the call warns and applies
    nothing.  Returns provenance for stats/bench artifacts:
    ``{"hardware", "applied": [...], "skipped": [...]}``.
    """
    from repro.core.hardware import resolve_hardware, find_profile
    name = resolve_hardware(hardware)
    prof = find_profile(name)
    flags: Tuple[str, ...] = prof.xla_latency_flags if prof else ()
    current = os.environ.get("XLA_FLAGS", "")
    applied, skipped = [], []
    missing = [f for f in flags if f.split("=")[0] not in current]
    skipped += [f for f in flags if f.split("=")[0] in current]
    if missing and _backends_initialized():
        warnings.warn(
            "jax backend already initialized; latency-hiding XLA flags for "
            f"{name!r} cannot take effect this process: {missing}",
            stacklevel=2)
        skipped += missing
        missing = []
    if missing:
        os.environ["XLA_FLAGS"] = " ".join(filter(None, [current] + missing))
        applied = missing
    return {"hardware": name, "applied": applied, "skipped": skipped}


def parse_mesh_spec(spec: str) -> Dict[str, int]:
    """``"data=4,model=2"`` -> ``{"data": 4, "model": 2}`` (order kept).

    Axis names must come from :data:`MESH_AXES` (the vocabulary
    ``rules_for_mesh`` maps logical axes onto); sizes must be >= 1.
    """
    out: Dict[str, int] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(
                f"bad mesh spec {spec!r}: expected axis=size, got {part!r}")
        name, _, size_s = part.partition("=")
        name = name.strip()
        if name not in MESH_AXES:
            raise ValueError(
                f"bad mesh spec {spec!r}: unknown axis {name!r} "
                f"(choose from {', '.join(MESH_AXES)})")
        if name in out:
            raise ValueError(f"bad mesh spec {spec!r}: duplicate axis {name!r}")
        try:
            size = int(size_s)
        except ValueError:
            raise ValueError(
                f"bad mesh spec {spec!r}: size of {name!r} is not an int")
        if size < 1:
            raise ValueError(
                f"bad mesh spec {spec!r}: size of {name!r} must be >= 1")
        out[name] = size
    if not out:
        raise ValueError(f"bad mesh spec {spec!r}: no axes")
    return out


def build_mesh(spec: Optional[str], *, devices=None,
               hardware: Optional[str] = None) -> Optional[jax.sharding.Mesh]:
    """Build a Mesh from a ``--mesh`` spec string (None/"" -> no mesh).

    ``"auto"`` puts every visible device on the ``data`` axis.  An explicit
    spec may use a *subset* of the visible devices (the first ``prod(sizes)``
    in ``jax.devices()`` order), so ``data=2`` works on an 8-device host.

    Passing ``hardware`` applies that profile's latency-hiding XLA flags
    *before* the first device touch (the ``jax.devices()`` below is usually
    what initializes the backend), so a launcher gets async collectives by
    building its mesh — no flag plumbing of its own.
    """
    if not spec:
        return None
    if hardware is not None:
        apply_latency_hiding_flags(hardware)
    devices = list(devices if devices is not None else jax.devices())
    if spec.strip() == "auto":
        sizes = {"data": len(devices)}
    else:
        sizes = parse_mesh_spec(spec)
    n = int(np.prod(list(sizes.values())))
    if n > len(devices):
        raise ValueError(
            f"mesh {spec!r} needs {n} devices, only {len(devices)} visible "
            f"(hint: XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"forces a CPU host to expose {n})")
    dev_array = np.array(devices[:n]).reshape(tuple(sizes.values()))
    return jax.sharding.Mesh(dev_array, tuple(sizes))


def describe_mesh(mesh: Optional[jax.sharding.Mesh]) -> Dict[str, object]:
    """JSON-friendly mesh provenance for stats()/bench artifacts."""
    if mesh is None:
        return {"devices": 1, "axes": None, "label": None}
    return {"devices": int(mesh.size),
            "axes": {name: int(mesh.shape[name]) for name in mesh.axis_names},
            "label": mesh_axis_label(mesh)}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model) — the 'pod' axis is
    pure data parallelism across the slow inter-pod (DCN) domain."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small CPU mesh for in-process smoke tests (requires the host platform
    to expose data*model devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
