"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the XLA_FLAGS trick in dryrun.py.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model) — the 'pod' axis is
    pure data parallelism across the slow inter-pod (DCN) domain."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small CPU mesh for in-process smoke tests (requires the host platform
    to expose data*model devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
