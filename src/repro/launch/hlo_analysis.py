"""HLO-text analysis: collective-operand byte accounting for the roofline.

``cost_analysis()`` has no collective term, so we parse the post-SPMD HLO
(``compiled.as_text()``) and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
The result shape is the canonical proxy for bytes crossing links per device
(ring all-gather: each device receives ~the full gathered buffer; all-reduce
~2x this — we record the op breakdown so either convention can be applied).
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# one shape token, e.g. bf16[256,4096]{1,0} or f32[]
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# lhs of a collective instruction: "%name = <shape-or-tuple> <op>("
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(COLLECTIVE_OPS) + r")(?:-start|-done)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """-> {op_name: summed result bytes} + {"total": ..., "count": ...}.

    ``-start`` variants are counted, ``-done`` skipped (same buffer).
    all-gather-start results can be tuples (operand, result); counting the
    tuple is the conservative upper bound.
    """
    out: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    count = 0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if f"{m.group(2)}-done(" in line:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
        count += 1
    out["total"] = sum(out[op] for op in COLLECTIVE_OPS)
    out["count"] = count
    return out


def op_histogram(hlo_text: str, ops=("fusion", "dot", "convolution",
                                     "dynamic-update-slice", "reshape",
                                     "transpose", "scatter", "gather")) -> Dict[str, int]:
    """Rough occurrence counts — used to spot remat duplication / layout churn."""
    hist = {}
    for op in ops:
        hist[op] = len(re.findall(rf"\b{op}\(", hlo_text))
    return hist
