"""Batched serving engine: continuous prefill + decode with a static KV cache.

Simple but production-shaped: fixed-capacity batch slots, greedy or
temperature sampling, per-request stop handling, jit'd prefill/decode steps
reused across requests (no recompilation per request).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    temperature: float = 0.0          # 0 => greedy
    eos_token: Optional[int] = None
    seed: int = 0


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.cfg.temperature, axis=-1)

    def generate(self, prompts: List[List[int]], max_new_tokens: int,
                 extra_inputs: Optional[Dict[str, jax.Array]] = None
                 ) -> List[List[int]]:
        """Batched generation.  Prompts are right-aligned padded to a common
        length (static shapes => one compilation)."""
        cfg = self.cfg
        assert len(prompts) <= cfg.max_batch
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((b, plen), np.int32)
        for i, p in enumerate(prompts):  # left-pad with repeats of first token
            toks[i, plen - len(p):] = p
            toks[i, :plen - len(p)] = p[0]

        batch = {"tokens": jnp.asarray(toks)}
        if extra_inputs:
            batch.update(extra_inputs)

        cache = self.model.init_cache(b, plen + max_new_tokens)
        logits, cache = self._prefill(self.params, batch, cache)

        key = jax.random.PRNGKey(cfg.seed)
        outs = [[] for _ in range(b)]
        done = np.zeros(b, bool)
        offset = jnp.int32(plen)
        cur = self._sample(logits, key)
        for step in range(max_new_tokens):
            cur_np = np.asarray(jax.device_get(cur))
            for i in range(b):
                if not done[i]:
                    outs[i].append(int(cur_np[i]))
                    if cfg.eos_token is not None and cur_np[i] == cfg.eos_token:
                        done[i] = True
            if done.all() or step == max_new_tokens - 1:
                break
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cur[:, None], cache, offset)
            offset = offset + 1
            cur = self._sample(logits, sub)
        return outs
