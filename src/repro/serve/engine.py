"""Device-resident continuous-batching serve engine.

Production-shaped serving over a fixed pool of ``max_batch`` KV-cache slots,
with two schedulers sharing one model path:

* **Continuous (default)** — true continuous batching over a *paged* KV
  cache: capacity is measured in tokens, finished rows are evicted at chunk
  boundaries mid-decode, and queued requests are prefilled and admitted into
  freed slots without restarting the fused loop.  Each live request holds a
  block table over fixed-size pages (``page_size`` is the tuned
  ``paged_attn`` knob); per decode chunk the engine gathers every row's KV
  into a dense right-aligned view, runs the same fused loop the wave path
  runs, and scatters the chunk's new KV columns back to their pages — so
  the model source never sees a page table and token-for-token parity with
  the wave engine holds by construction.  Host bookkeeping (allocator,
  block tables, FIFO admission, youngest-first preemption) lives in
  :mod:`repro.serve.kv_pages`.
* **Wave (``ServeConfig(scheduler="wave")``)** — requests are admitted into
  free slots and evicted on completion; the KV cache is allocated once per
  engine and reused across ``generate`` calls (stale entries are never
  attended thanks to per-slot ``kv_start``/length masking).  More requests
  than slots are served in successive waves.  Attention-free (pure SSM) and
  int8-quantized caches always take this path.
* **Fused decode loop** — a single ``jax.lax.while_loop`` carries tokens,
  per-slot done flags, per-slot token budgets, EOS checks, the sampling key
  and the KV cache entirely on device.  Exactly ONE ``jax.device_get`` per
  decode wave — i.e. per ``generate`` call whenever the batch fits the slot
  pool — fetches the finished token buffer; no per-token host round-trips.
* **Ragged batches** — prompts are right-aligned (left-padded); the per-slot
  pad offset ``kv_start`` is threaded through the model so attention masks
  pad columns, RoPE/learned positions restart at each row's first real
  token, and SSM blocks zero pad contributions.  Each row therefore decodes
  exactly what it would decode alone.
* **Tuned tiles** — the decode step's GEMM shapes are traced once and
  resolved against the global tile registry; the lookup provenance
  (exact/nearest/generic/default) is surfaced in :meth:`Engine.stats`.
* **Meshes** — ``ServeConfig(mesh="data=4,model=2")`` (or an ambient
  ``distributed.ctx.use_mesh``) shards params, KV-cache slots and the batch
  by the ``ShardingRules`` of the mesh — the distribution layer's analogue
  of the paper's tuning table: the same engine source serves one chip or a
  pod, selected by a spec string.  Tuned-tile lookups are then keyed on the
  per-shard *local* GEMM shapes (TP changes which tuned entry is hit), and
  :meth:`Engine.stats` reports mesh/sharding provenance.

* **Prefix cache** — continuous engines reuse prefilled prompt KV across
  requests (:mod:`repro.serve.prefix_cache`): a trie of page-sized token
  chunks pins pages in the allocator with refcounts.  A full-prompt hit
  skips admission prefill entirely (shared read-only pages + one
  copy-on-write page at the divergence point + a cached logits/fixed-state
  snapshot — bit-exact under greedy decoding); a page-aligned partial hit
  shares the prefix pages and redirects the re-run prefill's shared-column
  writes to the TRASH page.  Eviction is LRU under pool pressure and always
  yields before live rows are preempted.
* **Typed API** — :mod:`repro.serve.api`: ``submit(Request) ->
  RequestHandle`` and ``run() -> List[GenerationResult]`` with per-request
  timing, finish reasons, prefix provenance and per-token ``stream``
  callbacks fired at each decode-chunk boundary.  The legacy positional
  ``submit(prompt, n)`` / ``{rid: tokens}`` surface still works behind one
  ``DeprecationWarning`` per process.

Prompt lengths are bucketed to powers of two (min 8, clamped so the bucket
plus the wave's decode budget never exceeds ``max_len``) so a wave and a
lone prompt in the same bucket share one compiled prefill *and* take
bit-identical float paths — the basis of the ragged-batch parity guarantee.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged import paged_copy, paged_gather, paged_scatter
from repro.models.model import Model
from repro.serve import api
from repro.serve.stats_schema import SCHEMA_VERSION

_PLEN_BUCKET_MIN = 8

#: one DeprecationWarning per process for the legacy submit()/run() surface
_LEGACY_SUBMIT_WARNED = False

#: per-request latency records kept for percentile stats
_LATENCY_WINDOW = 4096


def _percentiles(xs: List[float]) -> Dict[str, Optional[float]]:
    if not xs:
        return {"p50": None, "p95": None, "p99": None}
    q = np.percentile(np.asarray(xs, np.float64), [50.0, 95.0, 99.0])
    return {"p50": float(q[0]), "p95": float(q[1]), "p99": float(q[2])}


def _bucket_len(n: int, cap: Optional[int] = None) -> int:
    """Smallest power-of-two bucket >= ``n``, clamped to ``cap``.

    The clamp keeps near-capacity buckets inside the KV-slot capacity
    instead of overshooting ``max_len`` and forcing callers back to exact
    (per-length-recompiling) sizes.  When ``cap < n`` the cap itself is
    returned (< n) and the caller must fall back to exact sizing.
    """
    b = _PLEN_BUCKET_MIN
    while b < n:
        b *= 2
    if cap is not None and b > cap:
        b = cap
    return b


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8                # KV-cache slots
    max_len: int = 512                # per-slot cache capacity (prompt + new)
    temperature: float = 0.0          # 0 => greedy
    eos_token: Optional[int] = None
    seed: int = 0
    profile: bool = False             # block after prefill to split timings
    # Hardware profile the engine tunes against (registry key).  None uses
    # the ambient execution context's resolution: explicit override >
    # $REPRO_HARDWARE > jax.devices() detection.
    hardware: Optional[str] = None
    # Device mesh: a spec string ("data=4,model=2" | "auto"), a prebuilt
    # jax.sharding.Mesh, or None.  None picks up the ambient
    # distributed.ctx.use_mesh() topology (single-device when absent).
    mesh: Optional[Union[str, jax.sharding.Mesh]] = None
    # Tokens decoded per fused-loop iteration.  Every while-loop spin is a
    # cross-device sync point on a mesh, so fatter iterations hide dispatch
    # latency.  None resolves: mesh-keyed tuned entry (decode_loop in the
    # TuningDB, topology in the key) > heuristic (4 on a mesh, 1 alone).
    decode_unroll: Optional[int] = None
    # "continuous" (paged KV, admit/evict at chunk boundaries) or "wave".
    # Pure-SSM and int8-KV models silently run "wave" either way.
    scheduler: str = "continuous"
    # Paged-KV page size in tokens.  None resolves a tuned ``paged_attn``
    # entry keyed by (max_batch, max_len) + hardware + mesh label.
    page_size: Optional[int] = None
    # Paged-pool capacity in TOKENS (the continuous scheduler's admission
    # currency).  None = max_batch * max_len — the wave engine's footprint,
    # now shared by need instead of reserved per slot.
    capacity_tokens: Optional[int] = None
    # Tokens decoded per fused chunk between scheduling boundaries
    # (admission/eviction happen only at boundaries).  Power of two.
    decode_chunk: int = 8
    # Share prefilled prompt KV across requests with common prefixes
    # (continuous scheduler only; requests served with extra_inputs are
    # never cached — their extras aren't part of the content key).
    prefix_cache: bool = True


@dataclasses.dataclass
class _Request:
    rid: int
    prompt: List[int]
    max_new: int
    row: Optional[int] = None         # row in the shared extra_inputs arrays
    slot: Optional[int] = None
    tokens: Optional[List[int]] = None
    # -- typed-API bookkeeping ------------------------------------------
    legacy: bool = False              # submitted via the deprecated surface
    handle: Optional[api.RequestHandle] = None
    stream: Optional[Callable[[api.StreamEvent], None]] = None
    result: Optional[api.GenerationResult] = None
    finish_reason: Optional[str] = None
    t_submit: float = 0.0
    t_first: Optional[float] = None   # first token host-visible (TTFT end)
    prefix_hit: Optional[str] = None  # "full" | "partial" | None
    cached_prefix_tokens: int = 0


class _SlotScheduler:
    """Admit/evict bookkeeping over the fixed pool of KV-cache slots."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._free = list(range(n_slots))
        self._use_count = [0] * n_slots
        self.admitted = 0
        self.evicted = 0

    def admit(self, req: _Request) -> int:
        if not self._free:
            raise RuntimeError("no free KV-cache slot")
        slot = self._free.pop(0)
        req.slot = slot
        self._use_count[slot] += 1
        self.admitted += 1
        return slot

    def evict(self, req: _Request) -> None:
        self._free.append(req.slot)
        self._free.sort()
        self.evicted += 1

    @property
    def reuses(self) -> int:
        return sum(max(c - 1, 0) for c in self._use_count)


class Engine:
    """Continuous-batching engine over a fixed slot pool.

    ``generate`` is the batched entry point; ``submit``/``run`` expose the
    underlying request queue for callers that stream requests in.
    """

    def __init__(self, model: Model, params, cfg: ServeConfig):
        from repro.core import current_hardware
        from repro.core.hardware import find_profile, resolve_hardware
        self.model = model
        self.params = params
        self.cfg = cfg
        # Resolved once at engine construction so every tile lookup (and the
        # stats provenance) is pinned to one profile for the engine's life.
        self.hardware = (resolve_hardware(cfg.hardware) if cfg.hardware
                         else current_hardware())
        prof = find_profile(self.hardware)
        self._platform = prof.platform if prof else "unknown"
        # Mesh topology: explicit config > ambient use_mesh() > single-device.
        # Resolved once, like the hardware profile — one engine, one mesh.
        from repro.distributed import ctx as dctx
        mesh, rules = cfg.mesh, None
        if mesh is None:
            mesh, rules = dctx.current_mesh(), dctx.current_rules()
        if isinstance(mesh, str):
            from repro.launch.mesh import build_mesh
            mesh = build_mesh(mesh)
        self.mesh = mesh
        self.rules = None
        if mesh is not None:
            from repro.distributed import sharding as sh
            # Inference rules: no FSDP.  Training shards weights over the
            # data axes and re-gathers them per step — amortized over a big
            # batch.  Decode GEMMs are tiny (B x 1 tokens), so per-step
            # weight all-gathers SERIALIZE the loop (profiling showed them
            # dominating decode wall-clock at 0.54x of the sync baseline).
            # Serving therefore replicates weights over the data axes and
            # shards them only over the tensor axis (classic inference TP);
            # explicit ambient rules still win for callers that know better.
            self.rules = rules or sh.rules_for_mesh(mesh, fsdp=False)
            # Re-place params by the rules (no-op layout change on values:
            # sharded and single-device engines stay token-for-token equal).
            self.params = sh.shard_params(params, mesh, self.rules,
                                          model.template)
        self._prefill = jax.jit(self._with_mesh(model.prefill))
        self._loop = None                 # built lazily (per-engine closure)
        self._unroll: Optional[int] = None         # resolved lazily, cached
        self._unroll_source: Optional[str] = None
        self._cache = None                # allocated once, reused across calls
        self._sched = _SlotScheduler(cfg.max_batch)
        self._queue: List[_Request] = []
        self._next_rid = 0
        self._tile_lookups: Optional[Dict[str, Dict[str, object]]] = None
        self._prefill_flash_lookups: Dict[str, Dict[str, object]] = {}
        self._plen_buckets: set = set()
        # -- continuous-batching state (paged KV pool) -------------------
        if cfg.scheduler not in ("continuous", "wave"):
            raise ValueError(f"unknown scheduler {cfg.scheduler!r}; "
                             f"expected 'continuous' or 'wave'")
        chunk = int(cfg.decode_chunk)
        if chunk < 1 or chunk & (chunk - 1):
            raise ValueError(
                f"decode_chunk must be a power of two >= 1, got {chunk}")
        self._chunk = chunk
        self._scheduler = cfg.scheduler
        self._scheduler_forced: Optional[str] = None
        if cfg.scheduler == "continuous":
            # The paged pool holds "self"-attention KV; models without one
            # (pure SSM) or with a quantized {q, s} cache layout keep the
            # dense wave path — transparently, so callers never branch.
            if model.cfg.family == "ssm":
                self._scheduler = "wave"
                self._scheduler_forced = "no self-attention KV cache"
            elif model.cfg.kv_quant:
                self._scheduler = "wave"
                self._scheduler_forced = "int8-quantized KV cache"
        self._capacity_tokens = int(cfg.capacity_tokens
                                    or cfg.max_batch * cfg.max_len)
        self._page_size: Optional[int] = None
        self._page_size_source: Optional[str] = None
        self._alloc = None                # PageAllocator (continuous only)
        self._csched = None               # ContinuousScheduler
        self._pools = None                # paged "self" KV leaves (flat)
        self._fixed = None                # resident non-paged cache leaves
        self._cur = None                  # (max_batch,) next-token register
        self._scratch: Dict[int, object] = {}   # admission prefill caches
        self._chunk_fn = None             # jitted fused chunk (lazily built)
        self._admit_fn = None             # jitted prefill+insert
        self._copy_fn = None              # jitted COW page copy
        self._prefix = None               # PrefixCache (continuous only)
        # Server-mode ingestion: a callable polled at every chunk/wave
        # boundary yielding (api.Request, RequestHandle) pairs submitted
        # mid-drain (see repro.serve.server.Server).
        self._ingest_hook: Optional[Callable] = None
        self._lat_ttft: List[float] = []  # finished-request TTFT records
        self._lat_tok: List[float] = []   # finished-request tok/s records
        self._stats: Dict[str, float] = {
            "requests": 0, "tokens_generated": 0, "generate_calls": 0,
            "waves": 0, "chunks": 0, "admission_prefills": 0,
            "device_transfers": 0, "cache_allocs": 0,
            "prefill_seconds": 0.0, "decode_seconds": 0.0,
            "total_seconds": 0.0,
        }

    # -- mesh plumbing --------------------------------------------------
    def _with_mesh(self, fn):
        """Wrap ``fn`` so tracing happens under this engine's activation
        policy (``constrain`` pins residual/logits layouts to the mesh).
        Identity when the engine is single-device."""
        if self.mesh is None:
            return fn
        mesh, rules = self.mesh, self.rules

        def wrapped(*args, **kwargs):
            from repro.distributed.ctx import activation_policy
            with activation_policy(mesh, rules):
                return fn(*args, **kwargs)

        return wrapped

    def _place_batch(self, tree):
        """Shard leading-batch-dim arrays over the data axes (no-op
        single-device).  Values are unchanged — only the layout."""
        if self.mesh is None:
            return tree
        from repro.distributed import sharding as sh
        return jax.device_put(
            tree, sh.batch_shardings(self.mesh, self.rules, tree))

    # -- sampling ------------------------------------------------------
    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    # -- fused device-resident decode loop -----------------------------
    def _resolve_unroll(self) -> int:
        """Tokens decoded per fused-loop iteration.

        Resolution: explicit ``ServeConfig.decode_unroll`` > a mesh-keyed
        ``decode_loop`` tuned entry (the topology is part of the op key, so
        ``data=4,model=2`` can tune a different unroll than a single chip) >
        the heuristic (4 on a mesh — spin sync points are collectives there
        — else 1).  The resolved value and its provenance land in
        :meth:`stats` as ``decode_unroll`` / ``decode_unroll_source``.
        """
        if self._unroll is not None:
            return self._unroll
        if self.cfg.decode_unroll is not None:
            self._unroll = max(int(self.cfg.decode_unroll), 1)
            self._unroll_source = "config"
        else:
            from repro.core.registry import GLOBAL_REGISTRY, OP_DECODE_LOOP
            from repro.launch.mesh import mesh_axis_label
            res = GLOBAL_REGISTRY.lookup_op(
                OP_DECODE_LOOP, self.hardware, self.model.cfg.dtype,
                (self.cfg.max_batch, self.cfg.max_len),
                mesh=mesh_axis_label(self.mesh))
            if res.source in ("exact", "nearest", "generic"):
                self._unroll = max(int(res.config.unroll), 1)
                self._unroll_source = f"tuned:{res.source}"
            else:
                self._unroll = 4 if self.mesh is not None else 1
                self._unroll_source = "heuristic"
        return self._unroll

    def _build_loop(self):
        decode = self.model.decode_step
        eos = self.cfg.eos_token

        def loop(params, cache, logits0, key, kv_start, budget, offset0, *,
                 width: int, unroll: int):
            b = logits0.shape[0]
            # Split BEFORE the first sample: the parent key is reserved for
            # splitting only, so the first token is uncorrelated with later
            # ones.
            key, sub = jax.random.split(key)
            cur = self._sample(logits0, sub)
            done = budget <= 0                 # empty slots start finished
            buf = jnp.zeros((b, width), jnp.int32)
            lens = jnp.zeros((b,), jnp.int32)

            # ``alldone`` rides in the carry so the while cond is a plain
            # scalar read.  Evaluating ``done.all()`` inside cond (and again
            # inside body's predicate) costs a cross-device reduction per
            # spin when ``done`` picks up a batch sharding — two extra
            # blocking collectives per token that serialize the mesh decode
            # loop.  Computing it ONCE per body and carrying the scalar
            # keeps every control decision local.
            def cond(carry):
                step, cur, done, alldone, buf, lens, cache, offset, key = carry
                return (step < width) & ~alldone

            def body(carry):
                step, cur, done, alldone, buf, lens, cache, offset, key = carry
                # Unrolled body: each while iteration records + decodes
                # ``unroll`` tokens.  Every loop spin is a cross-device sync
                # point on a mesh (cond broadcast + per-device dispatch), so
                # fewer, fatter iterations hide that latency behind compute;
                # done/budget bookkeeping stays exact per token via the
                # masked buffer writes.
                for _ in range(unroll):
                    with jax.named_scope("decode_token"):
                        buf = jax.lax.dynamic_update_slice(
                            buf, jnp.where(done, 0, cur)[:, None], (0, step))
                        lens = lens + jnp.where(done, 0, 1).astype(jnp.int32)
                        if eos is not None:
                            done = done | (cur == eos)
                        done = done | (lens >= budget)
                        alldone = done.all()
                        step = step + 1

                        def advance(op):
                            cache, cur, key, offset = op
                            key, sub = jax.random.split(key)
                            logits, cache = decode(params, cur[:, None],
                                                   cache, offset, kv_start)
                            return (cache, self._sample(logits, sub), key,
                                    offset + 1)

                        # Skip the model step once every live slot finished.
                        cache, cur, key, offset = jax.lax.cond(
                            (step < width) & ~alldone, advance, lambda op: op,
                            (cache, cur, key, offset))
                return (step, cur, done, alldone, buf, lens, cache, offset,
                        key)

            carry = (jnp.int32(0), cur, done, done.all(), buf, lens, cache,
                     offset0, key)
            _, _, _, _, buf, lens, cache, _, _ = jax.lax.while_loop(
                cond, body, carry)
            return buf, lens, cache

        return jax.jit(self._with_mesh(loop),
                       static_argnames=("width", "unroll"))

    # -- slot-pool cache -----------------------------------------------
    def _ensure_cache(self):
        if self._cache is None:
            cache = self.model.init_cache(self.cfg.max_batch,
                                          self.cfg.max_len)
            if self.mesh is not None:
                # Shard the slot pool itself: batch over the data axes,
                # heads (or cache sequence, for GQA) over the tensor axis.
                from repro.distributed import sharding as sh
                cache = jax.device_put(
                    cache, sh.cache_shardings(self.mesh, self.rules, cache))
            self._cache = cache
            self._stats["cache_allocs"] += 1
            self._trace_decode_tiles()
        return self._cache

    def _trace_decode_tiles(self) -> None:
        """Abstractly trace one decode step, resolve its GEMM shapes against
        the tuned-tile registry, and record the lookup provenance.

        On a mesh the traced shapes are *global*; what each shard actually
        runs is the local GEMM — batch split over the data axes, weight dims
        split per the sharding rules — so the registry lookup is keyed on
        the local shape (TP therefore changes which tuned entry is hit).
        Both shapes are recorded in the provenance.
        """
        from repro.core import capture_gemm_shapes
        from repro.core.registry import GLOBAL_REGISTRY
        b = self.cfg.max_batch
        tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        off = jax.ShapeDtypeStruct((), jnp.int32)
        ks = jax.ShapeDtypeStruct((b,), jnp.int32)
        cache = self._cache
        if cache is None:      # continuous engines never build a dense pool
            cache = jax.eval_shape(
                lambda: self.model.init_cache(b, self.cfg.max_len))
        try:
            with capture_gemm_shapes() as shapes:
                jax.eval_shape(self.model.decode_step, self.params, tok,
                               cache, off, ks)
        except Exception:      # provenance is telemetry, never fatal
            self._tile_lookups = {}
            return
        weight_div, batch_div = {}, 1
        if self.mesh is not None:
            from repro.distributed import sharding as sh
            weight_div = sh.local_gemm_divisors(self.mesh, self.rules,
                                                self.model.template)
            batch_div = sh.axis_size(self.mesh, self.rules.batch_axes)
        from repro.core.registry import OP_GEMM
        from repro.launch.mesh import mesh_axis_label
        mesh_label = mesh_axis_label(self.mesh)
        hw = self.hardware
        dtype = self.model.cfg.dtype
        lookups = {}
        for (m, k, n) in sorted(set(shapes)):
            # distinct weights can shard one global (K, N) differently
            # (e.g. square wq vs wo); record a lookup per local variant
            for dk, dn in weight_div.get((k, n), ((1, 1),)):
                lm = m // batch_div if m % batch_div == 0 else m
                lk, ln = k // dk, n // dn
                res = GLOBAL_REGISTRY.lookup_op(OP_GEMM, hw, dtype,
                                                (lm, lk, ln), mesh=mesh_label)
                entry = {
                    "source": res.source,
                    "tile": res.config.label,
                    "matched_shape": res.matched_shape,
                }
                key = f"{m}x{k}x{n}"
                if self.mesh is not None:
                    entry["local_shape"] = f"{lm}x{lk}x{ln}"
                    entry["mesh"] = res.mesh
                    if len(weight_div.get((k, n), ())) > 1:
                        key = f"{m}x{k}x{n}->{lm}x{lk}x{ln}"
                lookups[key] = entry
        self._tile_lookups = lookups

    def _record_prefill_flash_tiles(self, plen: int) -> None:
        """Resolve the tuned flash-attention blocks this prefill bucket uses
        and record the lookup provenance (mirrors the decode GEMM trace).

        The model path performs the same lookup inside ``layers.attention``
        (via :func:`repro.core.attention_api.flash_attention`); re-resolving
        here keeps the telemetry identical without threading state through
        jitted code.
        """
        cfg = self.model.cfg
        if cfg.attention_impl != "flash" or not cfg.num_heads:
            return
        key = f"{plen}x{plen}x{cfg.resolved_head_dim}"
        if key in self._prefill_flash_lookups:
            return
        from repro.core.attention_api import flash_tile_lookup
        res = flash_tile_lookup(self.hardware, cfg.dtype, plen, plen,
                                cfg.resolved_head_dim)
        self._prefill_flash_lookups[key] = {
            "source": res.source,
            "tile": res.config.label,
            "matched_shape": res.matched_shape,
        }

    # -- paged KV pool (continuous scheduler) ----------------------------
    def _resolve_page_size(self) -> None:
        """Page size (tokens) for the paged pool: explicit config > tuned
        ``paged_attn`` entry keyed by (max_batch, max_len) + hardware +
        mesh label > registry fallback.  Provenance lands in stats()."""
        if self._page_size is not None:
            return
        if self.cfg.page_size is not None:
            page = max(int(self.cfg.page_size), 1)
            self._page_size_source = "config"
        else:
            from repro.core.registry import GLOBAL_REGISTRY, OP_PAGED_ATTN
            from repro.launch.mesh import mesh_axis_label
            res = GLOBAL_REGISTRY.lookup_op(
                OP_PAGED_ATTN, self.hardware, self.model.cfg.dtype,
                (self.cfg.max_batch, self.cfg.max_len),
                mesh=mesh_axis_label(self.mesh))
            page = max(int(res.config.page_size), 1)
            self._page_size_source = (
                f"tuned:{res.source}"
                if res.source in ("exact", "nearest", "generic")
                else res.source)
        self._page_size = min(page, self._capacity_tokens)

    def _ensure_pool(self):
        """Allocate the paged pool once per engine: flat token-axis buffers
        for every "self" KV leaf plus a resident tree for the fixed-size
        leaves (cross-KV, SSM/conv states) that admission row-scatters."""
        if self._pools is not None:
            return
        from repro.serve import kv_pages
        self._resolve_page_size()
        self._alloc = kv_pages.PageAllocator(self._capacity_tokens,
                                             self._page_size)
        self._csched = kv_pages.ContinuousScheduler(self.cfg.max_batch,
                                                    self._alloc)
        npp = self._alloc.num_pages * self._page_size
        template = self.model.init_cache(self.cfg.max_batch, 1)

        def pool_leaf(leaf):
            # (lead..., B, 1, kvh, hd) -> (lead..., num_pages*page, kvh, hd)
            return jnp.zeros(leaf.shape[:-4] + (npp,) + leaf.shape[-2:],
                             leaf.dtype)

        pools = jax.tree_util.tree_map(pool_leaf, template["self"])
        fixed = {k: v for k, v in template.items() if k != "self"}
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            from repro.distributed import sharding as sh
            ta = self.rules.tensor_axis

            def pool_sharding(x):
                # no batch dim on the flat pool: shard KV heads over the
                # tensor axis when divisible, replicate otherwise
                spec = [None] * x.ndim
                if ta and x.shape[-2] % sh.axis_size(self.mesh, ta) == 0:
                    spec[x.ndim - 2] = ta
                return NamedSharding(self.mesh, P(*spec))

            pools = jax.device_put(
                pools, jax.tree_util.tree_map(pool_sharding, pools))
            if fixed:
                fixed = jax.device_put(
                    fixed, sh.cache_shardings(self.mesh, self.rules, fixed))
        self._pools, self._fixed = pools, fixed
        self._cur = jnp.zeros((self.cfg.max_batch,), jnp.int32)
        if self.cfg.prefix_cache:
            from repro.serve.prefix_cache import PrefixCache
            self._prefix = PrefixCache(self._alloc)
            # Under pool pressure the scheduler reclaims cache-pinned pages
            # (LRU) before preempting live rows.
            self._csched.reclaim = self._prefix.reclaim
        self._stats["cache_allocs"] += 1
        self._trace_decode_tiles()

    def _scratch_cache(self, plen: int):
        """Admission prefill cache for one plen bucket, reused across
        admissions: prefill fully overwrites its "self" columns [0, plen)
        and recomputes every fixed leaf, so stale contents never leak."""
        cache = self._scratch.get(plen)
        if cache is None:
            cache = self.model.init_cache(self.cfg.max_batch, plen)
            if self.mesh is not None:
                from repro.distributed import sharding as sh
                cache = jax.device_put(
                    cache, sh.cache_shardings(self.mesh, self.rules, cache))
            self._scratch[plen] = cache
        return cache

    @staticmethod
    def _scatter_fixed(fixed, new, slot_map):
        """Row-scatter ``new``'s admitted rows into the resident fixed tree
        along each leaf's batch dim (kind-aware: cross-KV at -4, SSM state
        at -4, conv state at -3).  ``slot_map`` pads with an out-of-range
        index, which JAX gathers clamp and scatters drop."""
        kinds = {"cross": "kv", "ssm": "ssm", "conv": "conv"}

        def walk(old, upd, kind=None):
            if isinstance(old, dict):
                return {k: walk(old[k], upd[k], kinds.get(k, kind))
                        for k in old}
            if isinstance(old, (tuple, list)):
                return type(old)(walk(o, u, kind)
                                 for o, u in zip(old, upd))
            bd = old.ndim - (3 if kind == "conv" else 4)
            o2 = jnp.moveaxis(old, bd, 0)
            u2 = jnp.moveaxis(upd, bd, 0)
            return jnp.moveaxis(o2.at[slot_map].set(u2[slot_map]), 0, bd)

        return walk(fixed, new)

    def _build_admit_fn(self):
        """Jitted admission: one full-batch prefill into the plen-bucket
        scratch cache, prompt KV scattered to its pages, fixed leaves
        row-scattered to their slots, first token sampled into ``cur``.
        Compiles once per plen bucket (shapes carry the key)."""
        prefill = self.model.prefill

        def admit_fn(params, batch, scratch, pools, fixed, cur, key,
                     dest_idx, slot_map):
            logits0, filled = prefill(params, batch, scratch)
            pools_out = jax.tree_util.tree_map(
                lambda pool, src: paged_scatter(pool, dest_idx, src),
                pools, filled["self"])
            fixed_out = self._scatter_fixed(
                fixed, {k: filled[k] for k in fixed}, slot_map)
            # Split BEFORE the first sample (wave-loop key discipline).
            key, sub = jax.random.split(key)
            first = self._sample(logits0, sub)
            cur_out = cur.at[slot_map].set(first[slot_map])
            # logits0 rides out so admission can snapshot each admitted
            # row's last-position logits into the prefix cache.
            return pools_out, fixed_out, cur_out, key, logits0

        return jax.jit(self._with_mesh(admit_fn))

    # -- prefix-cache device plumbing ------------------------------------
    @staticmethod
    def _walk_fixed(tree, fn, kind=None):
        """Apply ``fn(leaf, kind)`` over a fixed-cache tree with the same
        kind resolution ``_scatter_fixed`` uses (cross-KV / SSM state at
        batch dim -4, conv state at -3)."""
        kinds = {"cross": "kv", "ssm": "ssm", "conv": "conv"}
        if isinstance(tree, dict):
            return {k: Engine._walk_fixed(v, fn, kinds.get(k, kind))
                    for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            return type(tree)(Engine._walk_fixed(v, fn, kind) for v in tree)
        return fn(tree, kind)

    def _slice_fixed_row(self, slot: int):
        """Snapshot one slot's rows of every fixed cache leaf (the
        per-request state a full prefix hit must restore — SSM/conv state
        for hybrids; empty for pure transformers)."""
        def take(leaf, kind):
            bd = leaf.ndim - (3 if kind == "conv" else 4)
            return jnp.take(leaf, slot, axis=bd)
        return self._walk_fixed(self._fixed, take)

    def _restore_fixed_row(self, fixed, snap, slot: int):
        """Write a :meth:`_slice_fixed_row` snapshot back into ``slot``."""
        kinds = {"cross": "kv", "ssm": "ssm", "conv": "conv"}

        def walk(old, sn, kind=None):
            if isinstance(old, dict):
                return {k: walk(old[k], sn[k], kinds.get(k, kind))
                        for k in old}
            if isinstance(old, (tuple, list)):
                return type(old)(walk(o, s, kind)
                                 for o, s in zip(old, sn))
            bd = old.ndim - (3 if kind == "conv" else 4)
            moved = jnp.moveaxis(old, bd, 0)
            return jnp.moveaxis(moved.at[slot].set(sn), 0, bd)

        return walk(fixed, snap)

    def _build_copy_fn(self):
        """Jitted COW page copy: page ids are traced scalars, so every
        divergence-point copy shares one compile."""
        page = self._page_size

        def copy_fn(pools, src_page, dst_page):
            return jax.tree_util.tree_map(
                lambda pool: paged_copy(pool, src_page, dst_page, page),
                pools)

        return jax.jit(self._with_mesh(copy_fn))

    def _restore_hits(self, hits, key: jax.Array) -> jax.Array:
        """Admit full-prompt prefix hits without prefill: the row's block
        table already points at the shared pages; copy the straddling page
        (COW), restore the fixed-leaf snapshot, and sample the first token
        from the cached last-position logits (bit-identical under greedy —
        the argmax runs over the exact array the cold path sampled from)."""
        from repro.profiling import annotate
        t0 = time.perf_counter()
        page = self._page_size
        with annotate("serve.prefix_restore"):
            for req, row, entry in hits:
                if entry.tail_page is not None:
                    dst = row.pages[len(req.prompt) // page]
                    if self._copy_fn is None:
                        self._copy_fn = self._build_copy_fn()
                    self._pools = self._copy_fn(
                        self._pools, jnp.int32(entry.tail_page),
                        jnp.int32(dst))
                if self._fixed:
                    self._fixed = self._restore_fixed_row(
                        self._fixed, entry.fixed, row.slot)
                # Same key discipline as admission: split, then sample.
                key, sub = jax.random.split(key)
                first = self._sample(entry.logits0[None, :], sub)
                self._cur = self._cur.at[row.slot].set(first[0])
        self._stats["prefill_seconds"] += time.perf_counter() - t0
        return key

    def _build_chunk_fn(self):
        """Jitted fused decode chunk: gather a dense right-aligned KV view
        from the paged pool, run the wave-style fused loop for ``chunk``
        tokens, scatter the chunk's new KV columns back to their pages.

        One deliberate difference from the wave loop: the wave loop skips
        the *final* advance (nothing reads the last token's KV), while the
        chunk loop always advances while any row is live — the last emitted
        token's KV must land in the pool before the next chunk reads it,
        and the final advance's sample becomes the next chunk's first
        token (carried device-resident in ``cur``).
        """
        decode = self.model.decode_step
        eos = self.cfg.eos_token

        def chunk_fn(params, pools, fixed, cur, key, gidx, sidx, kv_start,
                     budget, *, width: int, chunk: int, unroll: int):
            view = jax.tree_util.tree_map(
                lambda pool: paged_gather(pool, gidx), pools)
            cache = dict(fixed)
            cache["self"] = view
            b = cur.shape[0]
            done = budget <= 0                 # empty slots start finished
            buf = jnp.zeros((b, chunk), jnp.int32)
            lens = jnp.zeros((b,), jnp.int32)

            def cond(carry):
                step, cur, done, alldone, buf, lens, cache, offset, key = carry
                return (step < chunk) & ~alldone

            def body(carry):
                step, cur, done, alldone, buf, lens, cache, offset, key = carry
                for _ in range(unroll):
                    with jax.named_scope("decode_token"):
                        buf = jax.lax.dynamic_update_slice(
                            buf, jnp.where(done, 0, cur)[:, None], (0, step))
                        lens = lens + jnp.where(done, 0, 1).astype(jnp.int32)
                        if eos is not None:
                            done = done | (cur == eos)
                        done = done | (lens >= budget)
                        alldone = done.all()
                        step = step + 1

                        def advance(op):
                            cache, cur, key, offset = op
                            key, sub = jax.random.split(key)
                            logits, cache = decode(params, cur[:, None],
                                                   cache, offset, kv_start)
                            return (cache, self._sample(logits, sub), key,
                                    offset + 1)

                        # No `step < chunk` guard here (see docstring): the
                        # chunk-boundary advance must run while rows live.
                        cache, cur, key, offset = jax.lax.cond(
                            ~alldone, advance, lambda op: op,
                            (cache, cur, key, offset))
                return (step, cur, done, alldone, buf, lens, cache, offset,
                        key)

            carry = (jnp.int32(0), cur, done, done.all(), buf, lens, cache,
                     jnp.int32(width - chunk), key)
            _, cur, _, _, buf, lens, cache, _, key = jax.lax.while_loop(
                cond, body, carry)
            cols = jax.tree_util.tree_map(
                lambda leaf: jax.lax.slice_in_dim(
                    leaf, width - chunk, width, axis=leaf.ndim - 3),
                cache["self"])
            pools_out = jax.tree_util.tree_map(
                lambda pool, c: paged_scatter(pool, sidx, c), pools, cols)
            fixed_out = {k: v for k, v in cache.items() if k != "self"}
            return pools_out, fixed_out, cur, key, buf, lens

        return jax.jit(self._with_mesh(chunk_fn),
                       static_argnames=("width", "chunk", "unroll"))

    # -- request queue --------------------------------------------------
    def submit(self, request, max_new_tokens: Optional[int] = None,
               row: Optional[int] = None,
               _handle: Optional[api.RequestHandle] = None):
        """Queue one generation request.

        The typed surface takes an :class:`repro.serve.api.Request` and
        returns a :class:`repro.serve.api.RequestHandle` resolved the
        moment the request finishes::

            handle = eng.submit(Request(prompt=[5, 9, 2], max_new_tokens=16))
            eng.run()
            tokens = handle.result().tokens

        The legacy positional form ``submit(prompt, max_new_tokens, row=)``
        still returns a bare request id (and makes :meth:`run` return the
        legacy ``{rid: tokens}`` dict) behind one ``DeprecationWarning``
        per process; see ``docs/SERVING.md`` for migration notes.

        ``_handle`` is internal (server mode pre-creates the handle on the
        ingestion thread).
        """
        global _LEGACY_SUBMIT_WARNED
        if isinstance(request, api.Request):
            if max_new_tokens is not None or row is not None:
                raise TypeError(
                    "submit(Request) takes no positional max_new_tokens/row "
                    "— set them on the Request")
            if (request.temperature is not None
                    and request.temperature != self.cfg.temperature):
                raise ValueError(
                    f"Request.temperature {request.temperature} != engine "
                    f"ServeConfig.temperature {self.cfg.temperature}; the "
                    f"engine compiles one sampling configuration")
            prompt = list(request.prompt)
            max_new = int(request.max_new_tokens)
            row = request.row
            stream = request.stream
            legacy = False
        else:
            if not _LEGACY_SUBMIT_WARNED:
                _LEGACY_SUBMIT_WARNED = True
                warnings.warn(
                    "Engine.submit(prompt, max_new_tokens) and the "
                    "{rid: tokens} run() return are deprecated; submit a "
                    "repro.serve.api.Request and read GenerationResult "
                    "(docs/SERVING.md has migration notes)",
                    DeprecationWarning, stacklevel=2)
            if max_new_tokens is None:
                raise TypeError(
                    "legacy submit(prompt, max_new_tokens) needs "
                    "max_new_tokens")
            prompt = list(request)
            max_new = int(max_new_tokens)
            stream = None
            legacy = True
        if not prompt:
            raise ValueError("empty prompt: each prompt needs >= 1 token")
        if max_new < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new}")
        # Per-request capacity check at enqueue time: an oversized request
        # fails fast HERE instead of bricking the batch it lands in later.
        # The continuous scheduler's capacity currency is TOKENS in the
        # paged pool (one request may exceed max_len as long as it fits the
        # pool); the wave scheduler reserves a max_len-column slot.
        if self._scheduler == "continuous":
            if len(prompt) + max_new > self._capacity_tokens:
                raise ValueError(
                    f"prompt ({len(prompt)}) + max_new ({max_new}) "
                    f"exceeds capacity_tokens ({self._capacity_tokens})")
        elif len(prompt) + max_new > self.cfg.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds "
                f"max_len ({self.cfg.max_len})")
        rid = self._next_rid
        self._next_rid += 1
        req = _Request(rid, prompt, max_new, row, legacy=legacy,
                       stream=stream, t_submit=time.perf_counter())
        if not legacy:
            handle = _handle if _handle is not None else api.RequestHandle()
            handle.request_id = rid
            req.handle = handle
        self._queue.append(req)
        self._stats["requests"] += 1
        return rid if legacy else req.handle

    def run(self, extra_inputs: Optional[Dict[str, jax.Array]] = None):
        """Drain the submitted queue.

        Requests are served by the configured scheduler (continuous paged
        batching by default; wave otherwise).  Ragged prompt lengths are
        handled by left-padding + ``kv_start`` masking.  Wave scheduling is
        *packed by capacity*: a wave's KV need is ``max(prompt) +
        max(max_new)`` over its members, so a long-prompt/small-budget
        request and a short-prompt/big-budget request that each fit on
        their own are scheduled into separate waves instead of being
        rejected together.

        Args:
          extra_inputs: optional per-request model inputs (e.g. Whisper
            ``encoder_embeds``) with leading dim indexed by each request's
            ``row=``.

        Returns:
          ``List[GenerationResult]`` in request-id order — unless any
          drained request came through the deprecated positional
          ``submit``, in which case the legacy ``{request_id: token list}``
          dict is returned (handles are still resolved either way).
        """
        from repro.core import execution_context
        # One key per run, split per wave: waves draw decorrelated samples
        # while repeated runs stay deterministic for a fixed seed.
        key = jax.random.PRNGKey(self.cfg.seed)
        # Pin the ambient hardware profile for the whole drain so the model
        # path's tile lookups (traced inside jit) resolve against the same
        # profile the engine reports in stats().
        with execution_context(hardware=self.hardware):
            if self._scheduler == "continuous":
                drained = self._run_continuous(extra_inputs, key)
            else:
                drained = []
                while True:
                    self._poll_ingest()
                    if not self._queue:
                        break
                    wave = self._pack_wave()
                    key, wave_key = jax.random.split(key)
                    self._run_wave(wave, extra_inputs, wave_key)
                    drained.extend(wave)
        if any(r.legacy for r in drained):
            return {r.rid: r.tokens for r in drained}
        return [r.result for r in sorted(drained, key=lambda r: r.rid)]

    def _poll_ingest(self) -> None:
        """Pull server-mode requests in at a scheduling boundary (no-op
        without an ingest hook)."""
        if self._ingest_hook is None:
            return
        for req, handle in self._ingest_hook():
            self.submit(req, _handle=handle)

    def _finish_request(self, req: _Request, reason: str,
                        now: float) -> None:
        """Request-granular completion: latency records, the terminal
        stream event, and handle resolution (servers see results without
        waiting for the drain to end)."""
        req.finish_reason = reason
        total = max(now - req.t_submit, 1e-9)
        ttft = (req.t_first - req.t_submit
                if req.t_first is not None else total)
        n = len(req.tokens)
        self._lat_ttft.append(ttft)
        self._lat_tok.append(n / total)
        if len(self._lat_tok) > _LATENCY_WINDOW:
            del self._lat_ttft[:-_LATENCY_WINDOW]
            del self._lat_tok[:-_LATENCY_WINDOW]
        req.result = api.GenerationResult(
            request_id=req.rid, tokens=list(req.tokens),
            finish_reason=reason, prompt_len=len(req.prompt),
            ttft_s=ttft, total_s=total, tok_per_s=n / total,
            prefix_hit=req.prefix_hit,
            cached_prefix_tokens=req.cached_prefix_tokens)
        if req.stream is not None:
            req.stream(api.StreamEvent(req.rid, None, n, finished=True,
                                       finish_reason=reason))
        if req.handle is not None:
            req.handle._set_result(req.result)

    def _pack_wave(self) -> List[_Request]:
        """Pop the next capacity-feasible wave off the queue (FIFO-biased).

        The head request always ships (submit() guaranteed it fits alone);
        later requests join only while the *joint* requirement
        ``max(prompt) + max(max_new)`` stays within ``max_len`` — requests
        that don't fit keep their queue position for a later wave, so mixed
        long-prompt/long-budget traffic never over-rejects.
        """
        wave = [self._queue.pop(0)]
        longest = len(wave[0].prompt)
        need = wave[0].max_new
        i = 0
        while len(wave) < self.cfg.max_batch and i < len(self._queue):
            r = self._queue[i]
            nl = max(longest, len(r.prompt))
            nn = max(need, r.max_new)
            if nl + nn <= self.cfg.max_len:
                wave.append(self._queue.pop(i))
                longest, need = nl, nn
            else:
                i += 1
        return wave

    # -- continuous drain: admit/evict at chunk boundaries ----------------
    def _run_continuous(self, extra_inputs: Optional[Dict[str, jax.Array]],
                        key: jax.Array) -> List[_Request]:
        """Drain the queue with true continuous batching.

        The loop body is one *chunk boundary*: poll the server ingest hook,
        admit every queue-head request that fits (strict FIFO — the head
        blocks), grow live block tables for the next chunk (preempting
        youngest-admitted rows if the pool runs dry; victims requeue at the
        FRONT with a clean restart), run one fused decode chunk, stream its
        tokens, then evict rows that finished inside it.  Exactly one host
        transfer per chunk.  Returns the finished requests.
        """
        if extra_inputs and any(r.row is None for r in self._queue):
            raise ValueError(
                "extra_inputs needs every request submitted with row= "
                "(its index into the extra arrays); generate() does this")
        self._ensure_pool()
        finished: List[_Request] = []
        active: Dict[int, _Request] = {}        # slot -> request
        eos = self.cfg.eos_token
        try:
            while True:
                self._poll_ingest()
                if not (self._queue or active):
                    break
                if self._queue:
                    key = self._admit_batch(active, extra_inputs, key)
                preempted = self._csched.ensure_chunk_pages(self._chunk)
                # Requeue victims at the queue front, smallest rid first,
                # with generated tokens discarded: re-admission restarts
                # them cleanly (greedy decode makes the restart exact).
                for row in sorted(preempted, key=lambda r: r.rid,
                                  reverse=True):
                    req = active.pop(row.slot)
                    self._sched.evict(req)
                    req.tokens = None
                    req.t_first = None
                    req.prefix_hit = None
                    req.cached_prefix_tokens = 0
                    self._queue.insert(0, req)
                if not active:
                    continue        # preemption freed the pool; re-admit
                key, buf_h, lens_h = self._run_chunk(key)
                now = time.perf_counter()
                for slot in list(active):
                    req = active[slot]
                    row = self._csched.rows[slot]
                    n = int(lens_h[slot])
                    emitted = [int(t) for t in buf_h[slot, :n]]
                    base = len(req.tokens)
                    req.tokens.extend(emitted)
                    if emitted and req.t_first is None:
                        req.t_first = now
                    if req.stream is not None:
                        for j, t in enumerate(emitted):
                            req.stream(api.StreamEvent(req.rid, t, base + j))
                    self._stats["tokens_generated"] += n
                    row.length += n
                    row.budget_left -= n
                    if row.budget_left <= 0 or (eos is not None
                                                and eos in emitted):
                        reason = (api.FINISH_STOP
                                  if eos is not None and eos in emitted
                                  else api.FINISH_LENGTH)
                        self._csched.evict(row)
                        self._sched.evict(req)
                        del active[slot]
                        self._finish_request(req, reason, now)
                        finished.append(req)
        except Exception as exc:
            # Free every live row (pages AND slots) so one bad request
            # can't brick the pool for the next call; fail their handles
            # so server-mode waiters aren't stranded.
            for slot in list(active):
                req = active.pop(slot)
                row = self._csched.rows.get(slot)
                if row is not None:
                    self._csched.evict(row)
                self._sched.evict(req)
                if req.handle is not None and not req.handle.done:
                    req.handle._set_error(exc)
            raise
        return finished

    def _admit_batch(self, active: Dict[int, "_Request"],
                     extra_inputs: Optional[Dict[str, jax.Array]],
                     key: jax.Array) -> jax.Array:
        """Admit every queue-head request that fits (slot + prompt pages),
        consult the prefix cache for each, then prefill the misses in ONE
        batched call and insert their prompt KV, fixed-leaf rows and first
        sampled token into the live state.

        Prefix-cache composition (all host bookkeeping):

        * the head's cached prefix pages count as *shared* for the
          capacity check — a mostly-cached long prompt admits into a
          nearly-full pool;
        * when the head still doesn't fit, the cache evicts LRU entries
          before admission blocks (matching entries are re-resolved each
          retry — the evicted item may have been the match);
        * full-prompt hits skip the batched prefill entirely
          (:meth:`_restore_hits`); partial hits prefill the whole prompt
          for exactness but redirect shared-column writes to TRASH;
        * every prefilled prompt (cache enabled, no extras) is inserted
          back into the cache while its pages are known-live.
        """
        admitted: List[_Request] = []
        hits = []                       # (req, RowState, cache entry)
        caching = self._prefix is not None and not extra_inputs
        while self._queue:
            nxt = self._queue[0]
            m = self._prefix.match(nxt.prompt) if caching else None
            shared = list(m.pages) if m is not None else []
            if not self._csched.can_admit(len(nxt.prompt),
                                          shared_pages=len(shared)):
                # only sacrifice cached pages for a PAGE shortage — a busy
                # slot frees itself at the next chunk boundary, and evicting
                # for it would churn the cache to no benefit
                if (self._csched.free_slots > 0
                        and self._prefix is not None
                        and self._prefix.evict_one()):
                    continue
                break
            req = self._queue.pop(0)
            row = self._csched.admit(req.rid, len(req.prompt), req.max_new,
                                     shared_pages=shared)
            self._sched.admit(req)      # lockstep: same smallest-free slot
            assert req.slot == row.slot
            req.tokens = []
            active[row.slot] = req
            if caching:
                self._prefix.record_admit(m, len(req.prompt))
            if m is not None:
                req.prefix_hit = (api.PREFIX_HIT_FULL if m.full
                                  else api.PREFIX_HIT_PARTIAL)
                req.cached_prefix_tokens = m.tokens
            if m is not None and m.full:
                hits.append((req, row, m.entry))
            else:
                admitted.append(req)
        if hits:
            key = self._restore_hits(hits, key)
        if not admitted:
            return key

        from repro.serve.kv_pages import TRASH_PAGE
        cfg = self.cfg
        b = cfg.max_batch
        page = self._page_size
        plen = _bucket_len(max(len(r.prompt) for r in admitted))
        toks = np.zeros((b, plen), np.int32)
        kv_start = np.full((b,), plen, np.int32)
        # Prompt-KV destinations: batch rows not admitted THIS call (and pad
        # columns of admitted rows) write to the TRASH page; real columns
        # map straight into the row's block table.  Columns covered by a
        # partial prefix hit ALSO write to TRASH — their pages are shared
        # read-only with the cache, and the cached KV is already what this
        # prefill would write (pages-written saving, dedup'd pool memory).
        dest = np.broadcast_to(TRASH_PAGE * page + np.arange(plen) % page,
                               (b, plen)).astype(np.int32).copy()
        for r in admitted:
            row = self._csched.rows[r.slot]
            np_prompt = len(r.prompt)
            toks[r.slot, plen - np_prompt:] = r.prompt
            kv_start[r.slot] = plen - np_prompt
            shared_toks = (r.cached_prefix_tokens
                           if r.prefix_hit == api.PREFIX_HIT_PARTIAL else 0)
            logical = np.arange(shared_toks, np_prompt)
            pages = np.asarray(row.pages, np.int64)
            dest[r.slot, plen - np_prompt + shared_toks:] = (
                pages[logical // page] * page + logical % page)
        # slot_map pads with the out-of-range index b: JAX clamps it on
        # gather (the garbage row is immediately discarded) and drops it on
        # scatter, so non-admitted slots keep their live state untouched.
        slot_map = np.full((b,), b, np.int32)
        slot_map[:len(admitted)] = [r.slot for r in admitted]

        batch = {"tokens": jnp.asarray(toks),
                 "kv_start": jnp.asarray(kv_start)}
        if extra_inputs:
            rows = [r.row for r in admitted]
            slots = [r.slot for r in admitted]
            for name, arr in extra_inputs.items():
                padded = jnp.zeros((b,) + arr.shape[1:], arr.dtype)
                batch[name] = padded.at[jnp.asarray(slots)].set(
                    jnp.asarray(arr)[jnp.asarray(rows)])
        batch = self._place_batch(batch)
        scratch = self._scratch_cache(plen)
        self._record_prefill_flash_tiles(plen)
        self._plen_buckets.add(int(plen))
        if self._admit_fn is None:
            self._admit_fn = self._build_admit_fn()
        from repro.profiling import annotate
        t0 = time.perf_counter()
        with annotate("serve.prefill_admit"):
            (self._pools, self._fixed, self._cur, key,
             logits0) = self._admit_fn(
                self.params, batch, scratch, self._pools, self._fixed,
                self._cur, key, jnp.asarray(dest), jnp.asarray(slot_map))
            if cfg.profile:
                # deliberate sync: profile mode wants the true prefill /
                # decode wall-time split, not dispatch-pipeline overlap
                jax.block_until_ready(self._cur)   # analysis: allow(TP001)
        self._stats["prefill_seconds"] += time.perf_counter() - t0
        self._stats["admission_prefills"] += 1
        if caching:
            # Insert while the rows' pages are known-live: the cache takes
            # its own refs, so the entries outlive the rows.
            for r in admitted:
                row = self._csched.rows[r.slot]
                self._prefix.insert(r.prompt, row.pages, logits0[r.slot],
                                    self._slice_fixed_row(r.slot))
        return key

    def _run_chunk(self, key: jax.Array):
        """One fused decode chunk over every live row; returns the updated
        key plus the host copies of the chunk's token buffer and counts
        (the chunk's single device transfer)."""
        from repro.serve.kv_pages import gather_indices, scatter_indices
        rows = self._csched.rows
        b = self.cfg.max_batch
        chunk = self._chunk
        page = self._page_size
        width = _bucket_len(max(r.length for r in rows.values()) + chunk)
        gidx = gather_indices(rows, b, width, chunk, page)
        sidx = scatter_indices(rows, b, chunk, page)
        kv_start = np.full((b,), width - chunk, np.int32)
        budget = np.zeros((b,), np.int32)
        for slot, row in rows.items():
            kv_start[slot] = width - chunk - row.length
            budget[slot] = row.budget_left
        if self._chunk_fn is None:
            self._chunk_fn = self._build_chunk_fn()
        # The fused loop advances in ``unroll``-token strides; clamp to a
        # divisor of the chunk so the final stride can't overshoot the
        # token buffer (a clamped dynamic_update_slice would silently
        # rewrite the last column).
        unroll = min(self._resolve_unroll(), chunk)
        while chunk % unroll:
            unroll -= 1
        from repro.profiling import annotate
        t0 = time.perf_counter()
        with annotate("serve.decode_chunk"):
            (self._pools, self._fixed, self._cur, key, buf,
             lens) = self._chunk_fn(
                self.params, self._pools, self._fixed, self._cur, key,
                jnp.asarray(gidx), jnp.asarray(sidx), jnp.asarray(kv_start),
                jnp.asarray(budget), width=width, chunk=chunk, unroll=unroll)
            # The ONE host transfer of this chunk.
            buf_h, lens_h = jax.device_get((buf, lens))  # analysis: allow(TP001)
        self._stats["decode_seconds"] += time.perf_counter() - t0
        self._stats["device_transfers"] += 1
        self._stats["chunks"] += 1
        return key, buf_h, lens_h

    # -- batched generation ---------------------------------------------
    def generate(self, prompts: List[List[int]], max_new_tokens: int,
                 extra_inputs: Optional[Dict[str, jax.Array]] = None
                 ) -> List[List[int]]:
        """Batched generation; prompts beyond ``max_batch`` run in waves."""
        # Validate the whole batch BEFORE the first submit so a bad prompt
        # can't leave earlier requests queued for the next call.
        if not prompts:
            raise ValueError("generate() needs at least one prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if any(not list(p) for p in prompts):
            raise ValueError("empty prompt: each prompt needs >= 1 token")
        for p in prompts:
            if self._scheduler == "continuous":
                if len(list(p)) + max_new_tokens > self._capacity_tokens:
                    raise ValueError(
                        f"prompt ({len(list(p))}) + max_new "
                        f"({max_new_tokens}) exceeds capacity_tokens "
                        f"({self._capacity_tokens})")
            elif len(list(p)) + max_new_tokens > self.cfg.max_len:
                raise ValueError(
                    f"prompt ({len(list(p))}) + max_new ({max_new_tokens}) "
                    f"exceeds max_len ({self.cfg.max_len})")
        if extra_inputs:
            for name, arr in extra_inputs.items():
                if arr.shape[0] != len(prompts):
                    raise ValueError(
                        f"extra input {name!r} leading dim {arr.shape[0]} != "
                        f"len(prompts) {len(prompts)}")
        t0 = time.perf_counter()
        handles = [self.submit(api.Request(prompt=list(p),
                                           max_new_tokens=max_new_tokens,
                                           row=i))
                   for i, p in enumerate(prompts)]
        try:
            self.run(extra_inputs)
        except Exception:
            # drop this call's unserved requests — they must not leak into
            # (and mis-index the extras of) the next call
            rid_set = {h.request_id for h in handles}
            self._queue = [r for r in self._queue if r.rid not in rid_set]
            raise
        self._stats["generate_calls"] += 1
        self._stats["total_seconds"] += time.perf_counter() - t0
        # handles resolved synchronously by the drain above; timeout=0
        # turns a (would-be) bug into a fast failure instead of a hang
        return [h.result(timeout=0).tokens for h in handles]

    # -- one wave: prefill + fused decode + single fetch -----------------
    def _run_wave(self, wave: List[_Request],
                  extra_inputs: Optional[Dict[str, jax.Array]],
                  key: jax.Array) -> None:
        cfg = self.cfg
        b = cfg.max_batch
        # Validate BEFORE admitting: a rejected request must not leak slots.
        need = max(r.max_new for r in wave)    # real token budget (cache need)
        longest = max(len(r.prompt) for r in wave)
        if longest + need > cfg.max_len:       # submit()/_pack_wave guarantee
            raise ValueError(                  # this; keep the guard for raw
                f"prompt ({longest}) + max_new ({need}) exceeds "   # callers
                f"max_len ({cfg.max_len})")
        # The decode width is a pure buffer/loop bound (the fused loop stops
        # at each slot's budget and cache writes stay within plen + need),
        # so it keeps its power-of-two bucket unclamped — one compile per
        # need bucket.  The prompt pad length IS capacity-bound: bucket it,
        # clamped so near-capacity prompts share one clamped bucket instead
        # of falling back to exact per-length sizes (a recompile per
        # distinct prompt length).  The cap prefers the width bucket (fewer
        # distinct plens) and degrades to the exact need only when the
        # bucket would push below the prompt itself.
        width = _bucket_len(need)
        plen = _bucket_len(longest, cfg.max_len - width)
        if plen < longest:
            plen = _bucket_len(longest, cfg.max_len - need)
        if plen < longest:     # unreachable: longest + need <= max_len
            plen = longest
        if extra_inputs and any(r.row is None for r in wave):
            raise ValueError(
                "extra_inputs needs every request submitted with row= "
                "(its index into the extra arrays); generate() does this")
        for r in wave:
            self._sched.admit(r)
        try:
            self._decode_wave(wave, extra_inputs, key, plen, width)
        except Exception as exc:
            for r in wave:
                if r.handle is not None and not r.handle.done:
                    r.handle._set_error(exc)
            raise
        finally:
            # free slots even when prefill/decode throws — one bad request
            # must never brick the pool
            for r in wave:
                self._sched.evict(r)

    def _decode_wave(self, wave: List[_Request],
                     extra_inputs: Optional[Dict[str, jax.Array]],
                     key: jax.Array, plen: int, width: int) -> None:
        cfg = self.cfg
        b = cfg.max_batch
        toks = np.zeros((b, plen), np.int32)
        kv_start = np.full((b,), plen, np.int32)   # empty slots: fully padded
        budget = np.zeros((b,), np.int32)
        for r in wave:
            toks[r.slot, plen - len(r.prompt):] = r.prompt
            kv_start[r.slot] = plen - len(r.prompt)
            budget[r.slot] = r.max_new

        batch = {"tokens": jnp.asarray(toks),
                 "kv_start": jnp.asarray(kv_start)}
        if extra_inputs:
            rows = [r.row for r in wave]
            slots = [r.slot for r in wave]
            for name, arr in extra_inputs.items():
                padded = jnp.zeros((b,) + arr.shape[1:], arr.dtype)
                batch[name] = padded.at[jnp.asarray(slots)].set(
                    jnp.asarray(arr)[jnp.asarray(rows)])
        # Split the wave over the data axes (identity without a mesh).
        batch = self._place_batch(batch)
        # Loop CONTROL state (per-slot budgets/offsets and everything
        # derived from them: done flags, emitted-token buffer) stays
        # replicated: these are a handful of ints per slot, and sharding
        # them turns every ``done.all()`` / budget check inside the fused
        # loop into a blocking cross-device reduction.  Replicated, the
        # whole control path is local to each device; only the model step
        # itself (cache, activations) runs sharded.
        kv_start_d, budget_d = jnp.asarray(kv_start), jnp.asarray(budget)

        cache = self._ensure_cache()
        self._record_prefill_flash_tiles(plen)
        self._plen_buckets.add(int(plen))
        from repro.profiling import annotate
        t0 = time.perf_counter()
        with annotate("serve.prefill_wave"):
            logits0, cache = self._prefill(self.params, batch, cache)
            if cfg.profile:
                # deliberate sync: profile mode wants the true prefill /
                # decode wall-time split, not dispatch-pipeline overlap
                jax.block_until_ready(logits0)   # analysis: allow(TP001)
        t1 = time.perf_counter()

        if self._loop is None:
            self._loop = self._build_loop()
        unroll = min(self._resolve_unroll(), width)
        with annotate("serve.decode_wave"):
            buf, lens, cache = self._loop(
                self.params, cache, logits0, key, kv_start_d,
                budget_d, jnp.int32(plen), width=width, unroll=unroll)
            self._cache = cache

            # The ONE host transfer of this wave (== of the whole generate
            # call when the batch fits the slot pool).
            buf_h, lens_h = jax.device_get((buf, lens))  # analysis: allow(TP001)
        t2 = time.perf_counter()
        self._stats["device_transfers"] += 1
        self._stats["waves"] += 1
        self._stats["prefill_seconds"] += t1 - t0
        self._stats["decode_seconds"] += t2 - t1

        eos = cfg.eos_token
        now = time.perf_counter()
        for r in wave:
            n = int(lens_h[r.slot])
            r.tokens = [int(t) for t in buf_h[r.slot, :n]]
            self._stats["tokens_generated"] += n
            # Wave scheduling streams at wave granularity: every token
            # becomes host-visible at the wave's single transfer, so the
            # callback fires for all of them here (the continuous path
            # streams at chunk granularity instead).
            r.t_first = now if n else None
            if r.stream is not None:
                for j, t in enumerate(r.tokens):
                    r.stream(api.StreamEvent(r.rid, t, j))
            reason = (api.FINISH_STOP if eos is not None and eos in r.tokens
                      else api.FINISH_LENGTH)
            self._finish_request(r, reason, now)

    # -- prefix-cache control --------------------------------------------
    def clear_prefix_cache(self) -> None:
        """Release every cache-pinned page (cold-cache reset).  Live rows
        keep their refs; parity tests and benchmarks use this to compare
        warm vs cold runs on one engine."""
        if self._prefix is not None:
            self._prefix.clear()

    # -- telemetry -------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        """Counters + tuned-block lookup provenance, as one plain dict.

        The key set is VERSIONED and frozen per
        :mod:`repro.serve.stats_schema` (``schema_version`` carries the
        version; the ST001 analysis check and
        :func:`repro.serve.stats_schema.validate_stats` both gate drift).

        Beyond the raw counters (requests, tokens, waves, timings), the
        tuning-framework telemetry:

        * ``hardware`` / ``hardware_platform`` — the resolved hardware
          profile every tile lookup below was keyed by (provenance for
          bench artifacts and the CI backend matrix);
        * ``mesh`` / ``sharding`` — the device topology (axis name → size)
          and, on a mesh, the active sharding rules plus a histogram of the
          param partition specs they produced (``sharding`` is ``None``
          single-device);
        * ``decode_tile_lookups`` — each decode-step GEMM shape mapped to
          its resolved tile and provenance tier
          (``exact``/``nearest``/``generic``/``default``/``fallback``);
        * ``prefill_flash_lookups`` — for ``attention_impl="flash"`` models,
          each prefill bucket's ``(sq, skv, head_dim)`` mapped to its tuned
          ``(bq, bk)`` blocks and provenance;
        * ``registry_hit_stats`` — global per-tier lookup counts.

        Example::

            eng = Engine(model, params, ServeConfig(max_batch=4))
            eng.generate([[1, 2, 3]], max_new_tokens=8)
            eng.stats()["prefill_flash_lookups"]
            # {'8x8x64': {'source': 'nearest', 'tile': '128x128', ...}}
        """
        from repro.core.registry import GLOBAL_REGISTRY
        from repro.launch.mesh import describe_mesh
        from repro.serve.prefix_cache import PrefixCache
        out = dict(self._stats)
        out["schema_version"] = SCHEMA_VERSION
        out["hardware"] = self.hardware
        out["hardware_platform"] = self._platform
        out["mesh"] = describe_mesh(self.mesh)
        if self.mesh is None:
            out["sharding"] = None
        else:
            from repro.distributed import sharding as sh
            out["sharding"] = {
                "rules": {
                    "tensor_axis": self.rules.tensor_axis,
                    "fsdp_axis": self.rules.fsdp_axis,
                    "batch_axes": list(self.rules.batch_axes),
                    "sequence_axis": self.rules.sequence_axis,
                },
                "params": sh.sharding_summary(self.mesh, self.rules,
                                              self.model.template),
            }
        out["prefill_plen_buckets"] = sorted(self._plen_buckets)
        out["decode_unroll"] = self._unroll
        out["decode_unroll_source"] = self._unroll_source
        out["scheduler"] = self._scheduler
        out["scheduler_forced"] = self._scheduler_forced
        if self._scheduler == "continuous":
            out["decode_chunk"] = self._chunk
            out["capacity_tokens"] = self._capacity_tokens
            out["page_size"] = self._page_size
            out["page_size_source"] = self._page_size_source
            out["pages"] = None
            if self._alloc is not None:
                out["pages"] = {
                    "page_size": self._alloc.page_size,
                    "usable_pages": self._alloc.usable_pages,
                    "used_pages": self._alloc.used_pages,
                    "free_pages": self._alloc.free_pages,
                    "utilization": self._alloc.utilization(),
                    "high_water_pages": self._alloc.high_water_pages,
                    "alloc_count": self._alloc.alloc_count,
                    "free_count": self._alloc.free_count,
                }
            out["admissions"] = (self._csched.admissions
                                 if self._csched is not None else 0)
            out["evictions"] = (self._csched.evictions
                                if self._csched is not None else 0)
            out["preemptions"] = (self._csched.preemptions
                                  if self._csched is not None else 0)
        out["prefix_cache"] = (self._prefix.stats()
                               if self._prefix is not None
                               else PrefixCache.disabled_stats())
        out["latency"] = {
            "count": len(self._lat_tok),
            "ttft_s": _percentiles(self._lat_ttft),
            "tok_per_s": _percentiles(self._lat_tok),
        }
        out["slots"] = self.cfg.max_batch
        out["slots_admitted"] = self._sched.admitted
        out["slots_evicted"] = self._sched.evicted
        out["slot_reuses"] = self._sched.reuses
        out["decode_tile_lookups"] = self._tile_lookups
        out["prefill_flash_lookups"] = dict(self._prefill_flash_lookups)
        out["registry_hit_stats"] = dict(GLOBAL_REGISTRY.hit_stats)
        return out
