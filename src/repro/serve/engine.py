"""Device-resident continuous-batching serve engine.

Production-shaped serving over a fixed pool of ``max_batch`` KV-cache slots:

* **Slot scheduler** — requests are admitted into free slots and evicted on
  completion; the KV cache is allocated once per engine and reused across
  ``generate`` calls (stale entries are never attended thanks to per-slot
  ``kv_start``/length masking).  More requests than slots are served in
  successive waves.
* **Fused decode loop** — a single ``jax.lax.while_loop`` carries tokens,
  per-slot done flags, per-slot token budgets, EOS checks, the sampling key
  and the KV cache entirely on device.  Exactly ONE ``jax.device_get`` per
  decode wave — i.e. per ``generate`` call whenever the batch fits the slot
  pool — fetches the finished token buffer; no per-token host round-trips.
* **Ragged batches** — prompts are right-aligned (left-padded); the per-slot
  pad offset ``kv_start`` is threaded through the model so attention masks
  pad columns, RoPE/learned positions restart at each row's first real
  token, and SSM blocks zero pad contributions.  Each row therefore decodes
  exactly what it would decode alone.
* **Tuned tiles** — the decode step's GEMM shapes are traced once and
  resolved against the global tile registry; the lookup provenance
  (exact/nearest/generic/default) is surfaced in :meth:`Engine.stats`.
* **Meshes** — ``ServeConfig(mesh="data=4,model=2")`` (or an ambient
  ``distributed.ctx.use_mesh``) shards params, KV-cache slots and the batch
  by the ``ShardingRules`` of the mesh — the distribution layer's analogue
  of the paper's tuning table: the same engine source serves one chip or a
  pod, selected by a spec string.  Tuned-tile lookups are then keyed on the
  per-shard *local* GEMM shapes (TP changes which tuned entry is hit), and
  :meth:`Engine.stats` reports mesh/sharding provenance.

Prompt lengths are bucketed to powers of two (min 8, clamped so the bucket
plus the wave's decode budget never exceeds ``max_len``) so a wave and a
lone prompt in the same bucket share one compiled prefill *and* take
bit-identical float paths — the basis of the ragged-batch parity guarantee.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model

_PLEN_BUCKET_MIN = 8


def _bucket_len(n: int, cap: Optional[int] = None) -> int:
    """Smallest power-of-two bucket >= ``n``, clamped to ``cap``.

    The clamp keeps near-capacity buckets inside the KV-slot capacity
    instead of overshooting ``max_len`` and forcing callers back to exact
    (per-length-recompiling) sizes.  When ``cap < n`` the cap itself is
    returned (< n) and the caller must fall back to exact sizing.
    """
    b = _PLEN_BUCKET_MIN
    while b < n:
        b *= 2
    if cap is not None and b > cap:
        b = cap
    return b


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8                # KV-cache slots
    max_len: int = 512                # per-slot cache capacity (prompt + new)
    temperature: float = 0.0          # 0 => greedy
    eos_token: Optional[int] = None
    seed: int = 0
    profile: bool = False             # block after prefill to split timings
    # Hardware profile the engine tunes against (registry key).  None uses
    # the ambient execution context's resolution: explicit override >
    # $REPRO_HARDWARE > jax.devices() detection.
    hardware: Optional[str] = None
    # Device mesh: a spec string ("data=4,model=2" | "auto"), a prebuilt
    # jax.sharding.Mesh, or None.  None picks up the ambient
    # distributed.ctx.use_mesh() topology (single-device when absent).
    mesh: Optional[Union[str, jax.sharding.Mesh]] = None
    # Tokens decoded per fused-loop iteration.  Every while-loop spin is a
    # cross-device sync point on a mesh, so fatter iterations hide dispatch
    # latency.  None resolves: mesh-keyed tuned entry (decode_loop in the
    # TuningDB, topology in the key) > heuristic (4 on a mesh, 1 alone).
    decode_unroll: Optional[int] = None


@dataclasses.dataclass
class _Request:
    rid: int
    prompt: List[int]
    max_new: int
    row: Optional[int] = None         # row in the shared extra_inputs arrays
    slot: Optional[int] = None
    tokens: Optional[List[int]] = None


class _SlotScheduler:
    """Admit/evict bookkeeping over the fixed pool of KV-cache slots."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._free = list(range(n_slots))
        self._use_count = [0] * n_slots
        self.admitted = 0
        self.evicted = 0

    def admit(self, req: _Request) -> int:
        if not self._free:
            raise RuntimeError("no free KV-cache slot")
        slot = self._free.pop(0)
        req.slot = slot
        self._use_count[slot] += 1
        self.admitted += 1
        return slot

    def evict(self, req: _Request) -> None:
        self._free.append(req.slot)
        self._free.sort()
        self.evicted += 1

    @property
    def reuses(self) -> int:
        return sum(max(c - 1, 0) for c in self._use_count)


class Engine:
    """Continuous-batching engine over a fixed slot pool.

    ``generate`` is the batched entry point; ``submit``/``run`` expose the
    underlying request queue for callers that stream requests in.
    """

    def __init__(self, model: Model, params, cfg: ServeConfig):
        from repro.core import current_hardware
        from repro.core.hardware import find_profile, resolve_hardware
        self.model = model
        self.params = params
        self.cfg = cfg
        # Resolved once at engine construction so every tile lookup (and the
        # stats provenance) is pinned to one profile for the engine's life.
        self.hardware = (resolve_hardware(cfg.hardware) if cfg.hardware
                         else current_hardware())
        prof = find_profile(self.hardware)
        self._platform = prof.platform if prof else "unknown"
        # Mesh topology: explicit config > ambient use_mesh() > single-device.
        # Resolved once, like the hardware profile — one engine, one mesh.
        from repro.distributed import ctx as dctx
        mesh, rules = cfg.mesh, None
        if mesh is None:
            mesh, rules = dctx.current_mesh(), dctx.current_rules()
        if isinstance(mesh, str):
            from repro.launch.mesh import build_mesh
            mesh = build_mesh(mesh)
        self.mesh = mesh
        self.rules = None
        if mesh is not None:
            from repro.distributed import sharding as sh
            # Inference rules: no FSDP.  Training shards weights over the
            # data axes and re-gathers them per step — amortized over a big
            # batch.  Decode GEMMs are tiny (B x 1 tokens), so per-step
            # weight all-gathers SERIALIZE the loop (profiling showed them
            # dominating decode wall-clock at 0.54x of the sync baseline).
            # Serving therefore replicates weights over the data axes and
            # shards them only over the tensor axis (classic inference TP);
            # explicit ambient rules still win for callers that know better.
            self.rules = rules or sh.rules_for_mesh(mesh, fsdp=False)
            # Re-place params by the rules (no-op layout change on values:
            # sharded and single-device engines stay token-for-token equal).
            self.params = sh.shard_params(params, mesh, self.rules,
                                          model.template)
        self._prefill = jax.jit(self._with_mesh(model.prefill))
        self._loop = None                 # built lazily (per-engine closure)
        self._unroll: Optional[int] = None         # resolved lazily, cached
        self._unroll_source: Optional[str] = None
        self._cache = None                # allocated once, reused across calls
        self._sched = _SlotScheduler(cfg.max_batch)
        self._queue: List[_Request] = []
        self._next_rid = 0
        self._tile_lookups: Optional[Dict[str, Dict[str, object]]] = None
        self._prefill_flash_lookups: Dict[str, Dict[str, object]] = {}
        self._plen_buckets: set = set()
        self._stats: Dict[str, float] = {
            "requests": 0, "tokens_generated": 0, "generate_calls": 0,
            "waves": 0, "device_transfers": 0, "cache_allocs": 0,
            "prefill_seconds": 0.0, "decode_seconds": 0.0,
            "total_seconds": 0.0,
        }

    # -- mesh plumbing --------------------------------------------------
    def _with_mesh(self, fn):
        """Wrap ``fn`` so tracing happens under this engine's activation
        policy (``constrain`` pins residual/logits layouts to the mesh).
        Identity when the engine is single-device."""
        if self.mesh is None:
            return fn
        mesh, rules = self.mesh, self.rules

        def wrapped(*args, **kwargs):
            from repro.distributed.ctx import activation_policy
            with activation_policy(mesh, rules):
                return fn(*args, **kwargs)

        return wrapped

    def _place_batch(self, tree):
        """Shard leading-batch-dim arrays over the data axes (no-op
        single-device).  Values are unchanged — only the layout."""
        if self.mesh is None:
            return tree
        from repro.distributed import sharding as sh
        return jax.device_put(
            tree, sh.batch_shardings(self.mesh, self.rules, tree))

    # -- sampling ------------------------------------------------------
    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    # -- fused device-resident decode loop -----------------------------
    def _resolve_unroll(self) -> int:
        """Tokens decoded per fused-loop iteration.

        Resolution: explicit ``ServeConfig.decode_unroll`` > a mesh-keyed
        ``decode_loop`` tuned entry (the topology is part of the op key, so
        ``data=4,model=2`` can tune a different unroll than a single chip) >
        the heuristic (4 on a mesh — spin sync points are collectives there
        — else 1).  The resolved value and its provenance land in
        :meth:`stats` as ``decode_unroll`` / ``decode_unroll_source``.
        """
        if self._unroll is not None:
            return self._unroll
        if self.cfg.decode_unroll is not None:
            self._unroll = max(int(self.cfg.decode_unroll), 1)
            self._unroll_source = "config"
        else:
            from repro.core.registry import GLOBAL_REGISTRY, OP_DECODE_LOOP
            from repro.launch.mesh import mesh_axis_label
            res = GLOBAL_REGISTRY.lookup_op(
                OP_DECODE_LOOP, self.hardware, self.model.cfg.dtype,
                (self.cfg.max_batch, self.cfg.max_len),
                mesh=mesh_axis_label(self.mesh))
            if res.source in ("exact", "nearest", "generic"):
                self._unroll = max(int(res.config.unroll), 1)
                self._unroll_source = f"tuned:{res.source}"
            else:
                self._unroll = 4 if self.mesh is not None else 1
                self._unroll_source = "heuristic"
        return self._unroll

    def _build_loop(self):
        decode = self.model.decode_step
        eos = self.cfg.eos_token

        def loop(params, cache, logits0, key, kv_start, budget, offset0, *,
                 width: int, unroll: int):
            b = logits0.shape[0]
            # Split BEFORE the first sample: the parent key is reserved for
            # splitting only, so the first token is uncorrelated with later
            # ones.
            key, sub = jax.random.split(key)
            cur = self._sample(logits0, sub)
            done = budget <= 0                 # empty slots start finished
            buf = jnp.zeros((b, width), jnp.int32)
            lens = jnp.zeros((b,), jnp.int32)

            # ``alldone`` rides in the carry so the while cond is a plain
            # scalar read.  Evaluating ``done.all()`` inside cond (and again
            # inside body's predicate) costs a cross-device reduction per
            # spin when ``done`` picks up a batch sharding — two extra
            # blocking collectives per token that serialize the mesh decode
            # loop.  Computing it ONCE per body and carrying the scalar
            # keeps every control decision local.
            def cond(carry):
                step, cur, done, alldone, buf, lens, cache, offset, key = carry
                return (step < width) & ~alldone

            def body(carry):
                step, cur, done, alldone, buf, lens, cache, offset, key = carry
                # Unrolled body: each while iteration records + decodes
                # ``unroll`` tokens.  Every loop spin is a cross-device sync
                # point on a mesh (cond broadcast + per-device dispatch), so
                # fewer, fatter iterations hide that latency behind compute;
                # done/budget bookkeeping stays exact per token via the
                # masked buffer writes.
                for _ in range(unroll):
                    with jax.named_scope("decode_token"):
                        buf = jax.lax.dynamic_update_slice(
                            buf, jnp.where(done, 0, cur)[:, None], (0, step))
                        lens = lens + jnp.where(done, 0, 1).astype(jnp.int32)
                        if eos is not None:
                            done = done | (cur == eos)
                        done = done | (lens >= budget)
                        alldone = done.all()
                        step = step + 1

                        def advance(op):
                            cache, cur, key, offset = op
                            key, sub = jax.random.split(key)
                            logits, cache = decode(params, cur[:, None],
                                                   cache, offset, kv_start)
                            return (cache, self._sample(logits, sub), key,
                                    offset + 1)

                        # Skip the model step once every live slot finished.
                        cache, cur, key, offset = jax.lax.cond(
                            (step < width) & ~alldone, advance, lambda op: op,
                            (cache, cur, key, offset))
                return (step, cur, done, alldone, buf, lens, cache, offset,
                        key)

            carry = (jnp.int32(0), cur, done, done.all(), buf, lens, cache,
                     offset0, key)
            _, _, _, _, buf, lens, cache, _, _ = jax.lax.while_loop(
                cond, body, carry)
            return buf, lens, cache

        return jax.jit(self._with_mesh(loop),
                       static_argnames=("width", "unroll"))

    # -- slot-pool cache -----------------------------------------------
    def _ensure_cache(self):
        if self._cache is None:
            cache = self.model.init_cache(self.cfg.max_batch,
                                          self.cfg.max_len)
            if self.mesh is not None:
                # Shard the slot pool itself: batch over the data axes,
                # heads (or cache sequence, for GQA) over the tensor axis.
                from repro.distributed import sharding as sh
                cache = jax.device_put(
                    cache, sh.cache_shardings(self.mesh, self.rules, cache))
            self._cache = cache
            self._stats["cache_allocs"] += 1
            self._trace_decode_tiles()
        return self._cache

    def _trace_decode_tiles(self) -> None:
        """Abstractly trace one decode step, resolve its GEMM shapes against
        the tuned-tile registry, and record the lookup provenance.

        On a mesh the traced shapes are *global*; what each shard actually
        runs is the local GEMM — batch split over the data axes, weight dims
        split per the sharding rules — so the registry lookup is keyed on
        the local shape (TP therefore changes which tuned entry is hit).
        Both shapes are recorded in the provenance.
        """
        from repro.core import capture_gemm_shapes
        from repro.core.registry import GLOBAL_REGISTRY
        b = self.cfg.max_batch
        tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        off = jax.ShapeDtypeStruct((), jnp.int32)
        ks = jax.ShapeDtypeStruct((b,), jnp.int32)
        try:
            with capture_gemm_shapes() as shapes:
                jax.eval_shape(self.model.decode_step, self.params, tok,
                               self._cache, off, ks)
        except Exception:      # provenance is telemetry, never fatal
            self._tile_lookups = {}
            return
        weight_div, batch_div = {}, 1
        if self.mesh is not None:
            from repro.distributed import sharding as sh
            weight_div = sh.local_gemm_divisors(self.mesh, self.rules,
                                                self.model.template)
            batch_div = sh.axis_size(self.mesh, self.rules.batch_axes)
        from repro.core.registry import OP_GEMM
        from repro.launch.mesh import mesh_axis_label
        mesh_label = mesh_axis_label(self.mesh)
        hw = self.hardware
        dtype = self.model.cfg.dtype
        lookups = {}
        for (m, k, n) in sorted(set(shapes)):
            # distinct weights can shard one global (K, N) differently
            # (e.g. square wq vs wo); record a lookup per local variant
            for dk, dn in weight_div.get((k, n), ((1, 1),)):
                lm = m // batch_div if m % batch_div == 0 else m
                lk, ln = k // dk, n // dn
                res = GLOBAL_REGISTRY.lookup_op(OP_GEMM, hw, dtype,
                                                (lm, lk, ln), mesh=mesh_label)
                entry = {
                    "source": res.source,
                    "tile": res.config.label,
                    "matched_shape": res.matched_shape,
                }
                key = f"{m}x{k}x{n}"
                if self.mesh is not None:
                    entry["local_shape"] = f"{lm}x{lk}x{ln}"
                    entry["mesh"] = res.mesh
                    if len(weight_div.get((k, n), ())) > 1:
                        key = f"{m}x{k}x{n}->{lm}x{lk}x{ln}"
                lookups[key] = entry
        self._tile_lookups = lookups

    def _record_prefill_flash_tiles(self, plen: int) -> None:
        """Resolve the tuned flash-attention blocks this prefill bucket uses
        and record the lookup provenance (mirrors the decode GEMM trace).

        The model path performs the same lookup inside ``layers.attention``
        (via :func:`repro.core.attention_api.flash_attention`); re-resolving
        here keeps the telemetry identical without threading state through
        jitted code.
        """
        cfg = self.model.cfg
        if cfg.attention_impl != "flash" or not cfg.num_heads:
            return
        key = f"{plen}x{plen}x{cfg.resolved_head_dim}"
        if key in self._prefill_flash_lookups:
            return
        from repro.core.attention_api import flash_tile_lookup
        res = flash_tile_lookup(self.hardware, cfg.dtype, plen, plen,
                                cfg.resolved_head_dim)
        self._prefill_flash_lookups[key] = {
            "source": res.source,
            "tile": res.config.label,
            "matched_shape": res.matched_shape,
        }

    # -- request queue --------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               row: Optional[int] = None) -> int:
        """Queue one generation request.

        Args:
          prompt: non-empty token-id sequence.
          max_new_tokens: decode budget for this request (>= 1).
          row: index of this request in the ``extra_inputs`` arrays later
            passed to :meth:`run` (required when extras are used;
            :meth:`generate` fills it automatically).

        Returns:
          The request id; :meth:`run` keys its result dict by it.

        Example::

            rid = eng.submit([5, 9, 2], max_new_tokens=16)
            tokens = eng.run()[rid]
        """
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt: each prompt needs >= 1 token")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        # Per-request capacity check at enqueue time: an oversized request
        # fails fast HERE instead of bricking the wave it lands in later.
        if len(prompt) + max_new_tokens > self.cfg.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new_tokens}) exceeds "
                f"max_len ({self.cfg.max_len})")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(_Request(rid, prompt, int(max_new_tokens), row))
        self._stats["requests"] += 1
        return rid

    def run(self, extra_inputs: Optional[Dict[str, jax.Array]] = None
            ) -> Dict[int, List[int]]:
        """Drain the submitted queue and return every request's tokens.

        Requests are served in waves of up to ``max_batch`` KV-cache slots;
        each wave is one prefill plus one fused device-resident decode loop
        (a single host transfer).  Ragged prompt lengths within a wave are
        handled by left-padding + ``kv_start`` masking.  Waves are *packed
        by capacity*: a wave's KV need is ``max(prompt) + max(max_new)``
        over its members, so a long-prompt/small-budget request and a
        short-prompt/big-budget request that each fit on their own are
        scheduled into separate waves instead of being rejected together.

        Args:
          extra_inputs: optional per-request model inputs (e.g. Whisper
            ``encoder_embeds``) with leading dim indexed by each request's
            ``row=``.

        Returns:
          ``{request_id: generated token list}`` for every drained request.
        """
        from repro.core import execution_context
        results: Dict[int, List[int]] = {}
        # One key per run, split per wave: waves draw decorrelated samples
        # while repeated runs stay deterministic for a fixed seed.
        key = jax.random.PRNGKey(self.cfg.seed)
        # Pin the ambient hardware profile for the whole drain so the model
        # path's tile lookups (traced inside jit) resolve against the same
        # profile the engine reports in stats().
        with execution_context(hardware=self.hardware):
            while self._queue:
                wave = self._pack_wave()
                key, wave_key = jax.random.split(key)
                self._run_wave(wave, extra_inputs, wave_key)
                for r in wave:
                    results[r.rid] = r.tokens
        return results

    def _pack_wave(self) -> List[_Request]:
        """Pop the next capacity-feasible wave off the queue (FIFO-biased).

        The head request always ships (submit() guaranteed it fits alone);
        later requests join only while the *joint* requirement
        ``max(prompt) + max(max_new)`` stays within ``max_len`` — requests
        that don't fit keep their queue position for a later wave, so mixed
        long-prompt/long-budget traffic never over-rejects.
        """
        wave = [self._queue.pop(0)]
        longest = len(wave[0].prompt)
        need = wave[0].max_new
        i = 0
        while len(wave) < self.cfg.max_batch and i < len(self._queue):
            r = self._queue[i]
            nl = max(longest, len(r.prompt))
            nn = max(need, r.max_new)
            if nl + nn <= self.cfg.max_len:
                wave.append(self._queue.pop(i))
                longest, need = nl, nn
            else:
                i += 1
        return wave

    # -- batched generation ---------------------------------------------
    def generate(self, prompts: List[List[int]], max_new_tokens: int,
                 extra_inputs: Optional[Dict[str, jax.Array]] = None
                 ) -> List[List[int]]:
        """Batched generation; prompts beyond ``max_batch`` run in waves."""
        # Validate the whole batch BEFORE the first submit so a bad prompt
        # can't leave earlier requests queued for the next call.
        if not prompts:
            raise ValueError("generate() needs at least one prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if any(not list(p) for p in prompts):
            raise ValueError("empty prompt: each prompt needs >= 1 token")
        for p in prompts:
            if len(list(p)) + max_new_tokens > self.cfg.max_len:
                raise ValueError(
                    f"prompt ({len(list(p))}) + max_new ({max_new_tokens}) "
                    f"exceeds max_len ({self.cfg.max_len})")
        if extra_inputs:
            for name, arr in extra_inputs.items():
                if arr.shape[0] != len(prompts):
                    raise ValueError(
                        f"extra input {name!r} leading dim {arr.shape[0]} != "
                        f"len(prompts) {len(prompts)}")
        t0 = time.perf_counter()
        rids = [self.submit(p, max_new_tokens, row=i)
                for i, p in enumerate(prompts)]
        try:
            results = self.run(extra_inputs)
        except Exception:
            # drop this call's unserved requests — they must not leak into
            # (and mis-index the extras of) the next call
            rid_set = set(rids)
            self._queue = [r for r in self._queue if r.rid not in rid_set]
            raise
        self._stats["generate_calls"] += 1
        self._stats["total_seconds"] += time.perf_counter() - t0
        return [results[rid] for rid in rids]

    # -- one wave: prefill + fused decode + single fetch -----------------
    def _run_wave(self, wave: List[_Request],
                  extra_inputs: Optional[Dict[str, jax.Array]],
                  key: jax.Array) -> None:
        cfg = self.cfg
        b = cfg.max_batch
        # Validate BEFORE admitting: a rejected request must not leak slots.
        need = max(r.max_new for r in wave)    # real token budget (cache need)
        longest = max(len(r.prompt) for r in wave)
        if longest + need > cfg.max_len:       # submit()/_pack_wave guarantee
            raise ValueError(                  # this; keep the guard for raw
                f"prompt ({longest}) + max_new ({need}) exceeds "   # callers
                f"max_len ({cfg.max_len})")
        # The decode width is a pure buffer/loop bound (the fused loop stops
        # at each slot's budget and cache writes stay within plen + need),
        # so it keeps its power-of-two bucket unclamped — one compile per
        # need bucket.  The prompt pad length IS capacity-bound: bucket it,
        # clamped so near-capacity prompts share one clamped bucket instead
        # of falling back to exact per-length sizes (a recompile per
        # distinct prompt length).  The cap prefers the width bucket (fewer
        # distinct plens) and degrades to the exact need only when the
        # bucket would push below the prompt itself.
        width = _bucket_len(need)
        plen = _bucket_len(longest, cfg.max_len - width)
        if plen < longest:
            plen = _bucket_len(longest, cfg.max_len - need)
        if plen < longest:     # unreachable: longest + need <= max_len
            plen = longest
        if extra_inputs and any(r.row is None for r in wave):
            raise ValueError(
                "extra_inputs needs every request submitted with row= "
                "(its index into the extra arrays); generate() does this")
        for r in wave:
            self._sched.admit(r)
        try:
            self._decode_wave(wave, extra_inputs, key, plen, width)
        finally:
            # free slots even when prefill/decode throws — one bad request
            # must never brick the pool
            for r in wave:
                self._sched.evict(r)

    def _decode_wave(self, wave: List[_Request],
                     extra_inputs: Optional[Dict[str, jax.Array]],
                     key: jax.Array, plen: int, width: int) -> None:
        cfg = self.cfg
        b = cfg.max_batch
        toks = np.zeros((b, plen), np.int32)
        kv_start = np.full((b,), plen, np.int32)   # empty slots: fully padded
        budget = np.zeros((b,), np.int32)
        for r in wave:
            toks[r.slot, plen - len(r.prompt):] = r.prompt
            kv_start[r.slot] = plen - len(r.prompt)
            budget[r.slot] = r.max_new

        batch = {"tokens": jnp.asarray(toks),
                 "kv_start": jnp.asarray(kv_start)}
        if extra_inputs:
            rows = [r.row for r in wave]
            slots = [r.slot for r in wave]
            for name, arr in extra_inputs.items():
                padded = jnp.zeros((b,) + arr.shape[1:], arr.dtype)
                batch[name] = padded.at[jnp.asarray(slots)].set(
                    jnp.asarray(arr)[jnp.asarray(rows)])
        # Split the wave over the data axes (identity without a mesh).
        batch = self._place_batch(batch)
        # Loop CONTROL state (per-slot budgets/offsets and everything
        # derived from them: done flags, emitted-token buffer) stays
        # replicated: these are a handful of ints per slot, and sharding
        # them turns every ``done.all()`` / budget check inside the fused
        # loop into a blocking cross-device reduction.  Replicated, the
        # whole control path is local to each device; only the model step
        # itself (cache, activations) runs sharded.
        kv_start_d, budget_d = jnp.asarray(kv_start), jnp.asarray(budget)

        cache = self._ensure_cache()
        self._record_prefill_flash_tiles(plen)
        self._plen_buckets.add(int(plen))
        from repro.profiling import annotate
        t0 = time.perf_counter()
        with annotate("serve.prefill_wave"):
            logits0, cache = self._prefill(self.params, batch, cache)
            if cfg.profile:
                # deliberate sync: profile mode wants the true prefill /
                # decode wall-time split, not dispatch-pipeline overlap
                jax.block_until_ready(logits0)   # analysis: allow(TP001)
        t1 = time.perf_counter()

        if self._loop is None:
            self._loop = self._build_loop()
        unroll = min(self._resolve_unroll(), width)
        with annotate("serve.decode_wave"):
            buf, lens, cache = self._loop(
                self.params, cache, logits0, key, kv_start_d,
                budget_d, jnp.int32(plen), width=width, unroll=unroll)
            self._cache = cache

            # The ONE host transfer of this wave (== of the whole generate
            # call when the batch fits the slot pool).
            buf_h, lens_h = jax.device_get((buf, lens))  # analysis: allow(TP001)
        t2 = time.perf_counter()
        self._stats["device_transfers"] += 1
        self._stats["waves"] += 1
        self._stats["prefill_seconds"] += t1 - t0
        self._stats["decode_seconds"] += t2 - t1

        for r in wave:
            n = int(lens_h[r.slot])
            r.tokens = [int(t) for t in buf_h[r.slot, :n]]
            self._stats["tokens_generated"] += n

    # -- telemetry -------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        """Counters + tuned-block lookup provenance, as one plain dict.

        Beyond the raw counters (requests, tokens, waves, timings), the
        tuning-framework telemetry:

        * ``hardware`` / ``hardware_platform`` — the resolved hardware
          profile every tile lookup below was keyed by (provenance for
          bench artifacts and the CI backend matrix);
        * ``mesh`` / ``sharding`` — the device topology (axis name → size)
          and, on a mesh, the active sharding rules plus a histogram of the
          param partition specs they produced (``sharding`` is ``None``
          single-device);
        * ``decode_tile_lookups`` — each decode-step GEMM shape mapped to
          its resolved tile and provenance tier
          (``exact``/``nearest``/``generic``/``default``/``fallback``);
        * ``prefill_flash_lookups`` — for ``attention_impl="flash"`` models,
          each prefill bucket's ``(sq, skv, head_dim)`` mapped to its tuned
          ``(bq, bk)`` blocks and provenance;
        * ``registry_hit_stats`` — global per-tier lookup counts.

        Example::

            eng = Engine(model, params, ServeConfig(max_batch=4))
            eng.generate([[1, 2, 3]], max_new_tokens=8)
            eng.stats()["prefill_flash_lookups"]
            # {'8x8x64': {'source': 'nearest', 'tile': '128x128', ...}}
        """
        from repro.core.registry import GLOBAL_REGISTRY
        from repro.launch.mesh import describe_mesh
        out = dict(self._stats)
        out["hardware"] = self.hardware
        out["hardware_platform"] = self._platform
        out["mesh"] = describe_mesh(self.mesh)
        if self.mesh is None:
            out["sharding"] = None
        else:
            from repro.distributed import sharding as sh
            out["sharding"] = {
                "rules": {
                    "tensor_axis": self.rules.tensor_axis,
                    "fsdp_axis": self.rules.fsdp_axis,
                    "batch_axes": list(self.rules.batch_axes),
                    "sequence_axis": self.rules.sequence_axis,
                },
                "params": sh.sharding_summary(self.mesh, self.rules,
                                              self.model.template),
            }
        out["prefill_plen_buckets"] = sorted(self._plen_buckets)
        out["decode_unroll"] = self._unroll
        out["decode_unroll_source"] = self._unroll_source
        out["slots"] = self.cfg.max_batch
        out["slots_admitted"] = self._sched.admitted
        out["slots_evicted"] = self._sched.evicted
        out["slot_reuses"] = self._sched.reuses
        out["decode_tile_lookups"] = self._tile_lookups
        out["prefill_flash_lookups"] = dict(self._prefill_flash_lookups)
        out["registry_hit_stats"] = dict(GLOBAL_REGISTRY.hit_stats)
        return out
