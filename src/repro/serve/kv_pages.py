"""Paged KV-cache bookkeeping: page allocator, block tables, and the
host-side continuous-batching scheduler.

The wave engine reserves ``max_len`` cache columns per slot for a request's
whole lifetime, so capacity is ``slots x max_len`` regardless of what
requests actually use.  Here KV memory is a pool of fixed-size **pages**
(``page_size`` tokens each — the tuned ``paged_attn`` knob); each live
request holds a **block table** (its ordered page list), pages are allocated
lazily as decode advances and returned the moment a request finishes, and
capacity is measured in *tokens*.

Everything in this module is host-side and jax-free: the allocator and
scheduler are plain bookkeeping driven between fused decode chunks, which is
what makes them property-testable without touching a model.  The scheduler's
contract (enforced by ``tests/test_kv_pages.py``):

* **no double allocation** — a page leaves the free list exactly once, and
  the reserved NULL/TRASH pages are never handed out;
* **FIFO admission** — requests enter service in submit order (preemption
  requeues at the front, so it can only *re*-order a victim earlier, never
  starve it);
* **pages always return** — eviction and preemption release the exact pages
  allocated, so a drained scheduler (with an empty prefix cache) always
  restores full capacity;
* **capacity is never exceeded** — admission + lazy decode growth never
  allocate past the pool.

Pages are **refcounted** so the prefix cache (:mod:`repro.serve
.prefix_cache`) can pin prefilled prompt pages while live rows share them
read-only: ``alloc`` hands a page out at refcount 1, ``ref`` adds holders,
and ``free`` drops one holder — the page returns to the free list only when
the last holder lets go.  Under pool pressure the scheduler asks the cache
to give pages back first (the ``reclaim`` hook) and preempts live rows only
after the cache is dry, which preserves the pre-cache termination argument
("the oldest row always fits").

Two pages are reserved for the device-side gather/scatter encoding:

* page ``NULL_PAGE`` (0) stays all-zeros and backs every *read* of a column
  outside a row's content (pad columns, empty slots) — gathers from it are
  masked out by attention but must be finite;
* page ``TRASH_PAGE`` (1) absorbs every *write* with no allocated home
  (finished rows mid-chunk, empty slots).  Collisions are harmless because
  nothing ever reads it back.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

#: reserved page ids (see module docstring)
NULL_PAGE = 0
TRASH_PAGE = 1
RESERVED_PAGES = 2


class PagePoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied (caller preempts)."""


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` KV entries."""
    return -(-max(tokens, 0) // page_size)


class PageAllocator:
    """Fixed pool of KV pages with a free list and double-alloc guards.

    ``capacity_tokens`` is the *logical* capacity; the pool rounds it up to
    whole pages (plus the two reserved pages, which never count toward
    capacity).
    """

    def __init__(self, capacity_tokens: int, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if capacity_tokens < 1:
            raise ValueError(
                f"capacity_tokens must be >= 1, got {capacity_tokens}")
        self.page_size = int(page_size)
        self.capacity_tokens = int(capacity_tokens)
        self.usable_pages = pages_for(capacity_tokens, page_size)
        self.num_pages = RESERVED_PAGES + self.usable_pages
        self._free: List[int] = list(range(RESERVED_PAGES, self.num_pages))
        self._refs: Dict[int, int] = {}    # live page -> holder count
        self.alloc_count = 0
        self.free_count = 0
        self.high_water_pages = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.usable_pages - len(self._free)

    def utilization(self) -> float:
        return self.used_pages / max(self.usable_pages, 1)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            raise PagePoolExhausted(
                f"need {n} pages, {len(self._free)} free "
                f"(pool: {self.usable_pages} x {self.page_size} tokens)")
        pages, self._free = self._free[:n], self._free[n:]
        for p in pages:
            if p in self._refs or p < RESERVED_PAGES:
                raise RuntimeError(f"page {p} double-allocated")
            self._refs[p] = 1
        self.alloc_count += n
        self.high_water_pages = max(self.high_water_pages, self.used_pages)
        return pages

    def ref(self, pages: List[int]) -> None:
        """Add one holder to each (already-live) page — used when a row
        shares prefix-cache pages, or the cache pins a row's pages."""
        for p in pages:
            if p not in self._refs:
                raise RuntimeError(f"page {p} ref'd but not live")
            self._refs[p] += 1

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def free(self, pages: List[int]) -> None:
        """Drop one holder per page; pages return to the free list (and
        count toward ``free_count``) only when their last holder lets go."""
        released = []
        for p in pages:
            if p not in self._refs:
                raise RuntimeError(
                    f"page {p} freed but not live (double free or foreign)")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                released.append(p)
        self._free.extend(released)
        self._free.sort()
        self.free_count += len(released)


@dataclasses.dataclass
class RowState:
    """One admitted request's paged-cache view (host bookkeeping only)."""
    rid: int
    slot: int
    length: int                 # tokens with real KV written (prompt + decoded)
    budget_left: int            # tokens still to emit
    pages: List[int]
    admit_seq: int              # admission order, for youngest-first preemption

    def covered(self, page_size: int) -> int:
        return len(self.pages) * page_size


class ContinuousScheduler:
    """Slot + page bookkeeping for continuous batching.

    Drives the policy between fused decode chunks: strict-FIFO admission
    (a queued request enters service only when a slot AND its prompt's pages
    are free), lazy page growth ahead of each chunk, youngest-first
    preemption when the pool runs dry, and eviction the moment a row
    finishes.  The engine consumes it; the property suite drives it with a
    simulated decode.
    """

    def __init__(self, n_slots: int, allocator: PageAllocator):
        self.alloc = allocator
        self.n_slots = n_slots
        self._free_slots = list(range(n_slots))
        self.rows: Dict[int, RowState] = {}      # slot -> RowState
        self._seq = 0
        self.admissions = 0
        self.evictions = 0
        self.preemptions = 0
        # Optional pool-pressure escape hatch: ``reclaim(need_pages)`` asks
        # an external pin holder (the prefix cache) to release pages; it
        # returns True iff it made progress.  Consulted before preemption.
        self.reclaim: Optional[object] = None

    # -- admission ------------------------------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    def can_admit(self, prompt_len: int, shared_pages: int = 0) -> bool:
        """Whether the queue head fits right now.  ``shared_pages`` counts
        block-table entries served by the prefix cache (already live, so
        they need a ref, not an allocation)."""
        need = pages_for(prompt_len, self.alloc.page_size) - shared_pages
        return bool(self._free_slots) and self.alloc.can_alloc(max(need, 0))

    def admit(self, rid: int, prompt_len: int, budget: int,
              shared_pages: Optional[List[int]] = None) -> RowState:
        """Admit one request.  ``shared_pages`` (prefix-cache hit) become
        the head of the row's block table with a ref taken on each; only
        the remainder is freshly allocated."""
        if not self._free_slots:
            raise RuntimeError("no free slot")
        shared = list(shared_pages or [])
        need = pages_for(prompt_len, self.alloc.page_size) - len(shared)
        if need < 0:
            raise ValueError(
                f"{len(shared)} shared pages exceed the "
                f"{pages_for(prompt_len, self.alloc.page_size)} the prompt needs")
        self.alloc.ref(shared)
        pages = shared + self.alloc.alloc(need)
        slot = self._free_slots.pop(0)
        row = RowState(rid=rid, slot=slot, length=prompt_len,
                       budget_left=budget, pages=pages, admit_seq=self._seq)
        self._seq += 1
        self.rows[slot] = row
        self.admissions += 1
        return row

    # -- decode-chunk growth + preemption --------------------------------
    def ensure_chunk_pages(self, chunk: int) -> List[RowState]:
        """Grow every live row's block table to cover its next ``chunk``
        tokens, preempting youngest-admitted rows when the pool runs dry.

        Returns the preempted rows (pages freed, removed from service) —
        the caller requeues them at the queue *front* so FIFO order over
        first admissions is preserved.  Under pressure the ``reclaim`` hook
        (prefix-cache eviction) runs first and preemption only starts once
        it stops making progress, so cached-but-idle pages are always
        sacrificed before live work.  Oldest-first service plus the
        submit-time capacity check guarantee the oldest row always fits
        once the cache is dry, so this terminates and nothing starves.
        """
        preempted: List[RowState] = []
        for row in sorted(self.rows.values(), key=lambda r: r.admit_seq):
            if row in preempted:
                continue
            while True:
                want = row.length + min(chunk, row.budget_left)
                need = (pages_for(want, self.alloc.page_size)
                        - len(row.pages))
                if need <= 0 or self.alloc.can_alloc(need):
                    if need > 0:
                        row.pages.extend(self.alloc.alloc(need))
                    break
                if self.reclaim is not None and self.reclaim(need):
                    continue
                victim = max(self.rows.values(), key=lambda r: r.admit_seq)
                self._preempt(victim)
                preempted.append(victim)
                if victim is row:
                    break
        return preempted

    def _preempt(self, row: RowState) -> None:
        self.alloc.free(row.pages)
        row.pages = []
        del self.rows[row.slot]
        self._free_slots.append(row.slot)
        self._free_slots.sort()
        self.preemptions += 1

    # -- eviction --------------------------------------------------------
    def evict(self, row: RowState) -> None:
        self.alloc.free(row.pages)
        row.pages = []
        del self.rows[row.slot]
        self._free_slots.append(row.slot)
        self._free_slots.sort()
        self.evictions += 1

    def evict_all(self) -> None:
        for row in list(self.rows.values()):
            self.evict(row)

    @property
    def live(self) -> List[RowState]:
        return sorted(self.rows.values(), key=lambda r: r.admit_seq)


# ---------------------------------------------------------------------------
# Flat gather/scatter index computation (host -> device, numpy int32)
# ---------------------------------------------------------------------------
# The fused chunk step sees the paged pool as one flat token axis of
# ``num_pages * page_size`` entries; these helpers translate block tables
# into per-chunk index arrays.  Columns outside a row's content read the
# NULL page (zeros, masked by attention); writes with no allocated home land
# in the TRASH page (never read back).

def gather_indices(rows: Dict[int, RowState], n_slots: int, width: int,
                   chunk: int, page_size: int) -> np.ndarray:
    """(n_slots, width) flat pool indices right-aligning each row's KV.

    Column ``c`` of slot ``b`` maps to the row's logical token
    ``c - kv_start_b`` where ``kv_start_b = (width - chunk) - length_b``, so
    all live content ends at the shared column ``width - chunk`` and the
    chunk's new columns land at ``[width - chunk, width)``.
    """
    idx = np.zeros((n_slots, width), np.int32)        # default: NULL page
    cols = np.arange(width)
    offset0 = width - chunk
    for slot, row in rows.items():
        logical = cols - (offset0 - row.length)
        valid = (logical >= 0) & (logical < row.length)
        # row.pages is host scheduler state (a Python list), never traced
        pages = np.asarray(row.pages, np.int64)  # analysis: allow(TP001)
        lv = logical[valid]
        idx[slot, valid] = pages[lv // page_size] * page_size + lv % page_size
    return idx


def scatter_indices(rows: Dict[int, RowState], n_slots: int, chunk: int,
                    page_size: int) -> np.ndarray:
    """(n_slots, chunk) flat pool indices for the chunk's new KV columns.

    New token ``j`` of slot ``b`` is logical position ``length_b + j``;
    positions beyond the row's allocated pages (i.e. past its remaining
    budget) and all positions of empty slots write to the TRASH page.
    """
    j = np.arange(chunk)
    idx = np.broadcast_to(TRASH_PAGE * page_size + j % page_size,
                          (n_slots, chunk)).astype(np.int32).copy()
    for slot, row in rows.items():
        logical = row.length + j
        covered = logical < row.covered(page_size)
        # row.pages is host scheduler state (a Python list), never traced
        pages = np.asarray(row.pages, np.int64)  # analysis: allow(TP001)
        lc = logical[covered]
        idx[slot, covered] = pages[lc // page_size] * page_size \
            + lc % page_size
    return idx
