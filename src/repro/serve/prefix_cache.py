"""Prefix cache: shared-prefix KV reuse over the paged pool.

Multi-tenant traffic repeats prompt prefixes (system prompts, few-shot
headers, chat history).  The paged KV cache already decouples a request's
logical KV from physical placement, so sharing is pure bookkeeping: this
module pins prefilled prompt pages in the :class:`~repro.serve.kv_pages
.PageAllocator` (refcounts) and hands them to later requests whose prompts
share the prefix — block tables point at shared read-only pages, and only
the page straddling the divergence point is copied (copy-on-write, see
:func:`repro.kernels.paged.paged_copy`).

Structure: a **trie keyed by page-sized token chunks**.  Each non-root node
owns one pinned page holding the KV of exactly one full ``page_size`` token
chunk; a path from the root spells out a page-aligned prefix.  A node
additionally stores **full-prompt entries** keyed by the prompt's sub-page
tail: an entry pins the tail page plus the per-row device state needed to
skip prefill entirely (the sampled-from logits of the prompt's last
position, and the row's fixed cache leaves — SSM/conv state for hybrids).
Chunk keys are exact token tuples, not hashes, so there are no collision
cases to reason about.

Hit taxonomy (``Engine._admit_batch`` consumes this):

* **full** — the prompt equals a cached entry's prompt token-for-token.
  Admission skips prefill: shared full pages + a COW copy of the tail page
  + the entry's snapshot restore the row exactly; the first token is
  re-sampled from the cached logits (bit-identical under greedy decoding).
  This is the prefill-FLOPs saving.
* **partial** — a page-aligned prefix matches.  The row's block table
  points at the shared pages and prefill still runs over the whole prompt
  for exactness, but its writes for shared columns are redirected to the
  TRASH page — a pages-written saving that also dedups pool memory.
* **miss** — nothing shared; after prefill the prompt's pages and the
  full-prompt entry are inserted, so the *next* request pays less.

Eviction is **LRU under pool pressure**: the scheduler's ``reclaim`` hook
and admission both evict least-recently-used leaves (entries first, then
childless nodes) until the allocator can satisfy the demand — so the cache
never blocks live work, and composes with preemption (rows are preempted
only once the cache is dry).

Content addressing is host-side and cheap; the pinned device state per
entry is one logits row plus the fixed leaves — small next to the KV pages
themselves, which are shared rather than duplicated.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.kv_pages import PageAllocator

#: provenance strings, re-exported for engine bookkeeping
HIT_FULL = "full"
HIT_PARTIAL = "partial"


@dataclasses.dataclass
class PrefixMatch:
    """One lookup's outcome: the shareable page chain (refs NOT yet taken —
    the scheduler takes them at admit) and, for full hits, the entry whose
    snapshot restores the row without prefill."""
    pages: List[int]                    # shared full pages, prefix order
    tokens: int                         # prompt tokens those pages cover
    full: bool = False
    entry: Optional["_Entry"] = None


class _Entry:
    """A cached full prompt: tail page + device snapshot to skip prefill."""
    __slots__ = ("prompt_len", "tail_page", "logits0", "fixed", "last_used")

    def __init__(self, prompt_len: int, tail_page: Optional[int],
                 logits0, fixed, last_used: int):
        self.prompt_len = prompt_len
        self.tail_page = tail_page      # None when the prompt is page-aligned
        self.logits0 = logits0          # (vocab,) last-position logits row
        self.fixed = fixed              # per-row fixed cache leaves (tree)
        self.last_used = last_used


class _Node:
    """One full page-sized chunk of cached prefix (root: chunk=page=None)."""
    __slots__ = ("chunk", "page", "parent", "children", "entries",
                 "last_used")

    def __init__(self, chunk: Optional[Tuple[int, ...]], page: Optional[int],
                 parent: Optional["_Node"], last_used: int):
        self.chunk = chunk
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.entries: Dict[Tuple[int, ...], _Entry] = {}
        self.last_used = last_used


class PrefixCache:
    """Trie of pinned prompt-prefix pages over one :class:`PageAllocator`.

    The engine owns the only references between chunk boundaries, so all
    methods are host-side, single-threaded bookkeeping.
    """

    def __init__(self, allocator: PageAllocator):
        self.alloc = allocator
        self.page_size = allocator.page_size
        self._root = _Node(None, None, None, 0)
        self._tick = 0
        self._nodes = 0
        self._entries = 0
        # counters surfaced via stats() — one admission decision each
        self.lookups = 0
        self.hits_full = 0
        self.hits_partial = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0
        self.cached_tokens_served = 0
        self.prefill_tokens_saved = 0
        self.prefill_tokens_computed = 0
        self.pages_write_skipped = 0

    # -- internals -------------------------------------------------------
    def _touch(self) -> int:
        self._tick += 1
        return self._tick

    def _chunks(self, prompt: Sequence[int]):
        page = self.page_size
        toks = list(prompt)
        nfull = len(toks) // page
        full = [tuple(toks[i * page:(i + 1) * page]) for i in range(nfull)]
        return full, tuple(toks[nfull * page:])

    # -- lookup ----------------------------------------------------------
    def match(self, prompt: Sequence[int]) -> Optional[PrefixMatch]:
        """Longest cached page-aligned prefix of ``prompt`` (or the full
        entry).  Pure lookup: takes no refs and bumps no hit counters —
        admission may retry after evictions, so the engine records the
        decision once via :meth:`record_admit`."""
        full_chunks, tail = self._chunks(prompt)
        node, pages = self._root, []
        for chunk in full_chunks:
            child = node.children.get(chunk)
            if child is None:
                break
            node = child
            node.last_used = self._touch()
            pages.append(node.page)
        if len(pages) == len(full_chunks):
            entry = node.entries.get(tail)
            if entry is not None:
                entry.last_used = self._touch()
                return PrefixMatch(pages=pages, tokens=entry.prompt_len,
                                   full=True, entry=entry)
        if pages:
            return PrefixMatch(pages=pages,
                               tokens=len(pages) * self.page_size)
        return None

    def record_admit(self, match: Optional[PrefixMatch],
                     prompt_len: int) -> None:
        """Account one admission decision (exactly once per admitted row)."""
        self.lookups += 1
        if match is None:
            self.misses += 1
            self.prefill_tokens_computed += prompt_len
        elif match.full:
            self.hits_full += 1
            self.cached_tokens_served += prompt_len
            self.prefill_tokens_saved += prompt_len
        else:
            self.hits_partial += 1
            self.cached_tokens_served += match.tokens
            self.pages_write_skipped += len(match.pages)
            self.prefill_tokens_computed += prompt_len

    # -- insertion -------------------------------------------------------
    def insert(self, prompt: Sequence[int], row_pages: Sequence[int],
               logits0, fixed) -> bool:
        """Pin ``prompt``'s pages (taken from the freshly-prefilled row's
        block table) and store its full entry.  Existing chunks/entries are
        deduped — the row keeps its own pages either way.  Returns whether
        anything new was pinned."""
        full_chunks, tail = self._chunks(prompt)
        node, new = self._root, False
        for i, chunk in enumerate(full_chunks):
            child = node.children.get(chunk)
            if child is None:
                page = row_pages[i]
                self.alloc.ref([page])
                child = _Node(chunk, page, node, self._touch())
                node.children[chunk] = child
                self._nodes += 1
                new = True
            else:
                child.last_used = self._touch()
            node = child
        if tail not in node.entries:
            tail_page = None
            if tail:
                tail_page = row_pages[len(full_chunks)]
                self.alloc.ref([tail_page])
            node.entries[tail] = _Entry(len(prompt), tail_page, logits0,
                                        fixed, self._touch())
            self._entries += 1
            new = True
        else:
            node.entries[tail].last_used = self._touch()
        if new:
            self.inserts += 1
        return new

    # -- eviction --------------------------------------------------------
    def _candidates(self):
        """Evictable items: every entry, plus childless+entryless nodes
        (inner chunk pages stay pinned while anything below needs them)."""
        stack = [self._root]
        while stack:
            node = stack.pop()
            for tail, entry in node.entries.items():
                yield (entry.last_used, "entry", node, tail, entry)
            for child in node.children.values():
                if not child.children and not child.entries:
                    yield (child.last_used, "node", child, None, None)
                stack.append(child)

    def evict_one(self) -> bool:
        """Evict the least-recently-used evictable item (one entry or one
        leaf chunk node); returns False when the cache is empty."""
        best = min(self._candidates(), key=lambda c: c[0], default=None)
        if best is None:
            return False
        _, kind, node, tail, entry = best
        if kind == "entry":
            if entry.tail_page is not None:
                self.alloc.free([entry.tail_page])
            del node.entries[tail]
            self._entries -= 1
        else:
            self.alloc.free([node.page])
            del node.parent.children[node.chunk]
            self._nodes -= 1
        self.evictions += 1
        return True

    def reclaim(self, need_pages: int) -> bool:
        """Pool-pressure hook (scheduler + admission): evict LRU items
        until ``need_pages`` are allocatable or the cache is dry.  Returns
        whether any eviction happened (progress)."""
        progress = False
        while not self.alloc.can_alloc(need_pages) and self.evict_one():
            progress = True
        return progress

    def clear(self) -> None:
        """Release every pinned page (cold-cache reset; used by parity
        tests and benchmarks)."""
        while self.evict_one():
            pass

    # -- telemetry -------------------------------------------------------
    @property
    def pinned_pages(self) -> int:
        pinned = self._nodes
        stack = [self._root]
        while stack:
            node = stack.pop()
            pinned += sum(1 for e in node.entries.values()
                          if e.tail_page is not None)
            stack.extend(node.children.values())
        return pinned

    def stats(self) -> Dict[str, object]:
        return {
            "enabled": True,
            "lookups": self.lookups,
            "hits_full": self.hits_full,
            "hits_partial": self.hits_partial,
            "misses": self.misses,
            "inserts": self.inserts,
            "evictions": self.evictions,
            "entries": self._entries,
            "nodes": self._nodes,
            "pinned_pages": self.pinned_pages,
            "cached_tokens_served": self.cached_tokens_served,
            "prefill_tokens_saved": self.prefill_tokens_saved,
            "prefill_tokens_computed": self.prefill_tokens_computed,
            "pages_write_skipped": self.pages_write_skipped,
        }

    @staticmethod
    def disabled_stats() -> Dict[str, object]:
        """The same key set with zeros, for engines running without a
        cache (wave scheduler, ``prefix_cache=False``) — stats consumers
        never branch on key presence."""
        st = {k: 0 for k in PrefixCache(
            PageAllocator(1, 1)).stats()}
        st["enabled"] = False
        return st
