"""Reference generation loops — correctness oracle and throughput baseline.

``generate_per_prompt`` is the trust anchor for ragged-batch parity tests:
each prompt runs alone (batch 1, no padding, no masking), so whatever it
produces is by construction what a request "should" get.

``generate_per_token_sync`` reproduces the seed engine's execution model —
batched, but with one ``jax.device_get`` per decoded token — and serves as
the baseline the serving benchmark measures the fused engine against.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.profiling import annotate


def _greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def generate_per_prompt(model: Model, params, prompts: List[List[int]],
                        max_new_tokens: int, max_len: int = 512,
                        eos_token: Optional[int] = None,
                        extra_inputs: Optional[Dict[str, jax.Array]] = None
                        ) -> List[List[int]]:
    """Greedy generation, one prompt at a time (batch 1, no padding)."""
    outs = []
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    for i, prompt in enumerate(prompts):
        batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
        if extra_inputs:
            batch.update({k: v[i:i + 1] for k, v in extra_inputs.items()})
        cache = model.init_cache(1, max_len)
        with annotate("reference.prefill"):
            logits, cache = prefill(params, batch, cache)
        offset = jnp.int32(len(prompt))
        cur = _greedy(logits)
        toks: List[int] = []
        for _ in range(max_new_tokens):
            # by-design per-token sync: the oracle trades throughput for the
            # simplest possible trust chain (one prompt, one token at a time)
            t = int(jax.device_get(cur)[0])      # analysis: allow(TP001)
            toks.append(t)
            if eos_token is not None and t == eos_token:
                break
            if len(toks) == max_new_tokens:
                break
            with annotate("reference.decode"):
                logits, cache = decode(params, cur[:, None], cache, offset)
            offset = offset + 1
            cur = _greedy(logits)
        outs.append(toks)
    return outs


class PerTokenSyncEngine:
    """Batched greedy generation with a host sync per token (the seed
    engine's execution model; prompts must share one length — no ragged
    handling).  Prefill/decode are jitted once per instance so repeated
    calls measure steady-state throughput, not compilation.

    ``mesh=`` shards params by the same inference rules the fused engine
    uses, so the serving benchmark's fused-vs-sync ratio compares the two
    *execution models* on an identical topology — per-token host syncs
    (each one a full cross-device round-trip on a mesh) against the fused
    device-resident loop — rather than conflating the loop structure with
    single-device-vs-sharded placement."""

    def __init__(self, model: Model, params, max_len: int = 512,
                 eos_token: Optional[int] = None, profile: bool = False,
                 mesh=None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.eos_token = eos_token
        self.profile = profile             # split prefill/decode wall time
        self.last_prefill_s = 0.0
        self.last_decode_s = 0.0
        if isinstance(mesh, str):
            from repro.launch.mesh import build_mesh
            mesh = build_mesh(mesh)
        self.mesh = mesh
        self.rules = None
        if mesh is not None:
            from repro.distributed import sharding as sh
            self.rules = sh.rules_for_mesh(mesh, fsdp=False)
            self.params = sh.shard_params(params, mesh, self.rules,
                                          model.template)
        self._prefill = jax.jit(self._with_mesh(model.prefill))
        self._decode = jax.jit(self._with_mesh(model.decode_step))

    def _with_mesh(self, fn):
        """Trace under the mesh's activation policy (identity without one) —
        the same wrapper the fused engine applies."""
        if self.mesh is None:
            return fn
        mesh, rules = self.mesh, self.rules

        def wrapped(*args, **kwargs):
            from repro.distributed.ctx import activation_policy
            with activation_policy(mesh, rules):
                return fn(*args, **kwargs)

        return wrapped

    def generate(self, prompts: List[List[int]], max_new_tokens: int
                 ) -> List[List[int]]:
        plens = {len(p) for p in prompts}
        if len(plens) != 1:
            raise ValueError("per-token-sync baseline expects uniform prompt "
                             f"lengths, got {sorted(plens)}")
        (plen,) = plens
        b = len(prompts)
        t0 = time.perf_counter()
        cache = self.model.init_cache(b, self.max_len)
        with annotate("reference.prefill"):
            logits, cache = self._prefill(
                self.params,
                {"tokens": jnp.asarray(np.array(prompts, np.int32))},
                cache)
        if self.profile:
            # deliberate sync: the prefill/decode wall-time split is the
            # whole point of profile mode
            jax.block_until_ready(logits)        # analysis: allow(TP001)
        t1 = time.perf_counter()
        offset = jnp.int32(plen)
        cur = _greedy(logits)
        outs: List[List[int]] = [[] for _ in range(b)]
        done = np.zeros(b, bool)
        for step in range(max_new_tokens):
            # the per-token sync IS this baseline's execution model — the
            # cost the fused engine's speedup ratio is measured against
            cur_np = np.asarray(jax.device_get(cur))   # analysis: allow(TP001)
            for i in range(b):
                if not done[i]:
                    outs[i].append(int(cur_np[i]))
                    if self.eos_token is not None and cur_np[i] == self.eos_token:
                        done[i] = True
            if done.all() or step == max_new_tokens - 1:
                break
            with annotate("reference.decode"):
                logits, cache = self._decode(self.params, cur[:, None],
                                             cache, offset)
            offset = offset + 1
            cur = _greedy(logits)
        self.last_prefill_s = t1 - t0
        self.last_decode_s = time.perf_counter() - t1
        return outs


def generate_per_token_sync(model: Model, params, prompts: List[List[int]],
                            max_new_tokens: int, max_len: int = 512,
                            eos_token: Optional[int] = None
                            ) -> List[List[int]]:
    """One-shot convenience wrapper around :class:`PerTokenSyncEngine`."""
    return PerTokenSyncEngine(model, params, max_len, eos_token
                              ).generate(prompts, max_new_tokens)
