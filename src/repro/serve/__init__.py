from repro.serve.api import (  # noqa: F401
    GenerationResult, Request, RequestHandle, StreamEvent,
)
from repro.serve.engine import Engine, ServeConfig  # noqa: F401
from repro.serve.reference import (  # noqa: F401
    PerTokenSyncEngine, generate_per_prompt, generate_per_token_sync,
)
from repro.serve.server import Server  # noqa: F401
