"""Async streaming front-end: a long-lived server over one Engine.

The Engine is single-threaded by design — every jitted call, page table and
counter is touched from one thread.  The :class:`Server` puts that thread
to work continuously (MaxText's ``OfflineInference``/``JetThread`` shape):

* callers on any thread ``submit(Request)`` into a queue and immediately
  get a :class:`~repro.serve.api.RequestHandle`;
* one daemon **worker thread** owns the engine: it drains the queue into
  ``engine.submit`` and calls ``engine.run()``;
* while a drain is in flight, the engine polls the server's **ingest hook**
  at every decode-chunk boundary, so requests arriving mid-drain join the
  live batch without waiting for it to finish — true continuous ingestion,
  not run-to-completion batching;
* per-token ``stream`` callbacks and handle resolution happen on the
  worker thread the moment tokens/results are host-visible, so TTFT in
  ``stats()["latency"]`` measures the real submit-to-first-token path.

Requests served through a Server cannot use ``extra_inputs``-style shared
arrays (``Request.row`` must be None): extras are positional per drain,
which contradicts open-ended ingestion.

Example::

    with Server(engine) as srv:
        h = srv.submit(Request(prompt=[5, 9, 2], max_new_tokens=16,
                               stream=print))
        tokens = h.result(timeout=60).tokens
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, List, Optional, Tuple

from repro.serve import api


class Server:
    """Threaded request ingestion + streaming over one Engine.

    Args:
      engine: a :class:`repro.serve.Engine`.  The server owns it while
        running — no other thread may call it.
      poll_timeout_s: how long the idle worker blocks waiting for the next
        request before re-checking for shutdown.
    """

    def __init__(self, engine, poll_timeout_s: float = 0.05):
        self.engine = engine
        self.poll_timeout_s = float(poll_timeout_s)
        self._ingest: "queue.Queue[Tuple[api.Request, api.RequestHandle]]" \
            = queue.Queue()
        self._stop = threading.Event()
        self._worker: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._submitted = 0
        self._served = 0
        self._failed = 0

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "Server":
        if self._worker is not None:
            raise RuntimeError("server already started")
        self._stop.clear()
        self.engine._ingest_hook = self._poll_ingest
        self._worker = threading.Thread(target=self._work, name="serve-worker",
                                        daemon=True)
        self._worker.start()
        return self

    def stop(self, drain: bool = True, timeout: Optional[float] = 30.0
             ) -> None:
        """Shut the worker down.  ``drain=True`` serves everything already
        submitted first; ``drain=False`` fails queued-but-unstarted
        requests with ``RuntimeError``."""
        if self._worker is None:
            return
        if not drain:
            self._drop_pending(RuntimeError("server stopped before serving"))
        self._stop.set()
        self._worker.join(timeout)
        alive = self._worker.is_alive()
        self._worker = None
        self.engine._ingest_hook = None
        if alive:
            raise RuntimeError(f"server worker did not stop in {timeout}s")

    def __enter__(self) -> "Server":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop(drain=exc_type is None)

    # -- ingestion (any thread) -----------------------------------------
    def submit(self, request: api.Request) -> api.RequestHandle:
        """Queue one request; returns its handle immediately.  The engine
        assigns the request id when the worker ingests it (handles resolve
        regardless)."""
        if self._worker is None or self._stop.is_set():
            raise RuntimeError("server is not running")
        if request.row is not None:
            raise ValueError(
                "server-mode requests cannot carry row=/extra_inputs; "
                "use Engine.generate for extras workloads")
        handle = api.RequestHandle()
        with self._lock:
            self._submitted += 1
        self._ingest.put((request, handle))
        return handle

    # -- worker thread ---------------------------------------------------
    def _poll_ingest(self) -> List[Tuple[api.Request, api.RequestHandle]]:
        """Engine callback at each chunk/wave boundary: everything queued
        since the last boundary joins the live batch."""
        items = []
        while True:
            try:
                items.append(self._ingest.get_nowait())
            except queue.Empty:
                return items

    def _work(self) -> None:
        while True:
            if self._ingest.empty():
                if self._stop.is_set():
                    return
                try:
                    item = self._ingest.get(timeout=self.poll_timeout_s)
                except queue.Empty:
                    continue
                self._ingest.put(item)      # run()'s ingest poll takes it
            try:
                results = self.engine.run()
            except Exception as exc:
                # engine.run already failed the handles of active rows;
                # anything still in the ingest queue fails here so no
                # caller blocks forever on a dead drain
                self._drop_pending(exc)
                with self._lock:
                    self._failed += 1
                continue
            with self._lock:
                self._served += len(results)

    def _drop_pending(self, exc: BaseException) -> None:
        for _, handle in self._poll_ingest():
            if not handle.done:
                handle._set_error(exc)

    # -- telemetry -------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        """Engine stats (schema v2) plus a ``server`` counter block."""
        st = self.engine.stats()
        with self._lock:
            st["server"] = {
                "submitted": self._submitted,
                "served": self._served,
                "failed_drains": self._failed,
                "pending": self._ingest.qsize(),
                "running": self._worker is not None,
            }
        return st
