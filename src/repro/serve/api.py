"""Public request/response types for the serve engine.

The engine grew up around bare ints and raw token lists: ``submit(prompt,
max_new_tokens)`` returned a request id and ``run()`` returned
``{rid: [token, ...]}``.  That surface can't carry what a long-lived server
needs — per-request timing, finish reasons, prefix-cache provenance, or a
stream callback — so this module defines the typed API:

* :class:`Request` — what a caller wants generated (prompt, budget, optional
  per-token stream callback).  ``Engine.submit(Request)`` returns a
  :class:`RequestHandle`.
* :class:`StreamEvent` — one token (or the terminal event) delivered to a
  request's ``stream`` callback at each decode-chunk boundary.
* :class:`GenerationResult` — the finished request: tokens, finish reason,
  TTFT / throughput, and how much of the prompt was served from the prefix
  cache.
* :class:`RequestHandle` — a future for one request; ``result()`` blocks
  until the engine drains it (the :class:`repro.serve.server.Server` resolves
  handles from its worker thread).

The legacy positional ``submit(prompt, max_new_tokens)`` / dict-of-tokens
``run()`` surface still works behind a one-per-process
``DeprecationWarning`` (see ``docs/SERVING.md`` for migration notes).

Everything here is host-side and jax-free.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, List, Optional, Sequence

#: finish reasons carried by GenerationResult / terminal StreamEvent
FINISH_STOP = "stop"        # the EOS token was emitted
FINISH_LENGTH = "length"    # the max_new_tokens budget was exhausted

#: prefix-cache provenance values (``None`` on GenerationResult = cold)
PREFIX_HIT_FULL = "full"        # whole prompt served from cache, no prefill
PREFIX_HIT_PARTIAL = "partial"  # page-aligned prefix shared, prefill re-run


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One streamed token (or the terminal event) for a request.

    Token events arrive in order with ``finished=False`` as each decode
    chunk reaches the host; the terminal event carries ``token=None``,
    ``finished=True`` and the finish reason.  ``index`` is the token's
    position in the generated sequence (== count of tokens delivered so
    far for the terminal event).
    """
    request_id: int
    token: Optional[int]
    index: int
    finished: bool = False
    finish_reason: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request for :meth:`Engine.submit`.

    Args:
      prompt: non-empty token-id sequence.
      max_new_tokens: decode budget (>= 1).
      row: index of this request in the ``extra_inputs`` arrays later
        passed to ``run()`` (required when extras are used; ``generate``
        fills it automatically).
      stream: optional callback invoked with a :class:`StreamEvent` per
        generated token plus one terminal event.  Called from the thread
        driving the engine (the server's worker thread in server mode).
      temperature: optional sampling-temperature assertion.  The engine is
        compiled against one ``ServeConfig.temperature``; a Request that
        names a different one is rejected at submit instead of silently
        sampling at the wrong temperature.
    """
    prompt: Sequence[int]
    max_new_tokens: int
    row: Optional[int] = None
    stream: Optional[Callable[[StreamEvent], None]] = None
    temperature: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class GenerationResult:
    """One finished request, as returned by ``Engine.run()``.

    ``tokens`` matches the legacy raw-token return exactly (the EOS token,
    when hit, is included).  ``ttft_s`` is submit-to-first-token-host-
    visible; ``tok_per_s`` is ``len(tokens) / total_s``.  ``prefix_hit`` is
    ``"full"`` / ``"partial"`` / ``None`` with ``cached_prefix_tokens``
    counting the prompt tokens served from the prefix cache.
    """
    request_id: int
    tokens: List[int]
    finish_reason: str
    prompt_len: int
    ttft_s: Optional[float]
    total_s: float
    tok_per_s: float
    prefix_hit: Optional[str] = None
    cached_prefix_tokens: int = 0


class RequestHandle:
    """Future for one submitted :class:`Request`.

    The engine resolves the handle the moment the request finishes (not at
    the end of the drain), so server-mode callers see results at request
    granularity.  ``result()`` re-raises the engine's exception when the
    drain died under the request.
    """

    def __init__(self, request_id: int = -1):
        self.request_id = request_id
        self._done = threading.Event()
        self._result: Optional[GenerationResult] = None
        self._error: Optional[BaseException] = None

    # -- engine side ----------------------------------------------------
    def _set_result(self, result: GenerationResult) -> None:
        self._result = result
        self._done.set()

    def _set_error(self, exc: BaseException) -> None:
        self._error = exc
        self._done.set()

    # -- caller side ----------------------------------------------------
    @property
    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> GenerationResult:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not finished after {timeout}s")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result
