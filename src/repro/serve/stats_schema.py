"""Versioned schema for ``Engine.stats()`` — the documented, frozen key set.

``stats()`` is the engine's public telemetry surface: launchers print it,
benchmarks persist it into ``BENCH_*.json`` artifacts, and CI renders it
into step summaries.  Eight PRs of accretion made its key set implicit —
every consumer hand-picked keys and silently broke when one drifted.  This
module is the single source of truth:

* ``SCHEMA_VERSION`` — bumped whenever a key is added/removed/renamed;
  ``stats()["schema_version"]`` carries it.
* ``STATS_SCHEMA`` — every top-level key, its display group, when it is
  present (``always`` vs ``continuous``-scheduler engines), and a one-line
  description (rendered into ``docs/SERVING.md`` and CI step summaries).
* ``PAGES_KEYS`` / ``PREFIX_CACHE_KEYS`` / ``LATENCY_KEYS`` — the nested
  dict sub-schemas.
* :func:`validate_stats` — runtime check that a stats dict matches the
  schema exactly (no missing, no undocumented keys).

Two gates keep this honest: the ST001 static check
(``repro.analysis.stats_checks``) diffs the keys ``engine.py`` *emits*
against this schema at ``analyze`` time, and the serve test suite runs
:func:`validate_stats` against live engines.  Drift fails CI either way.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

#: bump on any key add/remove/rename (v1 = the implicit pre-schema dict)
SCHEMA_VERSION = 2

#: presence conditions
ALWAYS = "always"
CONTINUOUS = "continuous"       # only on continuous-scheduler engines


@dataclasses.dataclass(frozen=True)
class StatKey:
    group: str
    when: str
    doc: str


#: display-group order for renderers (ci_step_summary, docs)
GROUP_ORDER = [
    "schema", "traffic", "timing", "latency", "scheduler", "paged",
    "prefix_cache", "hardware", "tuning",
]

STATS_SCHEMA: Dict[str, StatKey] = {
    # -- schema ----------------------------------------------------------
    "schema_version": StatKey("schema", ALWAYS,
                              "stats schema version (this file)"),
    # -- traffic counters ------------------------------------------------
    "requests": StatKey("traffic", ALWAYS, "requests ever submitted"),
    "tokens_generated": StatKey("traffic", ALWAYS,
                                "total tokens emitted across requests"),
    "generate_calls": StatKey("traffic", ALWAYS,
                              "batched generate() invocations"),
    "waves": StatKey("traffic", ALWAYS,
                     "wave-scheduler decode waves executed"),
    "chunks": StatKey("traffic", ALWAYS,
                      "continuous-scheduler fused decode chunks executed"),
    "admission_prefills": StatKey("traffic", ALWAYS,
                                  "batched admission prefill calls"),
    "device_transfers": StatKey("traffic", ALWAYS,
                                "device->host fetches (one per chunk/wave)"),
    "cache_allocs": StatKey("traffic", ALWAYS,
                            "KV pool/cache allocations (1 per engine)"),
    # -- timing ----------------------------------------------------------
    "prefill_seconds": StatKey("timing", ALWAYS,
                               "wall-clock in prefill (incl. cache restore)"),
    "decode_seconds": StatKey("timing", ALWAYS,
                              "wall-clock in fused decode"),
    "total_seconds": StatKey("timing", ALWAYS,
                             "wall-clock across generate() calls"),
    # -- latency percentiles --------------------------------------------
    "latency": StatKey("latency", ALWAYS,
                       "per-request TTFT / tok-per-s percentiles "
                       "(LATENCY_KEYS sub-schema)"),
    # -- scheduler -------------------------------------------------------
    "scheduler": StatKey("scheduler", ALWAYS,
                         "'continuous' or 'wave' (the resolved one)"),
    "scheduler_forced": StatKey("scheduler", ALWAYS,
                                "why a continuous config fell back to wave "
                                "(None otherwise)"),
    "slots": StatKey("scheduler", ALWAYS, "KV-cache slot count (max_batch)"),
    "slots_admitted": StatKey("scheduler", ALWAYS,
                              "requests ever admitted into a slot"),
    "slots_evicted": StatKey("scheduler", ALWAYS,
                             "requests ever evicted from a slot"),
    "slot_reuses": StatKey("scheduler", ALWAYS,
                           "slot admissions beyond each slot's first"),
    # -- paged pool (continuous engines only) ---------------------------
    "decode_chunk": StatKey("paged", CONTINUOUS,
                            "tokens per fused chunk between boundaries"),
    "capacity_tokens": StatKey("paged", CONTINUOUS,
                               "paged-pool capacity in tokens"),
    "page_size": StatKey("paged", CONTINUOUS,
                         "resolved page size in tokens"),
    "page_size_source": StatKey("paged", CONTINUOUS,
                                "page-size provenance (config/tuned:*)"),
    "pages": StatKey("paged", CONTINUOUS,
                     "allocator gauge dict (PAGES_KEYS sub-schema; None "
                     "before the pool is built)"),
    "admissions": StatKey("paged", CONTINUOUS,
                          "continuous-scheduler admissions"),
    "evictions": StatKey("paged", CONTINUOUS,
                         "continuous-scheduler evictions"),
    "preemptions": StatKey("paged", CONTINUOUS,
                           "rows preempted under pool pressure"),
    # -- prefix cache ----------------------------------------------------
    "prefix_cache": StatKey("prefix_cache", ALWAYS,
                            "prefix-cache counters (PREFIX_CACHE_KEYS "
                            "sub-schema; enabled=False zeros when off)"),
    # -- hardware / mesh -------------------------------------------------
    "hardware": StatKey("hardware", ALWAYS, "resolved hardware profile key"),
    "hardware_platform": StatKey("hardware", ALWAYS,
                                 "profile's platform (tpu/gpu/cpu/...)"),
    "mesh": StatKey("hardware", ALWAYS,
                    "device-mesh description (axis=size,...)"),
    "sharding": StatKey("hardware", ALWAYS,
                        "sharding rules + param-spec histogram "
                        "(None single-device)"),
    # -- tuning provenance ----------------------------------------------
    "prefill_plen_buckets": StatKey("tuning", ALWAYS,
                                    "prompt-length buckets compiled so far"),
    "decode_unroll": StatKey("tuning", ALWAYS,
                             "resolved fused-loop unroll factor"),
    "decode_unroll_source": StatKey("tuning", ALWAYS,
                                    "unroll provenance (config/tuned:*/"
                                    "heuristic)"),
    "decode_tile_lookups": StatKey("tuning", ALWAYS,
                                   "decode GEMM shape -> tuned tile + tier"),
    "prefill_flash_lookups": StatKey("tuning", ALWAYS,
                                     "flash prefill bucket -> tuned blocks"),
    "registry_hit_stats": StatKey("tuning", ALWAYS,
                                  "global registry lookups per tier"),
}

#: nested sub-schema: stats()["pages"]
PAGES_KEYS = [
    "page_size", "usable_pages", "used_pages", "free_pages", "utilization",
    "high_water_pages", "alloc_count", "free_count",
]

#: nested sub-schema: stats()["prefix_cache"]
PREFIX_CACHE_KEYS = [
    "enabled", "lookups", "hits_full", "hits_partial", "misses", "inserts",
    "evictions", "entries", "nodes", "pinned_pages", "cached_tokens_served",
    "prefill_tokens_saved", "prefill_tokens_computed", "pages_write_skipped",
]

#: nested sub-schema: stats()["latency"] (percentile dicts use PCTL_KEYS)
LATENCY_KEYS = ["count", "ttft_s", "tok_per_s"]
PCTL_KEYS = ["p50", "p95", "p99"]


def keys_for(scheduler: str) -> List[str]:
    """The exact key set a ``scheduler`` engine's stats() must carry."""
    return [k for k, spec in STATS_SCHEMA.items()
            if spec.when == ALWAYS or spec.when == scheduler]


def groups() -> Dict[str, List[str]]:
    """Schema keys bucketed by display group, in GROUP_ORDER."""
    out: Dict[str, List[str]] = {g: [] for g in GROUP_ORDER}
    for k, spec in STATS_SCHEMA.items():
        out[spec.group].append(k)
    return out


def validate_stats(stats: Dict[str, object]) -> List[str]:
    """Diff a live stats dict against the schema; returns violations
    (empty = conformant).  Checks top-level presence both ways plus the
    nested pages / prefix_cache / latency sub-schemas."""
    problems: List[str] = []
    sched = stats.get("scheduler")
    if sched not in ("continuous", "wave"):
        problems.append(f"scheduler key missing or unknown: {sched!r}")
        return problems
    expected = set(keys_for(sched))
    present = set(stats)
    for k in sorted(expected - present):
        problems.append(f"missing documented key: {k}")
    for k in sorted(present - expected):
        problems.append(f"undocumented key emitted: {k}")
    pages = stats.get("pages")
    if isinstance(pages, dict) and set(pages) != set(PAGES_KEYS):
        problems.append(
            f"pages sub-schema drift: {sorted(set(pages) ^ set(PAGES_KEYS))}")
    pc = stats.get("prefix_cache")
    if isinstance(pc, dict) and set(pc) != set(PREFIX_CACHE_KEYS):
        problems.append(
            "prefix_cache sub-schema drift: "
            f"{sorted(set(pc) ^ set(PREFIX_CACHE_KEYS))}")
    lat = stats.get("latency")
    if isinstance(lat, dict):
        if set(lat) != set(LATENCY_KEYS):
            problems.append(
                "latency sub-schema drift: "
                f"{sorted(set(lat) ^ set(LATENCY_KEYS))}")
        else:
            for sub in ("ttft_s", "tok_per_s"):
                val = lat[sub]
                if isinstance(val, dict) and set(val) != set(PCTL_KEYS):
                    problems.append(f"latency.{sub} percentile keys drift")
    return problems
