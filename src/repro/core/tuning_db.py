"""Versioned, persistent, multi-op tuning database — paper Tab. 4 as an artifact.

The paper's central claim is that tuned parameters live *outside* the
single-source kernel.  ``TuningDB`` is where they live between processes:
one schema-checked JSON file per hardware target under ``tuned/<hardware>.json``
(committed to the repo, like the paper's printed table), each entry recording
the winning block config for one ``(op, dtype, shape)`` problem together with
how it was obtained (``model`` cost estimate or wall-clock ``measure``) and
the score that won.

Ops and their shapes/blocks (see ``docs/TUNING.md`` for the full schema):

* ``gemm``            — shape ``(m, k, n)``, block ``(bm, bk, bn)``
  (:class:`~repro.core.tile_config.TileConfig`);
* ``flash_attention`` — shape ``(sq, skv, d)``, block ``(bq, bk)``
  (:class:`~repro.core.tile_config.FlashAttentionConfig`).

Producers: ``scripts/tune.py sweep`` and the sweep functions in
:mod:`repro.core.tuner`.  Consumers: :class:`repro.core.registry.TileRegistry`
auto-loads every DB file at first lookup (so ``gemm_api.matmul`` and
``attention_api.flash_attention`` pick tuned blocks up in any fresh process),
and ``launch/serve.py`` / ``launch/train.py`` load it explicitly at startup
and report what they found.

Schema versioning: files carry ``schema_version``.  The current schema is
version ``4`` (op-keyed entries with an optional per-entry ``mesh`` topology
label, e.g. ``"data4xmodel2"`` for the serve engine's ``decode_loop`` op).
Version ``3`` (op-keyed, no mesh) reads unchanged; the legacy GEMM-only
schemas (versions 1-2, entries carrying flat ``m/k/n/bm/bk/bn`` fields and no
``op``) still **load** — every legacy entry migrates to ``op="gemm"`` on read
and is rewritten op-keyed on the next save.  Versions *newer* than the library raise
:class:`TuningDBError` so a stale library can never silently misread a future
artifact (auto-load downgrades that to a warning and skips the file).
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import warnings
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.registry import (OP_BLOCK_LEN, OP_GEMM, OP_SHAPE_LEN,
                                 block_of, config_from_block)

#: current on-disk schema: op-keyed entries, optional per-entry "mesh" label
SCHEMA_VERSION = 4
#: older schemas that still load: 3 (op-keyed, no mesh field) reads as-is;
#: 1-2 (flat GEMM-only entries) migrate every entry to op="gemm"
LEGACY_SCHEMA_VERSIONS = (1, 2, 3)

#: env var overriding where tuned DBs are read from / written to
TUNED_DIR_ENV = "REPRO_TUNED_DIR"
#: env var disabling registry auto-load entirely (set to any non-empty value)
DISABLE_ENV = "REPRO_DISABLE_TUNED"

class TuningDBError(ValueError):
    """Raised for schema-version mismatches and malformed DB files."""


@dataclasses.dataclass(frozen=True)
class TuningRecord:
    """One tuned winner: (op, problem identity) + winning block + provenance.

    ``shape``/``block`` semantics are op-specific (module docstring); the
    :attr:`config` property rebuilds the typed config object.  GEMM records
    keep convenience accessors (``m``/``k``/``n``) and a :meth:`gemm`
    constructor matching the pre-op-keyed API.
    """
    dtype: str
    shape: Tuple[int, ...]
    block: Tuple[int, ...]
    op: str = OP_GEMM
    source: str = "model"        # "model" | "measure" | "measure-pruned"
    seconds: float = 0.0         # winning score (estimated or measured)
    gflops: float = 0.0
    #: topology label ("data4xmodel2") for entries tuned on a specific mesh;
    #: None = topology-agnostic (the overwhelmingly common case).  Mesh-keyed
    #: records land in the registry's ``<hardware>@<mesh>`` bucket and only
    #: satisfy lookups made under that same topology.
    mesh: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(x) for x in self.shape))
        object.__setattr__(self, "block", tuple(int(x) for x in self.block))
        want_s = OP_SHAPE_LEN.get(self.op)
        want_b = OP_BLOCK_LEN.get(self.op)
        if want_s is None:
            raise TuningDBError(f"unknown op {self.op!r}")
        if len(self.shape) != want_s or len(self.block) != want_b:
            raise TuningDBError(
                f"op {self.op!r} expects shape[{want_s}]/block[{want_b}], "
                f"got {self.shape}/{self.block}")

    @classmethod
    def gemm(cls, dtype: str, m: int, k: int, n: int,
             bm: int, bk: int, bn: int, **kw) -> "TuningRecord":
        """Legacy-style GEMM constructor (pre-op-keyed call signature)."""
        return cls(dtype=dtype, shape=(m, k, n), block=(bm, bk, bn),
                   op=OP_GEMM, **kw)

    # -- GEMM conveniences (match the pre-v3 record API) ----------------
    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def k(self) -> int:
        return self.shape[1]

    @property
    def n(self) -> int:
        return self.shape[2]

    @property
    def bm(self) -> int:
        return self.block[0]

    @property
    def bk(self) -> int:
        return self.block[1]

    @property
    def bn(self) -> int:
        return self.block[2]

    @property
    def config(self):
        """The typed config object (TileConfig / FlashAttentionConfig)."""
        return config_from_block(self.op, self.block)

    def to_json(self) -> dict:
        out = {"op": self.op, "dtype": self.dtype,
               "shape": list(self.shape), "block": list(self.block),
               "source": self.source, "seconds": self.seconds,
               "gflops": self.gflops}
        if self.mesh:    # omitted when topology-agnostic (schema <= 3 shape)
            out["mesh"] = self.mesh
        return out

    @classmethod
    def from_json(cls, blob: dict) -> "TuningRecord":
        try:
            if "op" in blob or "shape" in blob:
                return cls(op=blob.get("op", OP_GEMM), dtype=blob["dtype"],
                           shape=tuple(blob["shape"]),
                           block=tuple(blob["block"]),
                           source=blob.get("source", "model"),
                           seconds=blob.get("seconds", 0.0),
                           gflops=blob.get("gflops", 0.0),
                           mesh=blob.get("mesh"))
            # legacy (schema <= 2) flat GEMM entry -> migrate to op="gemm"
            return cls.gemm(blob["dtype"], blob["m"], blob["k"], blob["n"],
                            blob["bm"], blob["bk"], blob["bn"],
                            source=blob.get("source", "model"),
                            seconds=blob.get("seconds", 0.0),
                            gflops=blob.get("gflops", 0.0))
        except (KeyError, TypeError) as e:
            raise TuningDBError(f"malformed tuning record {blob!r}: {e}") from e


class TuningDB:
    """All tuned winners for one hardware target, persistable as JSON.

    Records are keyed by ``(op, dtype, shape)``; merge semantics keep the
    most trustworthy winner per key (measured > modelled, better-of-measured,
    latest-of-modelled).

    Example::

        from repro.core.hardware import TPU_V5E
        db = TuningDB(TPU_V5E.name)
        db.add(TuningRecord.gemm("bfloat16", 4096, 4096, 4096,
                                 512, 1024, 1024, seconds=8.8e-5))
        db.add(TuningRecord(op="flash_attention", dtype="bfloat16",
                            shape=(4096, 4096, 128), block=(512, 1024)))
        db.save("tuned/tpu-v5e.json")          # schema_version 3
        db2 = TuningDB.from_file("tuned/tpu-v5e.json")
        db2.get("bfloat16", 4096, 4096, 4096).config     # TileConfig(512, ...)
        db2.get_op("flash_attention", "bfloat16", (4096, 4096, 128)).config
    """

    def __init__(self, hardware: str):
        self.hardware = hardware
        # key: (op, dtype, shape, mesh) — mesh None for topology-agnostic
        self._records: Dict[Tuple[str, str, Tuple[int, ...], Optional[str]],
                            TuningRecord] = {}

    # -- content -------------------------------------------------------
    #: wall-clock measurements outrank analytic estimates — their "seconds"
    #: are not comparable, so source priority decides before score does.
    _SOURCE_RANK = {"model": 0, "measure": 1, "measure-pruned": 1}

    def add(self, rec: TuningRecord, *, keep_best: bool = True) -> None:
        """Insert a record.  With ``keep_best``:

        * a measured entry always beats a model estimate (their "seconds"
          are not comparable);
        * measured vs measured keeps the better score (best-of-runs);
        * model vs model always takes the NEW record — model estimates are
          recomputable, so the latest sweep (with the current cost model) is
          authoritative; keeping a lower stale estimate would pin pre-fix
          winners forever and make ``tune.py diff`` drift unrecoverable.
        """
        key = (rec.op, rec.dtype, rec.shape, rec.mesh)
        old = self._records.get(key)
        if keep_best and old is not None:
            new_rank = self._SOURCE_RANK.get(rec.source, 0)
            old_rank = self._SOURCE_RANK.get(old.source, 0)
            if new_rank < old_rank:
                return
            if (new_rank == old_rank and new_rank > 0
                    and old.seconds > 0 and rec.seconds > old.seconds):
                return
        self._records[key] = rec

    def records(self, op: Optional[str] = None) -> List[TuningRecord]:
        keys = sorted((k for k in self._records if op is None or k[0] == op),
                      key=lambda k: (k[0], k[1], k[2], k[3] or ""))
        return [self._records[k] for k in keys]

    def ops(self) -> List[str]:
        return sorted({k[0] for k in self._records})

    def get_op(self, op: str, dtype: str, shape: Tuple[int, ...],
               mesh: Optional[str] = None) -> Optional[TuningRecord]:
        return self._records.get((op, dtype, tuple(shape), mesh))

    def get(self, dtype: str, m: int, k: int, n: int) -> Optional[TuningRecord]:
        """GEMM-compat accessor (pre-op-keyed call signature)."""
        return self.get_op(OP_GEMM, dtype, (m, k, n))

    def __len__(self) -> int:
        return len(self._records)

    def merge(self, other: "TuningDB", *, keep_best: bool = True) -> None:
        if other.hardware != self.hardware:
            raise TuningDBError(
                f"cannot merge DB for {other.hardware!r} into {self.hardware!r}")
        for rec in other.records():
            self.add(rec, keep_best=keep_best)

    # -- persistence ---------------------------------------------------
    def to_json(self) -> dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "hardware": self.hardware,
            "entries": [r.to_json() for r in self.records()],
        }

    @classmethod
    def from_json(cls, blob: dict) -> "TuningDB":
        if not isinstance(blob, dict) or "schema_version" not in blob:
            raise TuningDBError("not a tuning DB (missing schema_version)")
        ver = blob["schema_version"]
        if ver != SCHEMA_VERSION and ver not in LEGACY_SCHEMA_VERSIONS:
            raise TuningDBError(
                f"tuning DB schema_version {ver} is newer than supported "
                f"{SCHEMA_VERSION}; upgrade the library or re-run "
                f"`python scripts/tune.py sweep` to regenerate")
        db = cls(blob.get("hardware", "unknown"))
        for entry in blob.get("entries", []):
            # legacy entries carry flat m/k/n fields; from_json migrates
            # them to op="gemm" records transparently
            db.add(TuningRecord.from_json(entry), keep_best=False)
        return db

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    @classmethod
    def from_file(cls, path: str) -> "TuningDB":
        with open(path) as f:
            try:
                blob = json.load(f)
            except json.JSONDecodeError as e:
                raise TuningDBError(f"{path}: invalid JSON: {e}") from e
        return cls.from_json(blob)

    # -- reporting (the literal Tab. 4 rendering) ----------------------
    def markdown(self) -> str:
        lines = []
        for op in self.ops() or [OP_GEMM]:
            if lines:
                lines.append("")
            lines += [
                f"### Tuned {op} table — `{self.hardware}` "
                f"(paper Tab. 4 analogue)",
                "",
                "| dtype | shape | best block | source | est/meas time "
                "| GFLOP/s |",
                "|---|---|---|---|---|---|",
            ]
            for r in self.records(op):
                t = f"{r.seconds * 1e6:.1f} us" if r.seconds else "-"
                gf = f"{r.gflops:.0f}" if r.gflops else "-"
                shape = "x".join(str(s) for s in r.shape)
                if r.mesh:
                    shape += f" @{r.mesh}"
                lines.append(f"| {r.dtype} | {shape} | {r.config.label} "
                             f"| {r.source} | {t} | {gf} |")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Location + registry wiring
# ---------------------------------------------------------------------------

def default_tuned_dir() -> str:
    """``$REPRO_TUNED_DIR`` if set, else ``<repo-root>/tuned``.

    The repo root is found by walking up from this file past ``src/``; when
    the package is installed without the repo layout the path simply will not
    exist and loaders no-op.
    """
    env = os.environ.get(TUNED_DIR_ENV)
    if env:
        return env
    here = os.path.abspath(os.path.dirname(__file__))      # .../src/repro/core
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    return os.path.join(root, "tuned")


def db_path(hardware: str, tuned_dir: Optional[str] = None) -> str:
    return os.path.join(tuned_dir or default_tuned_dir(), f"{hardware}.json")


def load_into_registry(registry, path: str, *, strict: bool = False) -> int:
    """Load one DB file into a :class:`TileRegistry`; returns entries loaded."""
    try:
        db = TuningDB.from_file(path)
    except (TuningDBError, OSError) as e:
        if strict:
            raise
        warnings.warn(f"skipping tuning DB {path}: {e}", stacklevel=2)
        return 0
    for rec in db.records():
        registry.put_op(rec.op, rec.config, db.hardware, rec.dtype, rec.shape,
                        mesh=rec.mesh)
    return len(db)


def load_all(registry, tuned_dir: Optional[str] = None, *,
             strict: bool = False) -> Dict[str, int]:
    """Load every ``<hardware>.json`` under the tuned dir into ``registry``.

    Returns ``{path: entries_loaded}``; missing dir -> empty dict.  Called
    lazily by the global registry at first lookup and eagerly by the
    serve/train launchers.
    """
    d = tuned_dir or default_tuned_dir()
    out: Dict[str, int] = {}
    try:
        if os.environ.get(DISABLE_ENV) or not os.path.isdir(d):
            return out
        for path in sorted(glob.glob(os.path.join(d, "*.json"))):
            out[path] = load_into_registry(registry, path, strict=strict)
        return out
    finally:
        # An explicit load supersedes (and must not later be overwritten by)
        # the registry's lazy default-dir autoload.  Marked only AFTER the
        # entries are in, so a concurrent lookup's lock-free fast path can
        # never observe the done-flag against a half-populated registry.
        mark = getattr(registry, "mark_autoloaded", None)
        if mark is not None:
            mark()


def db_from_sweeps(hardware: str, results: Iterable) -> TuningDB:
    """Build a DB from :class:`repro.core.tuner.SweepResult` objects (any op)."""
    db = TuningDB(hardware)
    for res in results:
        best = res.best
        db.add(TuningRecord(
            op=res.op, dtype=res.dtype, shape=res.shape,
            block=block_of(best.config),
            source=best.source, seconds=best.seconds, gflops=best.gflops))
    return db
