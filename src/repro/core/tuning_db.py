"""Versioned, persistent tuning database — the paper's Tab. 4 as an artifact.

The paper's central claim is that tuned parameters live *outside* the
single-source kernel.  ``TuningDB`` is where they live between processes:
one schema-checked JSON file per hardware target under ``tuned/<hardware>.json``
(committed to the repo, like the paper's printed table), each entry recording
the winning :class:`~repro.core.tile_config.TileConfig` for one
(dtype, m, k, n) problem together with how it was obtained (``model`` cost
estimate or wall-clock ``measure``) and the score that won.

Producers: ``scripts/tune.py sweep`` and :func:`repro.core.tuner.sweep_gemm`.
Consumers: :class:`repro.core.registry.TileRegistry` auto-loads every DB file
at first lookup (so ``gemm_api.matmul`` picks tuned tiles up in any fresh
process), and ``launch/serve.py`` / ``launch/train.py`` load it explicitly at
startup and report what they found.

Schema versioning: files carry ``schema_version``; :func:`TuningDB.from_file`
raises :class:`TuningDBError` on a mismatch so a stale artifact can never be
silently misread (auto-load downgrades that to a warning and skips the file).
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import warnings
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.tile_config import TileConfig

SCHEMA_VERSION = 2

#: env var overriding where tuned DBs are read from / written to
TUNED_DIR_ENV = "REPRO_TUNED_DIR"
#: env var disabling registry auto-load entirely (set to any non-empty value)
DISABLE_ENV = "REPRO_DISABLE_TUNED"


class TuningDBError(ValueError):
    """Raised for schema-version mismatches and malformed DB files."""


@dataclasses.dataclass(frozen=True)
class TuningRecord:
    """One tuned winner: problem identity + winning tile + provenance."""
    dtype: str
    m: int
    k: int
    n: int
    bm: int
    bk: int
    bn: int
    source: str = "model"        # "model" | "measure"
    seconds: float = 0.0         # winning score (estimated or measured)
    gflops: float = 0.0

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (self.m, self.k, self.n)

    @property
    def config(self) -> TileConfig:
        return TileConfig(bm=self.bm, bk=self.bk, bn=self.bn)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, blob: dict) -> "TuningRecord":
        try:
            return cls(**{f.name: blob[f.name] for f in dataclasses.fields(cls)
                          if f.name in blob})
        except (KeyError, TypeError) as e:
            raise TuningDBError(f"malformed tuning record {blob!r}: {e}") from e


class TuningDB:
    """All tuned winners for one hardware target, persistable as JSON."""

    def __init__(self, hardware: str):
        self.hardware = hardware
        self._records: Dict[Tuple[str, int, int, int], TuningRecord] = {}

    # -- content -------------------------------------------------------
    #: wall-clock measurements outrank analytic estimates — their "seconds"
    #: are not comparable, so source priority decides before score does.
    _SOURCE_RANK = {"model": 0, "measure": 1, "measure-pruned": 1}

    def add(self, rec: TuningRecord, *, keep_best: bool = True) -> None:
        """Insert a record.  With ``keep_best``:

        * a measured entry always beats a model estimate (their "seconds"
          are not comparable);
        * measured vs measured keeps the better score (best-of-runs);
        * model vs model always takes the NEW record — model estimates are
          recomputable, so the latest sweep (with the current cost model) is
          authoritative; keeping a lower stale estimate would pin pre-fix
          winners forever and make ``tune.py diff`` drift unrecoverable.
        """
        key = (rec.dtype, rec.m, rec.k, rec.n)
        old = self._records.get(key)
        if keep_best and old is not None:
            new_rank = self._SOURCE_RANK.get(rec.source, 0)
            old_rank = self._SOURCE_RANK.get(old.source, 0)
            if new_rank < old_rank:
                return
            if (new_rank == old_rank and new_rank > 0
                    and old.seconds > 0 and rec.seconds > old.seconds):
                return
        self._records[key] = rec

    def records(self) -> List[TuningRecord]:
        return [self._records[k] for k in sorted(self._records)]

    def get(self, dtype: str, m: int, k: int, n: int) -> Optional[TuningRecord]:
        return self._records.get((dtype, m, k, n))

    def __len__(self) -> int:
        return len(self._records)

    def merge(self, other: "TuningDB", *, keep_best: bool = True) -> None:
        if other.hardware != self.hardware:
            raise TuningDBError(
                f"cannot merge DB for {other.hardware!r} into {self.hardware!r}")
        for rec in other.records():
            self.add(rec, keep_best=keep_best)

    # -- persistence ---------------------------------------------------
    def to_json(self) -> dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "hardware": self.hardware,
            "entries": [r.to_json() for r in self.records()],
        }

    @classmethod
    def from_json(cls, blob: dict) -> "TuningDB":
        if not isinstance(blob, dict) or "schema_version" not in blob:
            raise TuningDBError("not a tuning DB (missing schema_version)")
        ver = blob["schema_version"]
        if ver != SCHEMA_VERSION:
            raise TuningDBError(
                f"tuning DB schema_version {ver} != supported {SCHEMA_VERSION}; "
                f"re-run `python scripts/tune.py sweep` to regenerate")
        db = cls(blob.get("hardware", "unknown"))
        for entry in blob.get("entries", []):
            db.add(TuningRecord.from_json(entry), keep_best=False)
        return db

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    @classmethod
    def from_file(cls, path: str) -> "TuningDB":
        with open(path) as f:
            try:
                blob = json.load(f)
            except json.JSONDecodeError as e:
                raise TuningDBError(f"{path}: invalid JSON: {e}") from e
        return cls.from_json(blob)

    # -- reporting (the literal Tab. 4 rendering) ----------------------
    def markdown(self) -> str:
        lines = [
            f"### Tuned tile table — `{self.hardware}` (paper Tab. 4 analogue)",
            "",
            "| dtype | m | k | n | best tile (bm x bk x bn) | source | est/meas time | GFLOP/s |",
            "|---|---|---|---|---|---|---|---|",
        ]
        for r in self.records():
            t = f"{r.seconds * 1e6:.1f} us" if r.seconds else "-"
            gf = f"{r.gflops:.0f}" if r.gflops else "-"
            lines.append(f"| {r.dtype} | {r.m} | {r.k} | {r.n} "
                         f"| {r.bm}x{r.bk}x{r.bn} | {r.source} | {t} | {gf} |")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Location + registry wiring
# ---------------------------------------------------------------------------

def default_tuned_dir() -> str:
    """``$REPRO_TUNED_DIR`` if set, else ``<repo-root>/tuned``.

    The repo root is found by walking up from this file past ``src/``; when
    the package is installed without the repo layout the path simply will not
    exist and loaders no-op.
    """
    env = os.environ.get(TUNED_DIR_ENV)
    if env:
        return env
    here = os.path.abspath(os.path.dirname(__file__))      # .../src/repro/core
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    return os.path.join(root, "tuned")


def db_path(hardware: str, tuned_dir: Optional[str] = None) -> str:
    return os.path.join(tuned_dir or default_tuned_dir(), f"{hardware}.json")


def load_into_registry(registry, path: str, *, strict: bool = False) -> int:
    """Load one DB file into a :class:`TileRegistry`; returns entries loaded."""
    try:
        db = TuningDB.from_file(path)
    except (TuningDBError, OSError) as e:
        if strict:
            raise
        warnings.warn(f"skipping tuning DB {path}: {e}", stacklevel=2)
        return 0
    for rec in db.records():
        registry.put(rec.config, db.hardware, rec.dtype, rec.m, rec.k, rec.n)
    return len(db)


def load_all(registry, tuned_dir: Optional[str] = None, *,
             strict: bool = False) -> Dict[str, int]:
    """Load every ``<hardware>.json`` under the tuned dir into ``registry``.

    Returns ``{path: entries_loaded}``; missing dir -> empty dict.  Called
    lazily by the global registry at first lookup and eagerly by the
    serve/train launchers.
    """
    d = tuned_dir or default_tuned_dir()
    out: Dict[str, int] = {}
    try:
        if os.environ.get(DISABLE_ENV) or not os.path.isdir(d):
            return out
        for path in sorted(glob.glob(os.path.join(d, "*.json"))):
            out[path] = load_into_registry(registry, path, strict=strict)
        return out
    finally:
        # An explicit load supersedes (and must not later be overwritten by)
        # the registry's lazy default-dir autoload.  Marked only AFTER the
        # entries are in, so a concurrent lookup's lock-free fast path can
        # never observe the done-flag against a half-populated registry.
        mark = getattr(registry, "mark_autoloaded", None)
        if mark is not None:
            mark()


def db_from_sweeps(hardware: str, results: Iterable) -> TuningDB:
    """Build a DB from :class:`repro.core.tuner.SweepResult` objects."""
    db = TuningDB(hardware)
    for res in results:
        best = res.best
        db.add(TuningRecord(
            dtype=res.dtype, m=res.m, k=res.k, n=res.n,
            bm=best.config.bm, bk=best.config.bk, bn=best.config.bn,
            source=best.source, seconds=best.seconds, gflops=best.gflops))
    return db
