"""Tuning parameters carried OUTSIDE the kernel (paper Listing 1.1).

``TileConfig`` is the TPU generalization of the paper's single tile size
``T``: the square CPU/GPU tile becomes a rectangular (bm, bk, bn) block with
MXU/VPU alignment constraints.  ``TuningSpace`` enumerates the candidates the
tuner sweeps — the analogue of the paper's power-of-two T/thread sweep
(Figs. 3/4) — with the cache-capacity constraint K(S,T) <= cache (Eq. 5)
made *explicit* against the VMEM budget instead of discovered empirically.

The same pattern generalizes beyond GEMM: ``FlashAttentionConfig`` carries
the flash-attention kernel's (bq, bk) block sizes — the knobs of the online
softmax's "bigger tile => fewer K/V re-reads" trade-off (the attention
analogue of the paper's Eq. 7) — and ``FlashTuningSpace`` enumerates its
candidates under the same VMEM feasibility predicate.  Every config class
here is hashable, orderable, and static-argument safe; kernels receive them
from the registry, never define them.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Sequence

import jax.numpy as jnp

from repro.core.hardware import HardwareSpec, TPU_V5E


@dataclasses.dataclass(frozen=True, order=True)
class TileConfig:
    """Block sizes of the single-source GEMM.  Hashable & static-arg safe."""
    bm: int = 128
    bk: int = 128
    bn: int = 128

    def vmem_working_set(self, in_dtype, out_dtype=None) -> int:
        """Rectangular generalization of paper Eq. 5:  K(S,T) = 2 T^2 S.

        A-tile + B-tile (+ C-tile when beta != 0, counted always for safety)
        in the input dtype, plus the f32 accumulator scratch.
        """
        s_in = jnp.dtype(in_dtype).itemsize
        s_out = jnp.dtype(out_dtype or in_dtype).itemsize
        return (self.bm * self.bk + self.bk * self.bn) * s_in \
            + self.bm * self.bn * (4 + s_out)

    def fits(self, hw: HardwareSpec, in_dtype, out_dtype=None,
             headroom: float = 0.9) -> bool:
        # Pallas double-buffers input windows: 2x the A/B tile footprint.
        s_in = jnp.dtype(in_dtype).itemsize
        s_out = jnp.dtype(out_dtype or in_dtype).itemsize
        need = 2 * (self.bm * self.bk + self.bk * self.bn) * s_in \
            + self.bm * self.bn * (4 + s_out)
        return need <= hw.vmem_bytes * headroom

    def aligned(self, hw: HardwareSpec, in_dtype) -> bool:
        """MXU/VPU alignment: minor dim multiple of 128, second-minor of the
        dtype-dependent sublane count (8 for f32, 16 for bf16)."""
        sub = hw.sublane * (2 if jnp.dtype(in_dtype).itemsize == 2 else 1)
        return (self.bn % hw.mxu_dim == 0 and self.bk % hw.mxu_dim == 0
                and self.bm % sub == 0)

    @property
    def label(self) -> str:
        return f"{self.bm}x{self.bk}x{self.bn}"


# Paper-faithful square tiles (the paper sweeps one T): bm = bn = bk = T.
def square(t: int) -> TileConfig:
    return TileConfig(bm=t, bk=t, bn=t)


@dataclasses.dataclass(frozen=True)
class TuningSpace:
    """Candidate enumeration for the sweep.

    ``square_only=True`` reproduces the paper's 1-parameter sweep exactly;
    the default rectangular space is the beyond-paper TPU generalization.
    """
    bm_candidates: Sequence[int] = (64, 128, 256, 512)
    bk_candidates: Sequence[int] = (128, 256, 512, 1024)
    bn_candidates: Sequence[int] = (128, 256, 512, 1024)
    square_only: bool = False

    def candidates(self, hw: HardwareSpec = TPU_V5E,
                   in_dtype=jnp.bfloat16,
                   m: int = None, k: int = None, n: int = None,
                   ) -> Iterator[TileConfig]:
        """Yield feasible, aligned candidates (VMEM predicate from Eq. 5).

        If problem dims are given, blocks larger than the (padded) problem
        are skipped — tiles never exceed the matrix, as in the paper.
        """
        if self.square_only:
            tiles = sorted(set(self.bm_candidates)
                           | set(self.bk_candidates) & set(self.bn_candidates))
            combos = list((t, t, t) for t in tiles)
        else:
            combos = list(itertools.product(
                self.bm_candidates, self.bk_candidates, self.bn_candidates))

        def feasible(cap_dims: bool):
            for bm, bk, bn in combos:
                cfg = TileConfig(bm=bm, bk=bk, bn=bn)
                if not cfg.aligned(hw, in_dtype):
                    continue
                if not cfg.fits(hw, in_dtype):
                    continue
                if cap_dims:
                    if m is not None and bm > max(m, hw.sublane):
                        continue
                    if k is not None and bk > max(k, hw.mxu_dim):
                        continue
                    if n is not None and bn > max(n, hw.mxu_dim):
                        continue
                yield cfg

        out = list(feasible(cap_dims=True))
        if not out:
            # problem smaller than every candidate block: padding applies,
            # so the single-block configs are the right space
            out = sorted(set(feasible(cap_dims=False)))[:8]
        yield from out


# A small space usable in interpret-mode measurement on CPU (tiny problems).
INTERPRET_SPACE = TuningSpace(
    bm_candidates=(8, 16, 32, 64),
    bk_candidates=(16, 32, 64),
    bn_candidates=(16, 32, 64),
)


# ---------------------------------------------------------------------------
# Flash attention (op = "flash_attention")
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, order=True)
class FlashAttentionConfig:
    """Block sizes of the single-source flash-attention kernel.

    ``bq`` tiles the query rows, ``bk`` tiles the KV columns the online
    softmax streams over.  Per (bq x d) output tile the kernel re-reads the
    full K/V once, so HBM traffic falls as ~1/bq until the q/k/v/accumulator
    working set hits VMEM — the attention edition of paper Eq. 7.
    """
    bq: int = 128
    bk: int = 128

    def vmem_working_set(self, d: int, in_dtype, *, gqa_groups: int = 1) -> int:
        """Bytes resident per grid step: q + k + v tiles in the input dtype
        plus the f32 (m, l, acc) scratch carried across KV blocks."""
        s_in = jnp.dtype(in_dtype).itemsize
        del gqa_groups  # KV heads are expanded before the kernel; no sharing
        return (self.bq * d + 2 * self.bk * d) * s_in \
            + (self.bq * (d + 2)) * 4

    def fits(self, hw: HardwareSpec, d: int, in_dtype,
             headroom: float = 0.9) -> bool:
        # Pallas double-buffers the streamed k/v windows.
        s_in = jnp.dtype(in_dtype).itemsize
        need = (2 * (self.bq * d + 2 * self.bk * d)) * s_in \
            + self.bq * (d + 2) * 4
        return need <= hw.vmem_bytes * headroom

    def aligned(self, hw: HardwareSpec, in_dtype) -> bool:
        """Score tile (bq, bk): minor dim multiple of the lane count, rows a
        multiple of the dtype sublane count (as for the GEMM tiles)."""
        sub = hw.sublane * (2 if jnp.dtype(in_dtype).itemsize == 2 else 1)
        return self.bk % hw.mxu_dim == 0 and self.bq % sub == 0

    @property
    def label(self) -> str:
        return f"{self.bq}x{self.bk}"


@dataclasses.dataclass(frozen=True)
class FlashTuningSpace:
    """Candidate (bq, bk) enumeration for the flash-attention sweep."""
    bq_candidates: Sequence[int] = (64, 128, 256, 512)
    bk_candidates: Sequence[int] = (128, 256, 512, 1024)

    def candidates(self, hw: HardwareSpec = TPU_V5E, in_dtype=jnp.bfloat16,
                   sq: int = None, skv: int = None, d: int = 128,
                   ) -> Iterator[FlashAttentionConfig]:
        """Yield feasible, aligned candidates; blocks larger than the
        (padded) sequence are skipped, as for GEMM."""
        combos = list(itertools.product(self.bq_candidates, self.bk_candidates))

        def feasible(cap_dims: bool):
            for bq, bk in combos:
                cfg = FlashAttentionConfig(bq=bq, bk=bk)
                if not cfg.aligned(hw, in_dtype):
                    continue
                if not cfg.fits(hw, d, in_dtype):
                    continue
                if cap_dims:
                    if sq is not None and bq > max(sq, hw.sublane):
                        continue
                    if skv is not None and bk > max(skv, hw.mxu_dim):
                        continue
                yield cfg

        out = list(feasible(cap_dims=True))
        if not out:
            # sequence shorter than every candidate block: the kernel pads,
            # so the smallest feasible blocks are the right space
            out = sorted(set(feasible(cap_dims=False)))[:8]
        yield from out


# Interpret-mode (host-measured) flash space: tiny sequences, loose alignment.
FLASH_INTERPRET_SPACE = FlashTuningSpace(
    bq_candidates=(8, 16, 32, 64),
    bk_candidates=(16, 32, 64),
)


# ---------------------------------------------------------------------------
# Serve-engine decode loop (op = "decode_loop")
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, order=True)
class DecodeLoopConfig:
    """Schedule knob of the serve engine's fused decode loop.

    ``unroll`` is how many tokens each ``while_loop`` iteration decodes.
    Every loop spin is a cross-device sync point (cond broadcast + thunk
    dispatch on every mesh device), so on a sharded topology fatter
    iterations hide dispatch latency behind compute; on one chip the spin is
    cheap and ``unroll=1`` keeps the early-exit granularity fine.  This is
    the first op whose best value depends on the *mesh* rather than the
    problem shape alone — its tuned entries carry the topology in the op key
    (``mesh="data4xmodel2"``).
    """
    unroll: int = 1

    @property
    def label(self) -> str:
        return f"u{self.unroll}"


@dataclasses.dataclass(frozen=True)
class DecodeLoopTuningSpace:
    """Candidate unroll factors for the decode-loop sweep (powers of two, so
    any power-of-two decode-width bucket divides evenly)."""
    unroll_candidates: Sequence[int] = (1, 2, 4, 8)

    def candidates(self, hw: HardwareSpec = TPU_V5E,
                   width: int = None) -> Iterator[DecodeLoopConfig]:
        for u in self.unroll_candidates:
            if width is not None and u > width:
                continue
            yield DecodeLoopConfig(unroll=u)


# ---------------------------------------------------------------------------
# Paged KV cache (op = "paged_attn")
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, order=True)
class PagedAttentionConfig:
    """Layout knob of the serve engine's paged KV cache.

    ``page_size`` is how many tokens one KV page holds.  Small pages cut
    fragmentation (a request wastes at most ``page_size - 1`` tokens of its
    last page) and let admission pack tighter; big pages keep the per-chunk
    gather/scatter index streams short and the pool's flat-token reads more
    contiguous.  Like ``decode_loop``, the best value depends on hardware
    AND topology, so tuned entries may carry a mesh label in the op key.
    """
    page_size: int = 16

    @property
    def label(self) -> str:
        return f"p{self.page_size}"


@dataclasses.dataclass(frozen=True)
class PagedAttentionTuningSpace:
    """Candidate page sizes for the paged-KV sweep (powers of two, so pages
    tile the power-of-two decode-width buckets evenly)."""
    page_candidates: Sequence[int] = (8, 16, 32, 64)

    def candidates(self, hw: HardwareSpec = TPU_V5E,
                   max_len: int = None) -> Iterator[PagedAttentionConfig]:
        for p in self.page_candidates:
            if max_len is not None and p > max_len:
                continue
            yield PagedAttentionConfig(page_size=p)
