"""The single public matmul entry point — every model matmul goes here.

This is the framework's enforcement of the paper's thesis: the algorithm
(kernels/gemm.py) is written once; *which execution backend runs it* and
*with which tile parameters* is decided here from ambient context + the
registry.  Model code never mentions tiles or backends.

``ExecutionContext`` plays the role of the paper's build matrix (Tab. 3):
backend x hardware x dtype.  On a real TPU the default context resolves to
the Pallas kernel; on this CPU container it resolves to XLA (for jit/pjit
paths) with pallas-interpret available for kernel validation.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import hardware as hw
from repro.core.registry import GLOBAL_REGISTRY
from repro.kernels import ops


def _default_backend() -> str:
    platform = jax.default_backend()
    return ops.BACKEND_PALLAS_TPU if platform == "tpu" else ops.BACKEND_XLA


@dataclasses.dataclass
class ExecutionContext:
    backend: Optional[str] = None       # None -> auto by platform
    # Registry/tuner key (target hardware profile).  None resolves through
    # the profile layer: $REPRO_HARDWARE, else jax.devices() detection —
    # an explicit execution_context(hardware=...) override always wins.
    hardware: Optional[str] = None
    capture: Optional[List[Tuple[int, int, int]]] = None  # GEMM shape trace
    # When True, 16-bit matmuls emit 16-bit outputs at the tile level, so
    # cross-shard partial-sum all-reduces run in bf16 instead of f32 (halves
    # the dominant TP collective; MXU still accumulates f32 within a shard).
    bf16_partials: bool = False

    def resolve_backend(self) -> str:
        return self.backend or _default_backend()

    def resolve_hardware(self) -> str:
        return hw.resolve_hardware(self.hardware)


_TLS = threading.local()


def _ctx() -> ExecutionContext:
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        ctx = ExecutionContext()
        _TLS.ctx = ctx
    return ctx


@contextlib.contextmanager
def execution_context(**overrides):
    """Scoped override, e.g. ``with execution_context(backend="pallas-interpret")``."""
    old = _ctx()
    new = dataclasses.replace(old, **overrides)
    _TLS.ctx = new
    try:
        yield new
    finally:
        _TLS.ctx = old


def current_hardware() -> str:
    """Resolved registry/tuner hardware key of the ambient execution context.

    Detection order: explicit ``execution_context(hardware=...)`` override,
    then ``$REPRO_HARDWARE``, then :func:`repro.core.hardware.detect_hardware`
    over ``jax.devices()``.
    """
    return _ctx().resolve_hardware()


@contextlib.contextmanager
def capture_gemm_shapes():
    """Collect every (m, k, n) issued under this scope — feeds the tuner."""
    shapes: List[Tuple[int, int, int]] = []
    with execution_context(capture=shapes):
        yield shapes


# --- bf16-reduction matmul (beyond-paper §Perf option) ---------------------
# Standard AD leaves cotangents in f32 wherever the fwd graph upcast
# (norms, softmax, loss), so the backward TP/FSDP partial-sum all-reduces
# run in f32.  This custom-VJP dot pins BOTH directions to bf16 outputs, so
# every cross-shard reduction of activations/grad-activations/grad-weights
# moves half the bytes.  MXU accumulation within a shard remains f32-backed;
# the cross-shard sum is bf16 (the usual production mixed-precision choice).

@jax.custom_vjp
def _dot_bf16_reduce(x2, w):
    return jax.lax.dot(x2, w, preferred_element_type=jnp.bfloat16)


def _dot_bf16_reduce_fwd(x2, w):
    return _dot_bf16_reduce(x2, w), (x2, w)


def _dot_bf16_reduce_bwd(res, g):
    x2, w = res
    gb = g.astype(jnp.bfloat16)
    dx = jax.lax.dot(gb, w.T, preferred_element_type=jnp.bfloat16)
    dw = jax.lax.dot(x2.T, gb, preferred_element_type=jnp.bfloat16)
    return dx.astype(x2.dtype), dw.astype(w.dtype)


_dot_bf16_reduce.defvjp(_dot_bf16_reduce_fwd, _dot_bf16_reduce_bwd)


def matmul(x: jax.Array, w: jax.Array, *, bias: Optional[jax.Array] = None,
           activation: Optional[str] = None, out_dtype=None) -> jax.Array:
    """``x @ w`` — the only matmul primitive the model zoo uses.

    Leading dims of ``x`` are flattened into the GEMM's M dimension; the
    execution backend and the (bm, bk, bn) tile config are resolved from the
    ambient :class:`ExecutionContext` and the op-keyed tuning registry
    (``op="gemm"``, exact tuned shape first, then nearest-shape, generic and
    per-hardware default tiers).  Fused epilogues (bias, activation) ride on
    the kernel's epilogue so the single source covers the model's hot paths,
    not just plain GEMM.

    Args:
      x: left operand, shape ``(..., K)``.
      w: right operand, shape ``(K, N)``.
      bias: optional ``(N,)`` bias added in f32 before the activation.
      activation: optional fused activation: ``"relu" | "gelu" | "silu" |
        "tanh"``.
      out_dtype: output dtype (default: the operands' result type).

    Returns:
      ``x @ w`` with shape ``(..., N)``, accumulated in float32.

    Example::

        from repro.core import execution_context, matmul
        from repro.core.hardware import TPU_V5E
        with execution_context(backend="pallas-interpret",
                               hardware=TPU_V5E.name):
            y = matmul(x, w, activation="silu")   # tuned tiles, fused SiLU
    """
    ctx = _ctx()
    backend = ctx.resolve_backend()
    k = x.shape[-1]
    if w.shape[0] != k:
        raise ValueError(f"matmul mismatch: {x.shape} @ {w.shape}")
    n = w.shape[1]
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, k)

    if ctx.capture is not None:
        ctx.capture.append((m, k, n))

    config = None
    if backend in (ops.BACKEND_PALLAS_TPU, ops.BACKEND_PALLAS_INTERPRET):
        # First lookup lazily pulls committed tuned/<hardware>.json DBs into
        # the global registry, so a fresh process serves tuned tiles with no
        # explicit setup; untuned shapes resolve via nearest-shape fallback.
        config = GLOBAL_REGISTRY.lookup(ctx.resolve_hardware(), x.dtype,
                                        m, k, n).config

    if (ctx.bf16_partials and backend == ops.BACKEND_XLA
            and bias is None and activation is None
            and x.dtype == jnp.bfloat16 and w.dtype == jnp.bfloat16):
        out = _dot_bf16_reduce(x2, w)
        if out_dtype is not None:
            out = out.astype(out_dtype)
        return out.reshape(*lead, n)

    out = ops.gemm(x2, w, config=config, backend=backend, bias=bias,
                   activation=activation, out_dtype=out_dtype,
                   bf16_partials=ctx.bf16_partials)
    return out.reshape(*lead, n)


def einsum(subscripts: str, *operands, **kw):
    """Thin escape hatch for contractions that are not plain (…,K)x(K,N).

    Routed through XLA dot_general; still subject to the ambient context's
    dtype policy.  Kept in one place so a future Pallas generalization can
    swap in without touching models.
    """
    pref = jnp.float32
    if _ctx().bf16_partials and all(
            jnp.dtype(getattr(o, "dtype", jnp.float32)).itemsize <= 2
            for o in operands):
        pref = jnp.bfloat16
    return jnp.einsum(subscripts, *operands,
                      preferred_element_type=pref, **kw)
