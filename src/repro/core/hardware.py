"""Hardware descriptions (the paper's Tables 1/2 analogue).

One record per target "architecture".  The roofline analysis, the analytic
tile cost model, and the tuner all read from these — never from constants
scattered in code.  TPU v5e is the primary target per the task spec.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    # peak FLOP/s per chip, keyed by dtype name (paper Tab. 1/2 "theoretical peak")
    peak_flops: Dict[str, float]
    hbm_bandwidth: float          # bytes/s per chip
    vmem_bytes: int               # software-managed on-chip memory (the "cache")
    ici_link_bandwidth: float     # bytes/s per link (inter-chip)
    mxu_dim: int = 128            # systolic array native dim
    sublane: int = 8              # native second-minor tiling for f32

    def peak_for(self, dtype) -> float:
        return self.peak_flops[jnp.dtype(dtype).name]


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops={
        "bfloat16": 197e12,   # task-spec constant: 197 TFLOP/s bf16
        "float32": 98.5e12,   # MXU f32 ~ half bf16 throughput
    },
    hbm_bandwidth=819e9,      # 819 GB/s
    vmem_bytes=128 * 1024 * 1024 // 8,  # ~16 MiB usable VMEM per core
    ici_link_bandwidth=50e9,  # ~50 GB/s per ICI link
)

# CPU record used when *measuring* on this container (interpret-mode sweeps).
HOST_CPU = HardwareSpec(
    name="host-cpu",
    peak_flops={"bfloat16": 1e11, "float32": 2e11},
    hbm_bandwidth=50e9,
    vmem_bytes=32 * 1024 * 1024,   # L2+L3-ish proxy
    ici_link_bandwidth=10e9,
    mxu_dim=16,                    # SIMD width proxy — relaxes alignment
    sublane=1,
)

HARDWARE: Dict[str, HardwareSpec] = {h.name: h for h in (TPU_V5E, HOST_CPU)}


def get_hardware(name: str) -> HardwareSpec:
    try:
        return HARDWARE[name]
    except KeyError:
        raise KeyError(f"unknown hardware {name!r}; known: {sorted(HARDWARE)}")
