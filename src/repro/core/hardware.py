"""Hardware profiles: one record per backend the single source runs on.

The paper's Tables 1/2 list one column per architecture (P100, KNL, Haswell,
Power8); here each column is a :class:`HardwareProfile` — peak FLOPS and HBM
bandwidth for the cost/roofline models, tile-alignment constraints for the
candidate spaces, and the seeded default blocks the registry serves before
any tuning ran.  The roofline analysis, the analytic tile cost model, the
tuner, the registry's default tier, and the serve engine all read from these
— never from constants scattered in code.

Three profiles ship registered (the paper's build matrix, Tab. 3):

* ``tpu-v5e``       — the TPU target (platform ``tpu``); tuned via the
  analytic cost model on any host, measured on real TPUs.
* ``gpu-generic``   — an A100-class target (platform ``gpu``); defines the
  lowering/tiling constraints (16-wide tensor-core tiles, SM shared-memory
  budget) so a GPU runner can ``tune.py sweep --mode measure`` without any
  code change.
* ``cpu-interpret`` — the pallas-interpret backend on the host CPU
  (platform ``cpu-interpret``); the measurable backend of this container,
  with its own committed ``tuned/cpu-interpret.json``.

Resolution order for "which hardware am I tuning/serving for":

1. explicit ``execution_context(hardware=...)`` / ``--hardware`` flag;
2. the ``REPRO_HARDWARE`` environment variable (how the CI backend matrix
   pins each job's profile);
3. auto-detection from ``jax.devices()`` (:func:`detect_hardware`).

``host-cpu`` is kept as a legacy alias of ``cpu-interpret`` so pre-profile
tuning DBs and call sites keep resolving.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterable, Optional, Tuple

import jax.numpy as jnp

#: platform kinds — the coarse backend families a profile belongs to
PLATFORM_TPU = "tpu"
PLATFORM_GPU = "gpu"
PLATFORM_CPU_INTERPRET = "cpu-interpret"
PLATFORMS = (PLATFORM_TPU, PLATFORM_GPU, PLATFORM_CPU_INTERPRET)

#: env var pinning the hardware profile for a whole process (CI matrix knob)
HARDWARE_ENV = "REPRO_HARDWARE"


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """One tuning/serving target: cost-model numbers + tiling constraints.

    ``mxu_dim``/``sublane`` drive the candidate-space alignment predicates
    (:meth:`repro.core.tile_config.TileConfig.aligned`); ``vmem_bytes`` is
    the on-chip budget of the feasibility predicate (paper Eq. 5) — VMEM on
    TPU, SM shared memory on GPU, an L2/L3 proxy for the interpreted CPU
    path.  ``hbm_bytes`` is the per-chip main-memory *capacity* (HBM on
    TPU/GPU, a host-RAM proxy on the interpreted CPU) that the IR memory
    check (IR003, ``analyze.py ir``) budgets each compiled program's
    live-buffer peak against.  ``gemm_block``/``flash_block`` seed the
    registry's default tier (the paper's ``#define GPU_ELEM_NUM`` analogue)
    before any sweep ran.
    """
    name: str
    # peak FLOP/s per chip, keyed by dtype name (paper Tab. 1/2 "theoretical peak")
    peak_flops: Dict[str, float]
    hbm_bandwidth: float          # bytes/s per chip
    vmem_bytes: int               # software-managed on-chip memory (the "cache")
    ici_link_bandwidth: float     # bytes/s per link (inter-chip)
    hbm_bytes: int = 16 * 1024**3  # per-chip main-memory capacity
    mxu_dim: int = 128            # native minor-dim tile (MXU / tensor core)
    sublane: int = 8              # native second-minor tiling for f32
    platform: str = PLATFORM_TPU
    default_backend: str = "pallas-tpu"   # kernels.ops backend string
    gemm_block: Tuple[int, int, int] = (128, 128, 128)   # seeded default tier
    flash_block: Tuple[int, int] = (128, 128)
    #: XLA flags enabling async collectives / latency-hiding scheduling on
    #: this backend.  Applied by ``launch.mesh.apply_latency_hiding_flags``
    #: *before* backend init (XLA reads XLA_FLAGS once), so collectives the
    #: decode loop issues can overlap with compute instead of serializing it.
    #: Empty for backends whose runtime has no such scheduler (interpret CPU).
    xla_latency_flags: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.platform not in PLATFORMS:
            raise ValueError(
                f"unknown platform {self.platform!r}; known: {PLATFORMS}")

    def peak_for(self, dtype) -> float:
        return self.peak_flops[jnp.dtype(dtype).name]

    def default_block(self, op: str) -> Optional[Tuple[int, ...]]:
        """Seeded default block tuple for an op family (None if unknown)."""
        return {"gemm": self.gemm_block,
                "flash_attention": self.flash_block}.get(op)


#: legacy alias — pre-profile code constructed/annotated ``HardwareSpec``
HardwareSpec = HardwareProfile


TPU_V5E = HardwareProfile(
    name="tpu-v5e",
    platform=PLATFORM_TPU,
    peak_flops={
        "bfloat16": 197e12,   # task-spec constant: 197 TFLOP/s bf16
        "float32": 98.5e12,   # MXU f32 ~ half bf16 throughput
    },
    hbm_bandwidth=819e9,      # 819 GB/s
    hbm_bytes=16 * 1024**3,   # 16 GiB HBM per chip
    vmem_bytes=128 * 1024 * 1024 // 8,  # ~16 MiB usable VMEM per core
    ici_link_bandwidth=50e9,  # ~50 GB/s per ICI link
    default_backend="pallas-tpu",
    gemm_block=(128, 128, 128),
    flash_block=(128, 128),
    # TPU collectives already run on dedicated ICI hardware; only ask the
    # scheduler to fuse/overlap all-gathers with the compute stream.
    xla_latency_flags=(
        "--xla_tpu_enable_async_collective_fusion=true",
        "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
    ),
)

GPU_GENERIC = HardwareProfile(
    name="gpu-generic",
    platform=PLATFORM_GPU,
    peak_flops={
        "bfloat16": 312e12,   # A100-class tensor-core bf16
        "float32": 19.5e12,   # CUDA-core f32
    },
    hbm_bandwidth=1555e9,     # HBM2e
    hbm_bytes=40 * 1024**3,   # A100-40GB HBM2e stack
    vmem_bytes=192 * 1024,    # SM shared memory (the GEMM tile budget)
    ici_link_bandwidth=600e9 / 12,  # NVLink per-link
    mxu_dim=16,               # tensor-core fragment minor dim
    sublane=4,                # warp-level row granularity for f32
    default_backend="xla",    # vendor-library path until a Triton lowering lands
    gemm_block=(64, 128, 128),
    flash_block=(64, 64),
    # The standard GPU latency-hiding set: async collectives on their own
    # high-priority stream, scheduled to overlap with compute.
    xla_latency_flags=(
        "--xla_gpu_enable_async_collectives=true",
        "--xla_gpu_enable_latency_hiding_scheduler=true",
        "--xla_gpu_enable_highest_priority_async_stream=true",
    ),
)

# The pallas-interpret backend on this host: the one we can actually measure.
CPU_INTERPRET = HardwareProfile(
    name="cpu-interpret",
    platform=PLATFORM_CPU_INTERPRET,
    peak_flops={"bfloat16": 1e11, "float32": 2e11},
    hbm_bandwidth=50e9,
    hbm_bytes=8 * 1024**3,         # host-RAM slice the CI runner can commit
    vmem_bytes=32 * 1024 * 1024,   # L2+L3-ish proxy
    ici_link_bandwidth=10e9,
    mxu_dim=16,                    # SIMD width proxy — relaxes alignment
    sublane=1,
    default_backend="pallas-interpret",
    gemm_block=(32, 32, 32),
    flash_block=(32, 32),
)

#: legacy name for the host-measurement profile (pre-profile code imports it)
HOST_CPU = CPU_INTERPRET

HARDWARE: Dict[str, HardwareProfile] = {}
PROFILES = HARDWARE   # the profile registry's preferred name

#: legacy hardware names -> canonical profile names
ALIASES: Dict[str, str] = {"host-cpu": CPU_INTERPRET.name}


def register_profile(profile: HardwareProfile) -> HardwareProfile:
    """Register (or replace) a profile; returns it for chaining."""
    HARDWARE[profile.name] = profile
    return profile


for _p in (TPU_V5E, GPU_GENERIC, CPU_INTERPRET):
    register_profile(_p)


def canonical_name(name: str) -> str:
    return ALIASES.get(name, name)


def find_profile(name: str) -> Optional[HardwareProfile]:
    """Profile for ``name`` (alias-aware), or None when unregistered."""
    return HARDWARE.get(canonical_name(name))


def get_profile(name: str) -> HardwareProfile:
    prof = find_profile(name)
    if prof is None:
        raise KeyError(f"unknown hardware {name!r}; known: {sorted(HARDWARE)}"
                       f" (aliases: {sorted(ALIASES)})")
    return prof


#: legacy accessor name
get_hardware = get_profile


# ---------------------------------------------------------------------------
# Detection: env pin > jax.devices() platform
# ---------------------------------------------------------------------------

#: jax platform string -> registered profile name
PLATFORM_DEFAULT_PROFILE: Dict[str, str] = {
    "cpu": CPU_INTERPRET.name,
    "gpu": GPU_GENERIC.name,
    "cuda": GPU_GENERIC.name,
    "rocm": GPU_GENERIC.name,
    "tpu": TPU_V5E.name,
}


def detect_hardware(devices: Optional[Iterable] = None) -> str:
    """Profile name for this process: ``$REPRO_HARDWARE`` if set, else the
    default profile for ``jax.devices()``'s platform (CPU-only hosts resolve
    to ``cpu-interpret``).  ``devices`` is injectable for tests."""
    env = os.environ.get(HARDWARE_ENV)
    if env:
        return canonical_name(env)
    if devices is not None:
        platforms = {getattr(d, "platform", "cpu") for d in devices}
        for plat in ("tpu", "gpu", "cuda", "rocm"):   # accelerator wins
            if plat in platforms:
                return PLATFORM_DEFAULT_PROFILE[plat]
        return CPU_INTERPRET.name
    try:
        import jax
        platform = jax.default_backend()
    except Exception:   # pragma: no cover - jax always importable here
        return CPU_INTERPRET.name
    return PLATFORM_DEFAULT_PROFILE.get(platform, CPU_INTERPRET.name)


def resolve_hardware(name: Optional[str] = None) -> str:
    """Canonical hardware name for an optional explicit override.

    Explicit ``name`` (alias-resolved) wins; ``None`` falls back to
    :func:`detect_hardware`.  Unregistered names pass through untouched —
    the registry's default tier handles them with a warning, so a typo'd
    target degrades loudly instead of crashing mid-serve.
    """
    if name:
        return canonical_name(name)
    return detect_hardware()


def resolve_profile(hardware=None,
                    default: Optional[HardwareProfile] = None
                    ) -> HardwareProfile:
    """Like :func:`resolve_hardware` but returns the profile object;
    accepts a profile, a name, or None.  ``None`` resolves to ``default``
    when given (how the benchmark suites pin the TPU target for direct
    calls), else to the detected host profile."""
    if isinstance(hardware, HardwareProfile):
        return hardware
    if hardware is None and default is not None:
        return default
    name = resolve_hardware(hardware)
    prof = find_profile(name)
    if prof is None:
        raise KeyError(f"unknown hardware {name!r}; known: {sorted(HARDWARE)}")
    return prof
