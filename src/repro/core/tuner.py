"""Autotuner: the paper's parameter sweep (Figs. 3/4) as a reusable engine.

Two scoring modes, matching how the paper and this container differ:

* ``mode="model"``  — score every candidate with the analytic TPU cost model
  (no hardware needed; used for the TPU-v5e target on this CPU container).
* ``mode="measure"`` — wall-clock the actual execution (pallas-interpret or
  XLA on CPU).  Like the paper we keep the *best* of ``repeats`` runs
  ("keeping the maximum over ten runs", §2).

The sweep result is returned in full (not just the argmax) so the benchmark
harness can render the paper's tuning curves, and the winner is written into
the registry — producing the machine equivalent of paper Tab. 4.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.core import cost_model
from repro.core.hardware import HardwareSpec, TPU_V5E, HOST_CPU
from repro.core.registry import GLOBAL_REGISTRY, TileRegistry
from repro.core.tile_config import TileConfig, TuningSpace
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    config: TileConfig
    seconds: float
    gflops: float
    source: str  # "model" | "measure"


@dataclasses.dataclass(frozen=True)
class SweepResult:
    m: int
    k: int
    n: int
    dtype: str
    hardware: str
    points: List[SweepPoint]

    @property
    def best(self) -> SweepPoint:
        return min(self.points, key=lambda p: p.seconds)


def _measure(fn: Callable[[], jax.Array], repeats: int) -> float:
    fn().block_until_ready()  # compile / warm up
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def sweep_gemm(
    m: int, k: int, n: int,
    *,
    dtype=jnp.float32,
    space: Optional[TuningSpace] = None,
    hardware: HardwareSpec = TPU_V5E,
    mode: str = "model",
    backend: str = ops.BACKEND_PALLAS_INTERPRET,
    repeats: int = 3,
    registry: Optional[TileRegistry] = None,
    record: bool = True,
) -> SweepResult:
    """Sweep tile configs for one GEMM problem; optionally record the winner."""
    space = space or TuningSpace()
    flops = 2.0 * m * k * n
    points: List[SweepPoint] = []

    if mode == "measure":
        key = jax.random.PRNGKey(0)
        a = jax.random.normal(key, (m, k), jnp.float32).astype(dtype)
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32).astype(dtype)

    for cfg in space.candidates(hardware, dtype, m=m, k=k, n=n):
        if mode == "model":
            cost = cost_model.gemm_cost(m, k, n, cfg, hardware, dtype)
            secs = cost.total_s
        elif mode == "measure":
            fn = jax.jit(lambda a, b, c=cfg: ops.gemm(a, b, config=c, backend=backend))
            secs = _measure(lambda: fn(a, b), repeats)
        else:
            raise ValueError(f"unknown mode {mode!r}")
        points.append(SweepPoint(cfg, secs, flops / secs / 1e9, mode))

    if not points:
        raise ValueError(
            f"tuning space empty for ({m},{k},{n}) {jnp.dtype(dtype).name} on {hardware.name}")

    result = SweepResult(m=m, k=k, n=n, dtype=jnp.dtype(dtype).name,
                         hardware=hardware.name, points=points)
    if record:
        reg = registry or GLOBAL_REGISTRY
        reg.put(result.best.config, hardware.name, dtype, m, k, n)
    return result


def tune_model_gemms(shapes, *, dtype=jnp.bfloat16,
                     hardware: HardwareSpec = TPU_V5E,
                     registry: Optional[TileRegistry] = None) -> dict:
    """Tune every (m, k, n) a model emits (collected via gemm_api tracing).

    Returns {shape: best TileConfig}.  This is the 'auto-tuning in a later
    step' the paper's §1.1 anticipates.
    """
    out = {}
    for (m, k, n) in sorted(set(shapes)):
        res = sweep_gemm(m, k, n, dtype=dtype, hardware=hardware,
                         mode="model", registry=registry)
        out[(m, k, n)] = res.best.config
    return out
