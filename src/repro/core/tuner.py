"""Autotuner: guided tile-parameter search feeding the persistent TuningDB.

The paper's methodology (Figs. 3/4) swept the tile parameter exhaustively and
kept the best of repeated runs.  This engine keeps those semantics available
(``search="exhaustive"``) but defaults to **guided search**:

1. every feasible candidate is *ranked* by the analytic cost model
   (:mod:`repro.core.cost_model` — microseconds per candidate, no hardware);
2. only the top-``top_k`` ranked candidates are *evaluated* with the real
   scorer — the cost model itself for ``mode="model"``, wall-clock timing for
   ``mode="measure"`` (pallas-interpret or XLA on this host);
3. measured evaluation prunes early: once a candidate's first timed run is
   ``prune_factor`` x slower than the incumbent best, its remaining repeats
   are skipped.

So ``mode="measure"`` times a fraction of the space while the ranked order
keeps the winner equal-or-better than the exhaustive sweep's in model mode
(identical ranker and scorer) and empirically equal on measured hosts.

Scoring modes, matching how the paper and this container differ:

* ``mode="model"``  — analytic TPU cost model (the TPU-v5e target on this
  CPU-only container).
* ``mode="measure"`` — wall-clock, best of ``repeats`` runs ("keeping the
  maximum over ten runs", paper §2).

Winners flow into the registry immediately (``record=True``) and into
``tuned/<hardware>.json`` via :func:`repro.core.tuning_db.db_from_sweeps` /
``scripts/tune.py`` — the machine equivalent of paper Tab. 4.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import cost_model
from repro.core.hardware import (HardwareSpec, TPU_V5E, HOST_CPU,
                                 resolve_profile)
from repro.core.registry import (GLOBAL_REGISTRY, OP_FLASH_ATTENTION, OP_GEMM,
                                 OP_PAGED_ATTN, TileRegistry)
from repro.core.tile_config import (FlashAttentionConfig, FlashTuningSpace,
                                    PagedAttentionTuningSpace, TileConfig,
                                    TuningSpace)
from repro.kernels import ops

SEARCH_GUIDED = "guided"
SEARCH_EXHAUSTIVE = "exhaustive"
DEFAULT_TOP_K = 8
DEFAULT_PRUNE_FACTOR = 2.0


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    config: object                    # TileConfig | FlashAttentionConfig
    seconds: float
    gflops: float
    source: str  # "model" | "measure" | "measure-pruned"


@dataclasses.dataclass(frozen=True)
class SweepResult:
    shape: Tuple[int, ...]            # gemm: (m, k, n); flash: (sq, skv, d)
    dtype: str
    hardware: str
    points: List[SweepPoint]          # evaluated candidates only
    op: str = OP_GEMM
    search: str = SEARCH_EXHAUSTIVE
    candidates_total: int = 0         # size of the feasible space
    evaluated: int = 0                # candidates actually scored
    pruned: int = 0                   # measured candidates cut short

    # GEMM conveniences (match the pre-multi-op result API)
    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def k(self) -> int:
        return self.shape[1]

    @property
    def n(self) -> int:
        return self.shape[2]

    @property
    def best(self) -> SweepPoint:
        return min(self.points, key=lambda p: p.seconds)


def _measure(fn: Callable[[], jax.Array], repeats: int,
             prune_above: Optional[float] = None) -> Tuple[float, bool]:
    """Best-of-``repeats`` wall clock; returns (seconds, was_pruned).

    If the first timed run already exceeds ``prune_above``, the remaining
    repeats are skipped — the candidate cannot win.
    """
    fn().block_until_ready()  # compile / warm up
    best = float("inf")
    for i in range(repeats):
        t0 = time.perf_counter()
        fn().block_until_ready()
        best = min(best, time.perf_counter() - t0)
        if i == 0 and prune_above is not None and best > prune_above:
            return best, True
    return best, False


def _rank_candidates(cands: Sequence[TileConfig], m: int, k: int, n: int,
                     hardware: HardwareSpec, dtype) -> List[Tuple[TileConfig, float]]:
    """Cost-model ranking used to seed the guided search (cheapest first)."""
    scored = [(cfg, cost_model.gemm_cost(m, k, n, cfg, hardware, dtype).total_s)
              for cfg in cands]
    scored.sort(key=lambda cs: (cs[1], cs[0]))
    return scored


def sweep_gemm(
    m: int, k: int, n: int,
    *,
    dtype=jnp.float32,
    space: Optional[TuningSpace] = None,
    hardware: HardwareSpec = TPU_V5E,
    mode: str = "model",
    search: str = SEARCH_GUIDED,
    top_k: int = DEFAULT_TOP_K,
    prune_factor: float = DEFAULT_PRUNE_FACTOR,
    backend: str = ops.BACKEND_PALLAS_INTERPRET,
    repeats: int = 3,
    registry: Optional[TileRegistry] = None,
    record: bool = True,
) -> SweepResult:
    """Tune tile configs for one GEMM problem; optionally record the winner.

    ``hardware`` accepts a :class:`HardwareProfile`, a registered profile
    name (``"cpu-interpret"``, ...), or ``None`` to auto-detect the host.
    """
    if mode not in ("model", "measure"):
        raise ValueError(f"unknown mode {mode!r}")
    if search not in (SEARCH_GUIDED, SEARCH_EXHAUSTIVE):
        raise ValueError(f"unknown search {search!r}")

    hardware = resolve_profile(hardware)
    space = space or TuningSpace()
    flops = 2.0 * m * k * n
    cands = list(space.candidates(hardware, dtype, m=m, k=k, n=n))
    if not cands:
        raise ValueError(
            f"tuning space empty for ({m},{k},{n}) {jnp.dtype(dtype).name} "
            f"on {hardware.name}")

    ranked = _rank_candidates(cands, m, k, n, hardware, dtype)
    if search == SEARCH_GUIDED:
        selected = ranked[:max(1, top_k)]
    else:
        selected = ranked

    points: List[SweepPoint] = []
    pruned = 0
    if mode == "model":
        # ranker == scorer: reuse the ranking scores directly.
        for cfg, secs in selected:
            points.append(SweepPoint(cfg, secs, flops / secs / 1e9, "model"))
    else:
        key = jax.random.PRNGKey(0)
        a = jax.random.normal(key, (m, k), jnp.float32).astype(dtype)
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32).astype(dtype)
        best_so_far = float("inf")
        for cfg, _est in selected:
            fn = jax.jit(lambda a, b, c=cfg: ops.gemm(a, b, config=c, backend=backend))
            prune_above = (best_so_far * prune_factor
                           if search == SEARCH_GUIDED and best_so_far < float("inf")
                           else None)
            secs, was_pruned = _measure(lambda: fn(a, b), repeats, prune_above)
            pruned += was_pruned
            best_so_far = min(best_so_far, secs)
            points.append(SweepPoint(cfg, secs, flops / secs / 1e9,
                                     "measure-pruned" if was_pruned else "measure"))

    result = SweepResult(shape=(m, k, n), op=OP_GEMM,
                         dtype=jnp.dtype(dtype).name,
                         hardware=hardware.name, points=points, search=search,
                         candidates_total=len(cands), evaluated=len(points),
                         pruned=pruned)
    if record:
        reg = registry or GLOBAL_REGISTRY
        reg.put(result.best.config, hardware.name, dtype, m, k, n)
    return result


def sweep_flash_attention(
    sq: int, skv: int, d: int,
    *,
    dtype=jnp.float32,
    causal: bool = True,
    space: Optional[FlashTuningSpace] = None,
    hardware: HardwareSpec = TPU_V5E,
    mode: str = "model",
    search: str = SEARCH_GUIDED,
    top_k: int = DEFAULT_TOP_K,
    prune_factor: float = DEFAULT_PRUNE_FACTOR,
    batch_heads: int = 4,
    repeats: int = 3,
    registry: Optional[TileRegistry] = None,
    record: bool = True,
) -> SweepResult:
    """Tune (bq, bk) blocks for one flash-attention problem.

    Same guided-search machinery as :func:`sweep_gemm` — cost-model ranking
    (:func:`repro.core.cost_model.flash_cost`), top-K evaluation, measured
    pruning — applied to the op="flash_attention" candidate space.  The
    problem is identified by ``(sq, skv, d)`` (query length, KV length, head
    dim); ``batch_heads`` only sizes the measured-mode operands.  As for
    :func:`sweep_gemm`, ``hardware`` may be a profile, a name, or ``None``
    (auto-detect).
    """
    if mode not in ("model", "measure"):
        raise ValueError(f"unknown mode {mode!r}")
    if search not in (SEARCH_GUIDED, SEARCH_EXHAUSTIVE):
        raise ValueError(f"unknown search {search!r}")

    hardware = resolve_profile(hardware)
    space = space or FlashTuningSpace()
    # QK^T + PV: 4 * sq * skv * d per (batch, head) slice, halved if causal.
    flops = 4.0 * sq * skv * d * (0.5 if causal else 1.0)
    cands = list(space.candidates(hardware, dtype, sq=sq, skv=skv, d=d))
    if not cands:
        raise ValueError(
            f"flash tuning space empty for ({sq},{skv},{d}) "
            f"{jnp.dtype(dtype).name} on {hardware.name}")

    ranked = [(cfg, cost_model.flash_cost(sq, skv, d, cfg, hardware, dtype,
                                          causal=causal).total_s)
              for cfg in cands]
    ranked.sort(key=lambda cs: (cs[1], cs[0]))
    selected = ranked[:max(1, top_k)] if search == SEARCH_GUIDED else ranked

    points: List[SweepPoint] = []
    pruned = 0
    if mode == "model":
        for cfg, secs in selected:
            points.append(SweepPoint(cfg, secs, flops / secs / 1e9, "model"))
    else:
        from repro.kernels.flash_attention import flash_attention
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, sq, batch_heads, d),
                              jnp.float32).astype(dtype)
        k = jax.random.normal(ks[1], (1, skv, batch_heads, d),
                              jnp.float32).astype(dtype)
        v = jax.random.normal(ks[2], (1, skv, batch_heads, d),
                              jnp.float32).astype(dtype)
        best_so_far = float("inf")
        for cfg, _est in selected:
            fn = jax.jit(lambda q, k, v, c=cfg: flash_attention(
                q, k, v, causal=causal, bq=c.bq, bk=c.bk, interpret=True))
            prune_above = (best_so_far * prune_factor
                           if search == SEARCH_GUIDED and best_so_far < float("inf")
                           else None)
            secs, was_pruned = _measure(lambda: fn(q, k, v), repeats,
                                        prune_above)
            pruned += was_pruned
            best_so_far = min(best_so_far, secs)
            points.append(SweepPoint(
                cfg, secs, batch_heads * flops / secs / 1e9,
                "measure-pruned" if was_pruned else "measure"))

    result = SweepResult(shape=(sq, skv, d), op=OP_FLASH_ATTENTION,
                         dtype=jnp.dtype(dtype).name,
                         hardware=hardware.name, points=points, search=search,
                         candidates_total=len(cands), evaluated=len(points),
                         pruned=pruned)
    if record:
        reg = registry or GLOBAL_REGISTRY
        reg.put_op(OP_FLASH_ATTENTION, result.best.config, hardware.name,
                   dtype, (sq, skv, d))
    return result


def sweep_paged_attention(
    max_batch: int, max_len: int,
    *,
    dtype=jnp.float32,
    space: Optional[PagedAttentionTuningSpace] = None,
    hardware: HardwareSpec = TPU_V5E,
    mode: str = "model",
    repeats: int = 3,
    kv_heads: int = 4,
    head_dim: int = 16,
    registry: Optional[TileRegistry] = None,
    record: bool = True,
    mesh: Optional[str] = None,
) -> SweepResult:
    """Tune the paged-KV ``page_size`` for one serve-pool problem.

    The problem is identified by ``(max_batch, max_len)`` — the engine's
    lookup key, mirroring ``decode_loop``.  ``mode="measure"`` times one
    decode chunk's full data-movement path per candidate: host block-table +
    index computation (which scales with the page count) followed by the
    device gather/scatter roundtrip (:mod:`repro.kernels.paged`).
    ``mode="model"`` ranks candidates analytically: per-chunk index/block
    overhead falls as ``1/page_size`` while last-page fragmentation grows
    with it, giving an interior optimum without hardware.
    """
    if mode not in ("model", "measure"):
        raise ValueError(f"unknown mode {mode!r}")
    hardware = resolve_profile(hardware)
    space = space or PagedAttentionTuningSpace()
    cands = list(space.candidates(hardware, max_len=max_len))
    if not cands:
        raise ValueError(
            f"paged-KV tuning space empty for ({max_batch},{max_len}) "
            f"on {hardware.name}")

    tokens = float(max_batch * max_len)

    def model_cost(page_size: int) -> float:
        # block-table entries touched per chunk ~ tokens/page; expected
        # last-page slack ~ (page-1)/2 per row widens the working pool
        overhead = tokens / page_size
        waste = max_batch * (page_size - 1) / 2.0
        return (tokens + 4.0 * overhead + 2.0 * waste) * 1e-9

    points: List[SweepPoint] = []
    if mode == "model":
        for cfg in cands:
            points.append(SweepPoint(cfg, model_cost(cfg.page_size), 0.0,
                                     "model"))
    else:
        import numpy as np

        from repro.kernels.paged import paged_gather, paged_scatter
        from repro.serve import kv_pages

        chunk = 8
        width = min(64, max_len)
        for cfg in cands:
            p = cfg.page_size
            alloc = kv_pages.PageAllocator(max_batch * max_len, p)
            sched = kv_pages.ContinuousScheduler(max_batch, alloc)
            rng = np.random.default_rng(0)
            for rid in range(max_batch):
                sched.admit(rid, int(rng.integers(1, width - chunk + 1)),
                            budget=chunk)
            sched.ensure_chunk_pages(chunk)
            pool = jnp.zeros((2, alloc.num_pages * p, kv_heads, head_dim),
                             dtype)
            cols = jnp.ones((2, max_batch, chunk, kv_heads, head_dim), dtype)

            def step(pool, cols, p=p, sched=sched):
                gidx = kv_pages.gather_indices(sched.rows, max_batch, width,
                                               chunk, p)
                sidx = kv_pages.scatter_indices(sched.rows, max_batch, chunk,
                                                p)
                view = paged_gather(pool, jnp.asarray(gidx))
                return paged_scatter(pool, jnp.asarray(sidx), cols) \
                    + view.sum()
            secs, _ = _measure(lambda: step(pool, cols), repeats)
            points.append(SweepPoint(cfg, secs, 0.0, "measure"))

    result = SweepResult(shape=(max_batch, max_len), op=OP_PAGED_ATTN,
                         dtype=jnp.dtype(dtype).name,
                         hardware=hardware.name, points=points,
                         search=SEARCH_EXHAUSTIVE,
                         candidates_total=len(cands), evaluated=len(points),
                         pruned=0)
    if record:
        reg = registry or GLOBAL_REGISTRY
        reg.put_op(OP_PAGED_ATTN, result.best.config, hardware.name, dtype,
                   (max_batch, max_len), mesh=mesh)
    return result


def tune_model_gemms(shapes, *, dtype=jnp.bfloat16,
                     hardware: HardwareSpec = TPU_V5E,
                     registry: Optional[TileRegistry] = None,
                     search: str = SEARCH_GUIDED) -> dict:
    """Tune every (m, k, n) a model emits (collected via gemm_api tracing).

    Returns {shape: best TileConfig}.  This is the 'auto-tuning in a later
    step' the paper's §1.1 anticipates; feed the results to
    :func:`repro.core.tuning_db.db_from_sweeps` to persist them.
    """
    out = {}
    for (m, k, n) in sorted(set(shapes)):
        res = sweep_gemm(m, k, n, dtype=dtype, hardware=hardware,
                         mode="model", search=search, registry=registry)
        out[(m, k, n)] = res.best.config
    return out


def sweep_shapes(shapes, *, dtype=jnp.bfloat16,
                 hardware: HardwareSpec = TPU_V5E, mode: str = "model",
                 search: str = SEARCH_GUIDED,
                 registry: Optional[TileRegistry] = None,
                 **kw) -> List[SweepResult]:
    """Sweep a list of (m, k, n) problems; returns the full SweepResults
    (ready for :func:`repro.core.tuning_db.db_from_sweeps`)."""
    return [sweep_gemm(m, k, n, dtype=dtype, hardware=hardware, mode=mode,
                       search=search, registry=registry, **kw)
            for (m, k, n) in shapes]
