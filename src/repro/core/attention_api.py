"""The single public flash-attention entry point — models route here.

Mirror of :mod:`repro.core.gemm_api` for the attention kernel family: the
algorithm (``kernels/flash_attention.py``) is written once; *which (bq, bk)
blocks it runs with* is decided here from the ambient
:class:`~repro.core.gemm_api.ExecutionContext` plus the op-keyed tuning
registry.  Model code never mentions block sizes.

Lookup key: ``op="flash_attention"``, shape ``(sq, skv, head_dim)`` — the
same exact → nearest → generic → default resolution order as GEMM tiles,
fed by the committed ``tuned/<hardware>.json`` databases.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.gemm_api import _ctx
from repro.core.registry import GLOBAL_REGISTRY, LookupResult, OP_FLASH_ATTENTION


def flash_tile_lookup(hardware: str, dtype, sq: int, skv: int,
                      d: int) -> LookupResult:
    """Resolve tuned (bq, bk) blocks for one flash-attention problem.

    Thin, named wrapper over the registry so telemetry consumers (e.g.
    ``Engine.stats()``) and the model path share one lookup definition.
    """
    return GLOBAL_REGISTRY.lookup_op(OP_FLASH_ATTENTION, hardware, dtype,
                                     (sq, skv, d))


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    kv_start: Optional[jax.Array] = None,
                    bq: Optional[int] = None, bk: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Tuned flash attention over GQA-layout operands.

    Args:
      q: queries, shape ``(B, S, H, d)``.
      k, v: keys/values, shape ``(B, S_kv, KV, d)`` with ``KV`` dividing
        ``H`` (grouped-query attention; KV heads are expanded internally).
      causal: apply the causal mask (queries aligned to the *end* of the KV
        sequence when ``S != S_kv``).
      kv_start: optional ``(B,)`` int32 — first valid KV column per row for
        left-padded ragged batches; earlier columns are masked out of every
        softmax.
      bq, bk: explicit block-size overrides.  When omitted (the normal
        case), the blocks come from the tuning registry's
        ``op="flash_attention"`` entry for ``(S, S_kv, d)`` on the ambient
        context's hardware — exact tuned shape first, then nearest-shape,
        generic, and per-hardware default tiers.
      interpret: force/disable Pallas interpret mode; default: interpret
        everywhere except on real TPU backends.

    Returns:
      Attention output, shape ``(B, S, H, d)``, in ``q.dtype``.

    Example::

        from repro.core import execution_context, flash_attention
        from repro.core.hardware import TPU_V5E
        with execution_context(hardware=TPU_V5E.name):
            out = flash_attention(q, k, v, causal=True)   # tuned (bq, bk)
    """
    from repro.kernels import flash_attention as fa_kernel
    b, sq, h, d = q.shape
    skv = k.shape[1]
    if bq is None or bk is None:
        ctx = _ctx()
        cfg = flash_tile_lookup(ctx.resolve_hardware(), q.dtype,
                                sq, skv, d).config
        bq = bq if bq is not None else cfg.bq
        bk = bk if bk is not None else cfg.bk
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return fa_kernel.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                                     interpret=interpret, kv_start=kv_start)
