"""Core: the paper's contribution — single-source tunable GEMM machinery."""
from repro.core.gemm_api import (  # noqa: F401
    ExecutionContext, capture_gemm_shapes, einsum, execution_context, matmul,
)
from repro.core.hardware import HARDWARE, HOST_CPU, TPU_V5E, get_hardware  # noqa: F401
from repro.core.registry import GLOBAL_REGISTRY, TileRegistry, get_tile_config  # noqa: F401
from repro.core.tile_config import INTERPRET_SPACE, TileConfig, TuningSpace, square  # noqa: F401
from repro.core.tuner import SweepResult, sweep_gemm, tune_model_gemms  # noqa: F401
