"""Core: the paper's contribution — single-source tunable GEMM machinery."""
from repro.core.gemm_api import (  # noqa: F401
    ExecutionContext, capture_gemm_shapes, current_hardware, einsum,
    execution_context, matmul,
)
from repro.core.hardware import HARDWARE, HOST_CPU, TPU_V5E, get_hardware  # noqa: F401
from repro.core.registry import (  # noqa: F401
    GLOBAL_REGISTRY, LookupResult, TileRegistry, get_tile_config,
)
from repro.core.tile_config import INTERPRET_SPACE, TileConfig, TuningSpace, square  # noqa: F401
from repro.core.tuner import (  # noqa: F401
    SEARCH_EXHAUSTIVE, SEARCH_GUIDED, SweepResult, sweep_gemm, sweep_shapes,
    tune_model_gemms,
)
from repro.core.tuning_db import (  # noqa: F401
    TuningDB, TuningDBError, TuningRecord, db_from_sweeps, load_all,
)
