"""Core: the paper's contribution — single-source tunable kernel machinery.

One architecture-agnostic kernel source per op (GEMM, flash attention), with
every tuning knob — block sizes, backend, dtype policy — carried *outside*
the kernel in an op-keyed registry fed by a persistent tuning database.
"""
from repro.core.attention_api import flash_attention  # noqa: F401
from repro.core.gemm_api import (  # noqa: F401
    ExecutionContext, capture_gemm_shapes, current_hardware, einsum,
    execution_context, matmul,
)
from repro.core.hardware import (  # noqa: F401
    CPU_INTERPRET, GPU_GENERIC, HARDWARE, HOST_CPU, HardwareProfile,
    HardwareSpec, PLATFORM_CPU_INTERPRET, PLATFORM_GPU, PLATFORM_TPU,
    PROFILES, TPU_V5E, detect_hardware, get_hardware, get_profile,
    register_profile, resolve_hardware, resolve_profile,
)
from repro.core.registry import (  # noqa: F401
    GLOBAL_REGISTRY, KNOWN_OPS, LookupResult, OP_DECODE_LOOP,
    OP_FLASH_ATTENTION, OP_GEMM, TileRegistry, get_tile_config,
    mesh_hardware_key,
)
from repro.core.tile_config import (  # noqa: F401
    FLASH_INTERPRET_SPACE, DecodeLoopConfig, DecodeLoopTuningSpace,
    FlashAttentionConfig, FlashTuningSpace, INTERPRET_SPACE, TileConfig,
    TuningSpace, square,
)
from repro.core.tuner import (  # noqa: F401
    SEARCH_EXHAUSTIVE, SEARCH_GUIDED, SweepResult, sweep_flash_attention,
    sweep_gemm, sweep_shapes, tune_model_gemms,
)
from repro.core.tuning_db import (  # noqa: F401
    TuningDB, TuningDBError, TuningRecord, db_from_sweeps, load_all,
)
