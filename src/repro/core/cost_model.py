"""Analytic per-tile-config GEMM cost model (paper Eqs. 2, 5-7, TPU-adapted).

On this CPU-only container the TPU cannot be timed, so the tuner scores
TPU-target candidates with this model; the model itself is the paper's
compute-to-memory-ratio analysis R(N,T) = 2NT/(2N+T) (Eq. 7) upgraded to a
three-resource roofline over the explicit TPU memory hierarchy:

  compute time   = useful_flops / (peak * mxu_utilization(tiles))
  hbm time       = hbm_traffic(tiles) / hbm_bw     <- tile-dependent, Eq. 6
  overhead       = per-grid-step fixed cost (dispatch + pipeline fill)

  t_est = max(compute, hbm) + overhead            (perfectly overlapped DMA)

The paper's headline observation — doubling T doubles throughput until the
cache cliff — falls out of hbm_traffic ∝ 1/T with the VMEM feasibility
predicate cutting the sweep off.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.hardware import HardwareSpec, TPU_V5E
from repro.core.tile_config import TileConfig

# Fixed cost per grid step: kernel dispatch + DMA pipeline fill (double
# buffering hides most of it).  Calibrated so the untuned default tile lands
# at the paper's observed ~20%-of-peak baseline (§2.1) — at that point the
# memory term, not this constant, dominates, so the exact value only affects
# the ranking of very small tiles.
GRID_STEP_OVERHEAD_S = 5e-8


@dataclasses.dataclass(frozen=True)
class GemmCost:
    compute_s: float
    hbm_s: float
    overhead_s: float
    flops: int
    hbm_bytes: int

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.hbm_s) + self.overhead_s

    @property
    def tflops(self) -> float:
        return self.flops / self.total_s / 1e12

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def mxu_utilization(cfg: TileConfig, hw: HardwareSpec, in_dtype) -> float:
    """Fraction of MXU issue slots doing useful work for this block shape.

    Misaligned/small blocks waste systolic-array columns/rows (the TPU
    analogue of the paper's K80 register-pressure discussion).
    """
    sub = hw.sublane * (2 if jnp.dtype(in_dtype).itemsize == 2 else 1)
    eff_m = min(cfg.bm / sub, 16.0) / 16.0 if cfg.bm < 128 else 1.0
    eff_n = min(cfg.bn, hw.mxu_dim) / hw.mxu_dim
    eff_k = min(cfg.bk, hw.mxu_dim) / hw.mxu_dim
    return max(min(eff_m, 1.0), 0.05) * eff_n * eff_k


def gemm_cost(m: int, k: int, n: int, cfg: TileConfig,
              hw: HardwareSpec = TPU_V5E, in_dtype=jnp.bfloat16,
              out_dtype=None) -> GemmCost:
    out_dtype = out_dtype or in_dtype
    s_in = jnp.dtype(in_dtype).itemsize
    s_out = jnp.dtype(out_dtype).itemsize

    gm, gk, gn = _ceil_div(m, cfg.bm), _ceil_div(k, cfg.bk), _ceil_div(n, cfg.bn)
    mp, kp, np_ = gm * cfg.bm, gk * cfg.bk, gn * cfg.bn  # padded dims

    # Padded FLOPs actually issued (padding waste shows up here):
    issued_flops = 2 * mp * kp * np_
    useful_flops = 2 * m * k * n

    peak = hw.peak_for(in_dtype)
    compute_s = issued_flops / (peak * mxu_utilization(cfg, hw, in_dtype))

    # HBM traffic — paper Eq. 6 in rectangular form: every (i, j) output tile
    # streams the full A row-panel and B col-panel once (no cross-block
    # reuse beyond VMEM):  gn * (A bytes) + gm * (B bytes) + C write.
    hbm_bytes = (gn * mp * kp * s_in) + (gm * kp * np_ * s_in) \
        + mp * np_ * s_out
    hbm_s = hbm_bytes / hw.hbm_bandwidth

    overhead_s = gm * gn * gk * GRID_STEP_OVERHEAD_S

    return GemmCost(compute_s=compute_s, hbm_s=hbm_s, overhead_s=overhead_s,
                    flops=useful_flops, hbm_bytes=hbm_bytes)


def ratio_model(n: int, t: int) -> float:
    """Paper Eq. 7 verbatim: R(N, T) = 2NT / (2N + T)."""
    return 2.0 * n * t / (2.0 * n + t)


# ---------------------------------------------------------------------------
# Flash attention (op = "flash_attention")
# ---------------------------------------------------------------------------

def flash_cost(sq: int, skv: int, d: int, cfg: "FlashAttentionConfig",
               hw: HardwareSpec = TPU_V5E, in_dtype=jnp.bfloat16,
               causal: bool = True) -> GemmCost:
    """Analytic cost of one (batch*head) slice of the flash-attention kernel.

    Same three-resource roofline as :func:`gemm_cost`, with the kernel's
    actual traffic pattern: per q-block the full K and V stream through VMEM
    once, so HBM reads scale with ``ceil(sq / bq)`` — bigger bq => higher
    arithmetic intensity, the attention edition of paper Eq. 7.  Causal
    masking halves the useful score/PV work but not the streamed K/V bytes
    (the kernel visits every block; skipped math is modelled as utilization).
    """
    from repro.core.tile_config import FlashAttentionConfig  # cycle guard
    assert isinstance(cfg, FlashAttentionConfig), cfg
    s_in = jnp.dtype(in_dtype).itemsize

    gq, gk = _ceil_div(sq, cfg.bq), _ceil_div(skv, cfg.bk)
    sq_p, skv_p = gq * cfg.bq, gk * cfg.bk

    # Two matmuls per (q-block, kv-block): QK^T and PV -> 4 * sq * skv * d.
    issued_flops = 4 * sq_p * skv_p * d
    useful = 4 * sq * skv * d
    if causal:
        useful //= 2                       # lower-triangular half only

    peak = hw.peak_for(in_dtype)
    util_k = min(cfg.bk, hw.mxu_dim) / hw.mxu_dim
    util_d = min(d, hw.mxu_dim) / hw.mxu_dim
    compute_s = issued_flops / (peak * max(util_k * util_d, 0.05))

    # HBM: q read once, o written once, K and V re-read once per q-block.
    hbm_bytes = (sq_p * d + sq_p * d) * s_in + gq * (2 * skv_p * d) * s_in
    hbm_s = hbm_bytes / hw.hbm_bandwidth

    overhead_s = gq * gk * GRID_STEP_OVERHEAD_S

    return GemmCost(compute_s=compute_s, hbm_s=hbm_s, overhead_s=overhead_s,
                    flops=useful, hbm_bytes=hbm_bytes)
