"""Per-architecture optimal-parameter registry — Alpaka Listing 1.1 in JAX.

The paper stores the tuned tile size in a trait specialized per accelerator::

    template<...> struct OptimalVectorSize<AccGpuCudaRt<...>> { ... GPU_ELEM_NUM ... };
    template<...> struct OptimalVectorSize<AccCpuOmp2Blocks<...>> { ... OMP_ELEM_NUM ... };

Here the same role is played by a runtime registry keyed by
(backend/hardware, dtype) with optional per-problem-shape tuned overrides
persisted to JSON (the tuner writes them; Tab. 4 of the paper is exactly
such a table).  Model/kernel code only ever asks ``get_tile_config`` —
tuning never touches implementation code.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core.tile_config import TileConfig

# ---------------------------------------------------------------------------
# Defaults (the #define GPU_ELEM_NUM / OMP_ELEM_NUM analogue): reasonable
# untuned starting points per backend & dtype — the paper's "20% of peak"
# baseline configuration.
# ---------------------------------------------------------------------------
_DEFAULTS: Dict[Tuple[str, str], TileConfig] = {
    ("tpu-v5e", "bfloat16"): TileConfig(128, 128, 128),
    ("tpu-v5e", "float32"): TileConfig(128, 128, 128),
    ("host-cpu", "bfloat16"): TileConfig(32, 32, 32),
    ("host-cpu", "float32"): TileConfig(32, 32, 32),
}
_FALLBACK = TileConfig(128, 128, 128)


def _key_str(hardware: str, dtype, m=None, k=None, n=None) -> str:
    dt = jnp.dtype(dtype).name
    if m is None:
        return f"{hardware}/{dt}"
    return f"{hardware}/{dt}/{m}x{k}x{n}"


class TileRegistry:
    """Thread-safe tuned-parameter store with JSON persistence."""

    def __init__(self, path: Optional[str] = None):
        self._lock = threading.Lock()
        self._tuned: Dict[str, TileConfig] = {}
        self._path = path
        if path and os.path.exists(path):
            self.load(path)

    # -- lookup --------------------------------------------------------
    def get(self, hardware: str, dtype, m: int = None, k: int = None,
            n: int = None) -> TileConfig:
        """Most-specific-first: tuned (hw, dtype, shape) -> tuned (hw, dtype)
        -> built-in default -> fallback."""
        with self._lock:
            if m is not None:
                hit = self._tuned.get(_key_str(hardware, dtype, m, k, n))
                if hit is not None:
                    return hit
            hit = self._tuned.get(_key_str(hardware, dtype))
            if hit is not None:
                return hit
        return _DEFAULTS.get((hardware, jnp.dtype(dtype).name), _FALLBACK)

    # -- update --------------------------------------------------------
    def put(self, cfg: TileConfig, hardware: str, dtype, m: int = None,
            k: int = None, n: int = None) -> None:
        with self._lock:
            self._tuned[_key_str(hardware, dtype, m, k, n)] = cfg

    # -- persistence (Tab. 4 as a file) ---------------------------------
    def save(self, path: Optional[str] = None) -> None:
        path = path or self._path
        if not path:
            raise ValueError("no path for registry save")
        with self._lock:
            blob = {k: [c.bm, c.bk, c.bn] for k, c in self._tuned.items()}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
        os.replace(tmp, path)

    def load(self, path: str) -> None:
        with open(path) as f:
            blob = json.load(f)
        with self._lock:
            for k, (bm, bk, bn) in blob.items():
                self._tuned[k] = TileConfig(bm=bm, bk=bk, bn=bn)

    def entries(self) -> Dict[str, TileConfig]:
        with self._lock:
            return dict(self._tuned)


# Process-global registry (models import this).
GLOBAL_REGISTRY = TileRegistry()


def get_tile_config(hardware: str, dtype, m: int = None, k: int = None,
                    n: int = None) -> TileConfig:
    return GLOBAL_REGISTRY.get(hardware, dtype, m, k, n)
