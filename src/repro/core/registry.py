"""Tuned-parameter lookup: the runtime face of the tuning database.

The paper stores its tuned tile size in a C++ trait specialized per
accelerator (Listing 1.1); here the same role is played by a thread-safe
registry keyed by **(op, hardware, dtype)** with per-problem-shape tuned
entries.  ``op`` names the kernel family — ``"gemm"`` entries hold
:class:`~repro.core.tile_config.TileConfig` blocks, ``"flash_attention"``
entries hold :class:`~repro.core.tile_config.FlashAttentionConfig` blocks —
so one registry (and one committed DB file per hardware target) serves every
tunable kernel.  Kernel/model code only ever asks :func:`get_tile_config`
(via ``gemm_api.matmul``) or :func:`repro.core.attention_api.flash_attention`
— tuning never touches implementation code.

Resolution order for ``lookup_op(op, hardware, dtype, shape)``:

1. **exact**   — a tuned entry for this precise shape;
2. **nearest** — the tuned entry for the closest shape (log-space distance
   over the dims, capped by ``NEAREST_MAX_LOG2_DIST``), so untuned
   problems reuse a neighbour's blocks instead of the static default;
3. **generic** — a shape-agnostic tuned entry for (op, hardware, dtype);
4. **default** — the hardware profile's seeded per-op starting point (the
   paper's ``#define GPU_ELEM_NUM`` analogue, its ~20%-of-peak baseline) —
   registering a profile in :mod:`repro.core.hardware` is what gives a new
   backend this tier;
5. **fallback** — for an *unregistered* hardware name, the detected host
   profile's seeds (after a once-per-process warning), else the op's
   hardware-agnostic last resort.

Nearest-shape scans never cross ops, hardware, or dtypes: exact entries are
bucketed by the full (op, hardware, dtype) key, so a flash-attention lookup
can never be satisfied by (or pay a scan over) GEMM entries.

Persistence lives in :mod:`repro.core.tuning_db` (versioned, op-keyed
``tuned/<hardware>.json`` files, the paper's Tab. 4 as committed artifacts);
the process-global registry lazily loads every DB file at first lookup, so a
fresh process — serving, training, or a bare ``matmul`` call — picks up
committed tuning results automatically.  ``TileRegistry.save``/``load`` keep
the legacy flat-JSON format for ad-hoc snapshots.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import threading
import warnings
from typing import Dict, Optional, Tuple, Union

import jax.numpy as jnp

from repro.core import hardware as hw
from repro.core.tile_config import (DecodeLoopConfig, FlashAttentionConfig,
                                    PagedAttentionConfig, TileConfig)

#: op names — the kernel families the tuning framework knows about
OP_GEMM = "gemm"
OP_FLASH_ATTENTION = "flash_attention"
OP_DECODE_LOOP = "decode_loop"
OP_PAGED_ATTN = "paged_attn"
KNOWN_OPS = (OP_GEMM, OP_FLASH_ATTENTION, OP_DECODE_LOOP, OP_PAGED_ATTN)

AnyConfig = Union[TileConfig, FlashAttentionConfig, DecodeLoopConfig,
                  PagedAttentionConfig]


def mesh_hardware_key(hardware: str, mesh: Optional[str]) -> str:
    """Registry bucket name for mesh-keyed tuned entries.

    The paper keys tuned parameters by architecture; a sharded run adds a
    second coordinate — the *topology* — because the best block (or decode
    unroll) on ``data=4,model=2`` need not match one chip.  Entries tuned
    for a specific mesh live under ``<hardware>@<mesh-label>`` (e.g.
    ``cpu-interpret@data4xmodel2``) and are consulted before the plain
    per-hardware tiers.
    """
    return f"{hardware}@{mesh}" if mesh else hardware

# ---------------------------------------------------------------------------
# Defaults (the #define GPU_ELEM_NUM / OMP_ELEM_NUM analogue): the untuned
# starting point per (op, backend) — the paper's "20% of peak" baseline —
# now seeded from the hardware-profile layer rather than a table here, so
# registering a new backend automatically gives it a default tier.
# ---------------------------------------------------------------------------
_FALLBACK: Dict[str, AnyConfig] = {
    OP_GEMM: TileConfig(128, 128, 128),
    OP_FLASH_ATTENTION: FlashAttentionConfig(128, 128),
    OP_DECODE_LOOP: DecodeLoopConfig(1),
    OP_PAGED_ATTN: PagedAttentionConfig(16),
}

#: hardware names already warned about (once-per-process, tests reset it)
_WARNED_UNKNOWN_HARDWARE = set()


def _seeded_default(op: str, hardware: str) -> Tuple[Optional[AnyConfig], str]:
    """(config, source) for the default tier of ``(op, hardware)``.

    A registered profile (alias-aware) yields its seeded default block with
    source ``"default"``.  An *unknown* hardware name used to escape as a
    bare ``KeyError`` from deep inside the lookup path; now it warns once
    per process and serves the detected host profile's seeded defaults with
    source ``"fallback"`` — a typo'd or not-yet-registered target degrades
    loudly instead of crashing mid-serve.
    """
    prof = hw.find_profile(hardware)
    source = "default"
    if prof is None:
        detected = hw.detect_hardware()
        if hardware not in _WARNED_UNKNOWN_HARDWARE:
            _WARNED_UNKNOWN_HARDWARE.add(hardware)
            warnings.warn(
                f"unknown hardware {hardware!r} (known: {sorted(hw.HARDWARE)});"
                f" falling back to the detected profile {detected!r}'s seeded"
                f" default blocks", stacklevel=4)
        prof = hw.find_profile(detected)
        source = "fallback"
    block = prof.default_block(op) if prof is not None else None
    if block is None:
        return None, source
    return config_from_block(op, block), source

#: per-op config class — used to rebuild configs from persisted block tuples
CONFIG_CLASS = {OP_GEMM: TileConfig, OP_FLASH_ATTENTION: FlashAttentionConfig,
                OP_DECODE_LOOP: DecodeLoopConfig,
                OP_PAGED_ATTN: PagedAttentionConfig}

#: length of each op's problem-shape tuple: gemm (m, k, n); flash
#: (sq, skv, head_dim); decode_loop and paged_attn (max_batch, max_len).
#: The block-tuple length is derived from the config class's fields —
#: together with CONFIG_CLASS/_DEFAULTS/_FALLBACK this is the one place to
#: extend when adding an op.
OP_SHAPE_LEN = {OP_GEMM: 3, OP_FLASH_ATTENTION: 3, OP_DECODE_LOOP: 2,
                OP_PAGED_ATTN: 2}
OP_BLOCK_LEN = {op: len(dataclasses.fields(cls))
                for op, cls in CONFIG_CLASS.items()}


def config_from_block(op: str, block) -> AnyConfig:
    """Rebuild the op's config object from a flat block-size tuple."""
    try:
        cls = CONFIG_CLASS[op]
    except KeyError:
        raise ValueError(f"unknown op {op!r}; known: {sorted(CONFIG_CLASS)}")
    return cls(*block)


def block_of(cfg: AnyConfig) -> Tuple[int, ...]:
    """Flatten a config object to its persistable block-size tuple."""
    return tuple(dataclasses.astuple(cfg))


#: nearest-shape matches beyond this cumulative |log2| distance are rejected
#: (e.g. 6.0 allows a combined size ratio of 2**6 across the dims).
NEAREST_MAX_LOG2_DIST = 6.0


def _key_str(op: str, hardware: str, dtype, shape=None) -> str:
    dt = jnp.dtype(dtype).name
    prefix = f"{hardware}/{dt}" if op == OP_GEMM else f"{op}:{hardware}/{dt}"
    if shape is None:
        return prefix
    return prefix + "/" + "x".join(str(s) for s in shape)


def _shape_dist(a: Tuple[int, ...], b: Tuple[int, ...]) -> float:
    if len(a) != len(b):
        return float("inf")
    return sum(abs(math.log2(max(x, 1)) - math.log2(max(y, 1)))
               for x, y in zip(a, b))


@dataclasses.dataclass(frozen=True)
class LookupResult:
    """A resolved config plus where it came from (for tests/telemetry)."""
    config: AnyConfig
    source: str                                  # exact|nearest|generic|default|fallback
    matched_shape: Optional[Tuple[int, ...]] = None
    distance: float = 0.0
    op: str = OP_GEMM
    #: mesh label of the bucket that satisfied the lookup (None = the plain
    #: per-hardware tiers; set only when a mesh-keyed entry won)
    mesh: Optional[str] = None


class TileRegistry:
    """Thread-safe tuned-parameter store with nearest-shape fallback.

    GEMM callers keep the original (hardware, dtype, m, k, n) API
    (:meth:`get`, :meth:`put`, :meth:`lookup`); other ops use the op-keyed
    :meth:`get_op`, :meth:`put_op`, :meth:`lookup_op`.
    """

    def __init__(self, path: Optional[str] = None, *, autoload: bool = False):
        self._lock = threading.Lock()
        self._autoload_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        # shape-specific entries, bucketed by (op, hw, dtype) so hot lookups
        # (e.g. decode-shape GEMMs) never scan other ops' or hardware's
        # entries:  (op, hw, dtype) -> {shape tuple -> config}
        self._exact: Dict[Tuple[str, str, str],
                          Dict[Tuple[int, ...], AnyConfig]] = {}
        # shape-agnostic entries: (op, hw, dtype) -> config
        self._generic: Dict[Tuple[str, str, str], AnyConfig] = {}
        self._path = path
        self._autoload = autoload
        self._autoload_done = False
        self.hit_stats: Dict[str, int] = {}
        if path and os.path.exists(path):
            self.load(path)

    # -- auto-load of committed tuning DBs ------------------------------
    def _ensure_autoloaded(self) -> None:
        if not self._autoload or self._autoload_done:
            return
        # Concurrent first lookups block here until the load completes, so
        # no thread ever resolves against a half-populated registry; the
        # done flag is only set once the DBs are in.
        with self._autoload_lock:
            if self._autoload_done:
                return
            from repro.core import tuning_db  # deferred: tuning_db is standalone
            tuning_db.load_all(self)
            self._autoload_done = True

    def mark_autoloaded(self) -> None:
        """Disable the lazy default-dir load (an explicit load supersedes it)."""
        self._autoload_done = True

    # -- lookup --------------------------------------------------------
    def lookup_op(self, op: str, hardware: str, dtype,
                  shape: Optional[Tuple[int, ...]] = None,
                  mesh: Optional[str] = None) -> LookupResult:
        """Resolve a config for ``op``, reporting which tier satisfied it.

        ``hardware`` is alias-canonicalized (``host-cpu`` -> ``cpu-interpret``)
        so entries stored under a legacy name and lookups under the new one
        land in the same bucket.  When ``mesh`` (a topology label such as
        ``"data4xmodel2"``) is given, the mesh-keyed bucket
        ``<hardware>@<mesh>`` is consulted first — its exact/nearest/generic
        tiers outrank every plain-hardware tier, because a block tuned for
        this topology beats a block tuned for one chip — before falling back
        to the topology-agnostic path.
        """
        self._ensure_autoloaded()
        hardware = hw.canonical_name(hardware)
        dt = jnp.dtype(dtype).name
        if mesh:
            mesh_hw = mesh_hardware_key(hardware, mesh)
            with self._lock:
                res = self._tuned_locked(op, mesh_hw, dt, shape)
            if res is not None:
                return self._count(dataclasses.replace(res, mesh=mesh))
        with self._lock:
            res = self._tuned_locked(op, hardware, dt, shape)
        if res is not None:
            return self._count(res)
        cfg, source = _seeded_default(op, hardware)
        if cfg is not None:
            return self._count(LookupResult(cfg, source, op=op))
        return self._count(LookupResult(_FALLBACK[op], "fallback", op=op))

    def _tuned_locked(self, op: str, hardware: str, dt: str,
                      shape: Optional[Tuple[int, ...]],
                      ) -> Optional[LookupResult]:
        """exact > nearest > generic within one hardware bucket, or None."""
        if shape is not None:
            bucket = self._exact.get((op, hardware, dt))
            hit = bucket.get(tuple(shape)) if bucket else None
            if hit is not None:
                return LookupResult(hit, "exact", tuple(shape), op=op)
            near = self._nearest_locked(op, hardware, dt, tuple(shape))
            if near is not None:
                return near
        hit = self._generic.get((op, hardware, dt))
        if hit is not None:
            return LookupResult(hit, "generic", op=op)
        return None

    def lookup(self, hardware: str, dtype, m: int = None, k: int = None,
               n: int = None) -> LookupResult:
        """GEMM-compat wrapper: resolve a :class:`TileConfig` for (m, k, n)."""
        has_shape = m is not None and k is not None and n is not None
        return self.lookup_op(OP_GEMM, hardware, dtype,
                              (m, k, n) if has_shape else None)

    def _nearest_locked(self, op: str, hardware: str, dt: str,
                        shape: Tuple[int, ...]) -> Optional[LookupResult]:
        # Scans only this (op, hardware, dtype) bucket — other ops' and
        # backends' tuned shapes never slow down (or leak into) this lookup.
        best = None
        for mshape, cfg in self._exact.get((op, hardware, dt), {}).items():
            dist = _shape_dist(shape, mshape)
            if dist > NEAREST_MAX_LOG2_DIST:
                continue
            cand = (dist, mshape, cfg)
            if best is None or cand[:2] < best[:2]:  # distance, then shape
                best = cand
        if best is None:
            return None
        dist, mshape, cfg = best
        return LookupResult(cfg, "nearest", mshape, dist, op=op)

    def _count(self, res: LookupResult) -> LookupResult:
        # leaf-level lock of its own: callers may or may not hold self._lock
        with self._stats_lock:
            self.hit_stats[res.source] = self.hit_stats.get(res.source, 0) + 1
        return res

    def get_op(self, op: str, hardware: str, dtype,
               shape: Optional[Tuple[int, ...]] = None,
               mesh: Optional[str] = None) -> AnyConfig:
        return self.lookup_op(op, hardware, dtype, shape, mesh=mesh).config

    def get(self, hardware: str, dtype, m: int = None, k: int = None,
            n: int = None) -> TileConfig:
        return self.lookup(hardware, dtype, m, k, n).config

    # -- update --------------------------------------------------------
    def put_op(self, op: str, cfg: AnyConfig, hardware: str, dtype,
               shape: Optional[Tuple[int, ...]] = None,
               mesh: Optional[str] = None) -> None:
        if op not in CONFIG_CLASS:
            raise ValueError(f"unknown op {op!r}; known: {sorted(CONFIG_CLASS)}")
        # Canonicalize legacy aliases on write too, so a tuned/host-cpu.json
        # loaded into the registry is reachable from cpu-interpret lookups.
        hardware = mesh_hardware_key(hw.canonical_name(hardware), mesh)
        dt = jnp.dtype(dtype).name
        with self._lock:
            if shape is None:
                self._generic[(op, hardware, dt)] = cfg
            else:
                self._exact.setdefault((op, hardware, dt), {})[tuple(shape)] = cfg

    def put(self, cfg: TileConfig, hardware: str, dtype, m: int = None,
            k: int = None, n: int = None) -> None:
        """GEMM-compat wrapper around :meth:`put_op`."""
        if m is None or k is None or n is None:
            # partial shapes are meaningless for nearest-distance math;
            # anything short of a full (m, k, n) is a generic entry
            self.put_op(OP_GEMM, cfg, hardware, dtype, None)
        else:
            self.put_op(OP_GEMM, cfg, hardware, dtype, (m, k, n))

    def clear(self) -> None:
        with self._lock:
            self._exact.clear()
            self._generic.clear()
            self.hit_stats.clear()

    # -- persistence (legacy flat snapshot; tuning_db is the real store) -
    def save(self, path: Optional[str] = None) -> None:
        path = path or self._path
        if not path:
            raise ValueError("no path for registry save")
        blob = {k: list(block_of(c)) for k, c in self.entries().items()}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
        os.replace(tmp, path)

    def load(self, path: str) -> None:
        with open(path) as f:
            blob = json.load(f)
        with self._lock:
            for key, block in blob.items():
                op = OP_GEMM
                if ":" in key:
                    op, key = key.split(":", 1)
                cfg = config_from_block(op, block)
                parts = key.split("/")
                if len(parts) == 2:
                    self._generic[(op, parts[0], parts[1])] = cfg
                else:
                    shape = tuple(int(x) for x in parts[2].split("x"))
                    self._exact.setdefault(
                        (op, parts[0], parts[1]), {})[shape] = cfg

    def entries(self) -> Dict[str, AnyConfig]:
        with self._lock:
            out = {_key_str(op, hw, dt): cfg
                   for (op, hw, dt), cfg in self._generic.items()}
            out.update({_key_str(op, hw, dt, shape): cfg
                        for (op, hw, dt), bucket in self._exact.items()
                        for shape, cfg in bucket.items()})
        return out


# Process-global registry (models import this); lazily pulls in every
# committed tuned/<hardware>.json at first lookup.
GLOBAL_REGISTRY = TileRegistry(autoload=True)


def get_tile_config(hardware: str, dtype, m: int = None, k: int = None,
                    n: int = None) -> TileConfig:
    return GLOBAL_REGISTRY.get(hardware, dtype, m, k, n)
