"""Tuned-parameter lookup: the runtime face of the tuning database.

The paper stores its tuned tile size in a C++ trait specialized per
accelerator (Listing 1.1); here the same role is played by a thread-safe
registry keyed by (hardware, dtype) with per-problem-shape tuned entries.
Kernel/model code only ever asks :func:`get_tile_config` (via
``gemm_api.matmul``) — tuning never touches implementation code.

Resolution order for ``get(hardware, dtype, m, k, n)``:

1. **exact**   — a tuned entry for this precise (m, k, n);
2. **nearest** — the tuned entry for the closest shape (log-space distance
   over the three dims, capped by ``NEAREST_MAX_LOG2_DIST``), so untuned
   problems reuse a neighbour's tile instead of the static default;
3. **generic** — a shape-agnostic tuned entry for (hardware, dtype);
4. **default** — the built-in per-backend starting point (the paper's
   ``#define GPU_ELEM_NUM`` analogue, its ~20%-of-peak baseline);
5. **fallback** — 128x128x128.

Persistence lives in :mod:`repro.core.tuning_db` (versioned
``tuned/<hardware>.json`` files, the paper's Tab. 4 as committed artifacts);
the process-global registry lazily loads every DB file at first lookup, so a
fresh process — serving, training, or a bare ``matmul`` call — picks up
committed tuning results automatically.  ``TileRegistry.save``/``load`` keep
the legacy flat-JSON format for ad-hoc snapshots.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import threading
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core.tile_config import TileConfig

# ---------------------------------------------------------------------------
# Defaults (the #define GPU_ELEM_NUM / OMP_ELEM_NUM analogue): reasonable
# untuned starting points per backend & dtype — the paper's "20% of peak"
# baseline configuration.
# ---------------------------------------------------------------------------
_DEFAULTS: Dict[Tuple[str, str], TileConfig] = {
    ("tpu-v5e", "bfloat16"): TileConfig(128, 128, 128),
    ("tpu-v5e", "float32"): TileConfig(128, 128, 128),
    ("host-cpu", "bfloat16"): TileConfig(32, 32, 32),
    ("host-cpu", "float32"): TileConfig(32, 32, 32),
}
_FALLBACK = TileConfig(128, 128, 128)

#: nearest-shape matches beyond this cumulative |log2| distance are rejected
#: (e.g. 6.0 allows a combined size ratio of 2**6 across the three dims).
NEAREST_MAX_LOG2_DIST = 6.0


def _key_str(hardware: str, dtype, m=None, k=None, n=None) -> str:
    dt = jnp.dtype(dtype).name
    if m is None:
        return f"{hardware}/{dt}"
    return f"{hardware}/{dt}/{m}x{k}x{n}"


def _shape_dist(a: Tuple[int, int, int], b: Tuple[int, int, int]) -> float:
    return sum(abs(math.log2(max(x, 1)) - math.log2(max(y, 1)))
               for x, y in zip(a, b))


@dataclasses.dataclass(frozen=True)
class LookupResult:
    """A resolved tile config plus where it came from (for tests/telemetry)."""
    config: TileConfig
    source: str                                  # exact|nearest|generic|default|fallback
    matched_shape: Optional[Tuple[int, int, int]] = None
    distance: float = 0.0


class TileRegistry:
    """Thread-safe tuned-parameter store with nearest-shape fallback."""

    def __init__(self, path: Optional[str] = None, *, autoload: bool = False):
        self._lock = threading.Lock()
        self._autoload_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        # shape-specific entries, bucketed by (hw, dtype) so hot lookups
        # (e.g. decode-shape GEMMs) never scan other hardware's entries:
        # (hw, dtype) -> {(m, k, n) -> TileConfig}
        self._exact: Dict[Tuple[str, str],
                          Dict[Tuple[int, int, int], TileConfig]] = {}
        # shape-agnostic entries: (hw, dtype) -> TileConfig
        self._generic: Dict[Tuple[str, str], TileConfig] = {}
        self._path = path
        self._autoload = autoload
        self._autoload_done = False
        self.hit_stats: Dict[str, int] = {}
        if path and os.path.exists(path):
            self.load(path)

    # -- auto-load of committed tuning DBs ------------------------------
    def _ensure_autoloaded(self) -> None:
        if not self._autoload or self._autoload_done:
            return
        # Concurrent first lookups block here until the load completes, so
        # no thread ever resolves against a half-populated registry; the
        # done flag is only set once the DBs are in.
        with self._autoload_lock:
            if self._autoload_done:
                return
            from repro.core import tuning_db  # deferred: tuning_db is standalone
            tuning_db.load_all(self)
            self._autoload_done = True

    def mark_autoloaded(self) -> None:
        """Disable the lazy default-dir load (an explicit load supersedes it)."""
        self._autoload_done = True

    # -- lookup --------------------------------------------------------
    def lookup(self, hardware: str, dtype, m: int = None, k: int = None,
               n: int = None) -> LookupResult:
        """Resolve a tile config, reporting which tier satisfied it."""
        self._ensure_autoloaded()
        dt = jnp.dtype(dtype).name
        has_shape = m is not None and k is not None and n is not None
        with self._lock:
            if has_shape:
                bucket = self._exact.get((hardware, dt))
                hit = bucket.get((m, k, n)) if bucket else None
                if hit is not None:
                    res = LookupResult(hit, "exact", (m, k, n))
                    return self._count(res)
                near = self._nearest_locked(hardware, dt, (m, k, n))
                if near is not None:
                    return self._count(near)
            hit = self._generic.get((hardware, dt))
            if hit is not None:
                return self._count(LookupResult(hit, "generic"))
        cfg = _DEFAULTS.get((hardware, dt))
        if cfg is not None:
            return self._count(LookupResult(cfg, "default"))
        return self._count(LookupResult(_FALLBACK, "fallback"))

    def _nearest_locked(self, hardware: str, dt: str,
                        shape: Tuple[int, int, int]) -> Optional[LookupResult]:
        # Scans only this (hardware, dtype) bucket — other backends' tuned
        # shapes never slow down (or leak into) this lookup.
        best = None
        for (m, k, n), cfg in self._exact.get((hardware, dt), {}).items():
            dist = _shape_dist(shape, (m, k, n))
            if dist > NEAREST_MAX_LOG2_DIST:
                continue
            cand = (dist, (m, k, n), cfg)
            if best is None or cand[:2] < best[:2]:  # distance, then shape
                best = cand
        if best is None:
            return None
        dist, mshape, cfg = best
        return LookupResult(cfg, "nearest", mshape, dist)

    def _count(self, res: LookupResult) -> LookupResult:
        # leaf-level lock of its own: callers may or may not hold self._lock
        with self._stats_lock:
            self.hit_stats[res.source] = self.hit_stats.get(res.source, 0) + 1
        return res

    def get(self, hardware: str, dtype, m: int = None, k: int = None,
            n: int = None) -> TileConfig:
        return self.lookup(hardware, dtype, m, k, n).config

    # -- update --------------------------------------------------------
    def put(self, cfg: TileConfig, hardware: str, dtype, m: int = None,
            k: int = None, n: int = None) -> None:
        dt = jnp.dtype(dtype).name
        with self._lock:
            if m is None or k is None or n is None:
                # partial shapes are meaningless for nearest-distance math;
                # anything short of a full (m, k, n) is a generic entry
                self._generic[(hardware, dt)] = cfg
            else:
                self._exact.setdefault((hardware, dt), {})[(m, k, n)] = cfg

    def clear(self) -> None:
        with self._lock:
            self._exact.clear()
            self._generic.clear()
            self.hit_stats.clear()

    # -- persistence (legacy flat snapshot; tuning_db is the real store) -
    def save(self, path: Optional[str] = None) -> None:
        path = path or self._path
        if not path:
            raise ValueError("no path for registry save")
        blob = {k: [c.bm, c.bk, c.bn] for k, c in self.entries().items()}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
        os.replace(tmp, path)

    def load(self, path: str) -> None:
        with open(path) as f:
            blob = json.load(f)
        with self._lock:
            for key, (bm, bk, bn) in blob.items():
                parts = key.split("/")
                cfg = TileConfig(bm=bm, bk=bk, bn=bn)
                if len(parts) == 2:
                    self._generic[(parts[0], parts[1])] = cfg
                else:
                    m, k, n = (int(x) for x in parts[2].split("x"))
                    self._exact.setdefault(
                        (parts[0], parts[1]), {})[(m, k, n)] = cfg

    def entries(self) -> Dict[str, TileConfig]:
        with self._lock:
            out = {_key_str(hw, dt): cfg
                   for (hw, dt), cfg in self._generic.items()}
            out.update({_key_str(hw, dt, m, k, n): cfg
                        for (hw, dt), bucket in self._exact.items()
                        for (m, k, n), cfg in bucket.items()})
        return out


# Process-global registry (models import this); lazily pulls in every
# committed tuned/<hardware>.json at first lookup.
GLOBAL_REGISTRY = TileRegistry(autoload=True)


def get_tile_config(hardware: str, dtype, m: int = None, k: int = None,
                    n: int = None) -> TileConfig:
    return GLOBAL_REGISTRY.get(hardware, dtype, m, k, n)
