"""Trace capture: a scoped ``jax.profiler`` session + code markers.

``trace(dir)`` wraps ``jax.profiler.start_trace``/``stop_trace`` with the
directory management the post-processor expects; when profiling is off
(``enabled=False`` or no directory) it is a STRICT no-op — no directories
created, no XLA/env state touched, no profiler hooks installed — so it can
stay permanently in the serve/train launchers at zero cost.

``annotate(name)`` is the marker the engine and trainer thread through
their hot paths.  It stacks ``jax.profiler.TraceAnnotation`` (a host-side
timeline event, how the breakdown attributes wall time to e.g.
``serve.decode_wave``) with ``jax.named_scope`` (an HLO metadata scope, so
compiled-op names carry the region they were traced under).  Both are
near-free when no trace is active, so annotations are unconditional.
"""
from __future__ import annotations

import contextlib
import dataclasses
import glob
import os
from typing import Iterator, List, Optional

import jax


@dataclasses.dataclass
class TraceSession:
    """Handle yielded by :func:`trace`: where the capture landed (if on)."""
    dir: Optional[str]
    enabled: bool

    def trace_files(self) -> List[str]:
        """The captured ``*.trace.json.gz`` files (newest capture first).

        ``jax.profiler`` writes ``<dir>/plugins/profile/<timestamp>/`` per
        capture; an engine process may trace more than once into one dir.
        """
        if not self.dir:
            return []
        pattern = os.path.join(self.dir, "plugins", "profile", "*",
                               "*.trace.json.gz")
        return sorted(glob.glob(pattern), key=os.path.getmtime, reverse=True)

    def events(self) -> List[dict]:
        """Parsed Chrome-trace events of the newest capture ([] when off)."""
        from repro.profiling.breakdown import load_trace_events
        if not self.enabled:
            return []
        return load_trace_events(self.dir)


@contextlib.contextmanager
def trace(out_dir: Optional[str] = None, *,
          enabled: bool = True) -> Iterator[TraceSession]:
    """Capture a ``jax.profiler`` trace into ``out_dir`` for the block.

    Disabled (``enabled=False`` or falsy ``out_dir``) it yields an inert
    session and touches nothing.  Enabled, it creates the directory, starts
    the profiler, and guarantees ``stop_trace`` on exit (also on exceptions,
    so a crashed wave still leaves a parseable capture behind).
    """
    if not enabled or not out_dir:
        yield TraceSession(dir=None, enabled=False)
        return
    os.makedirs(out_dir, exist_ok=True)
    jax.profiler.start_trace(out_dir)
    try:
        yield TraceSession(dir=out_dir, enabled=True)
    finally:
        jax.profiler.stop_trace()


@contextlib.contextmanager
def annotate(name: str) -> Iterator[None]:
    """Mark a code region in the trace timeline AND the HLO metadata."""
    with jax.profiler.TraceAnnotation(name), jax.named_scope(name):
        yield
