"""Trace post-processing: Chrome-trace -> per-op-family PROFILE_*.json.

``jax.profiler`` writes gzipped Chrome-trace JSON under
``<dir>/plugins/profile/<timestamp>/<host>.trace.json.gz``; everything here
parses that with the stdlib (gzip + json — no tensorboard/tensorflow
dependency) and rolls device time up into the op families the tuning work
cares about:

* **collective** — all-reduce/all-gather/… (the mesh tax; what serialized
  the 0.54x decode loop);
* **gemm** — dot/convolution (the roofline's compute term);
* **attention** — flash/softmax fusions;
* **host_transfer** — device<->host copies; their *count* is the
  ``host_syncs`` metric (the fused decode loop's "one device_get per wave"
  invariant made measurable);
* **other** — everything else (elementwise fusions, dynamic-slice, …).

Only events carrying an ``args.hlo_op`` enter the op universe — that is how
XLA device ops are distinguished from python-tracer/runtime scaffolding —
and container ops (``while``/``call``/…, whose duration covers the leaf ops
they re-dispatch) are dropped so nothing is double-counted.
``annotate(...)`` markers (``serve.*``/``train.*``) are collected
separately: they partition *wall* time where the families partition
*device* time.
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import re
from typing import Dict, List, Optional, Sequence

#: op families of the breakdown, in render order
FAMILIES = ("collective", "gemm", "attention", "host_transfer", "other")

#: bump on any incompatible PROFILE_*.json layout change
PROFILE_SCHEMA_VERSION = 1

# Container/control HLO ops re-dispatch their body ops: their duration is
# the sum of leaves already counted, so they are excluded from the universe.
_CONTAINER_RE = re.compile(
    r"^(while|call|conditional|tuple|get-tuple-element|parameter|constant)"
    r"(\.|$)")
_COLLECTIVE_RE = re.compile(
    r"all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast")
_GEMM_RE = re.compile(r"^(dot|convolution|gemm|cublas|custom-call.*gemm)")
_ATTENTION_RE = re.compile(
    r"flash|attention|softmax|exponential|reduce-window|scaled")
# Host<->device copies show up as runtime events (no hlo_op): the blocking
# np.asarray(jax.Array) fetch of jax.device_get, plus explicit transfers.
_TRANSFER_RE = re.compile(
    r"np\.asarray\(jax\.Array\)|TransferTo|TransferFrom|device_get|"
    r"copy_to_host|BufferToHost")
#: markers produced by repro.profiling.annotate in the serve/train paths
_ANNOTATION_RE = re.compile(r"^(serve|train)\.[\w.]+$")


def classify_event_name(name: str) -> str:
    """Family of one HLO-op name (``host_transfer`` never comes from here —
    transfers are runtime events without an ``hlo_op``)."""
    low = name.lower()
    if _COLLECTIVE_RE.search(low):
        return "collective"
    if _GEMM_RE.search(low):
        return "gemm"
    if _ATTENTION_RE.search(low):
        return "attention"
    return "other"


def _base_op(name: str) -> str:
    """``all-reduce.7`` -> ``all-reduce`` (aggregate over SSA numbering)."""
    return re.sub(r"\.\d+$", "", name)


def find_capture_dirs(trace_dir: str) -> List[str]:
    """Capture directories under a trace dir, newest first."""
    pattern = os.path.join(trace_dir, "plugins", "profile", "*")
    dirs = [d for d in glob.glob(pattern) if os.path.isdir(d)]
    return sorted(dirs, key=os.path.getmtime, reverse=True)


def load_trace_events(trace_dir: str, capture: str = "latest") -> List[dict]:
    """Parse the Chrome-trace events of one capture (default: the newest).

    Raises ``FileNotFoundError`` when the directory holds no capture —
    the CI leg's "the profiler actually ran" check.
    """
    captures = find_capture_dirs(trace_dir)
    if not captures:
        raise FileNotFoundError(
            f"no profiler capture under {trace_dir!r} "
            "(expected plugins/profile/<timestamp>/*.trace.json.gz)")
    chosen = captures[0] if capture == "latest" else capture
    events: List[dict] = []
    for path in sorted(glob.glob(os.path.join(chosen, "*.trace.json.gz"))):
        with gzip.open(path, "rt") as f:
            events.extend(json.load(f).get("traceEvents", []))
    return events


def summarize_events(events: Sequence[dict]) -> Dict[str, object]:
    """Roll Chrome-trace events up into the breakdown core.

    Returns ``totals`` (op/wall microseconds), per-family us/count/fraction,
    ``top_ops`` (by device time, SSA numbering folded), ``annotations``
    (the ``serve.*``/``train.*`` markers), and ``host_syncs``.
    """
    fam_us = {f: 0.0 for f in FAMILIES}
    fam_n = {f: 0 for f in FAMILIES}
    op_us: Dict[str, float] = {}
    op_n: Dict[str, int] = {}
    ann_us: Dict[str, float] = {}
    ann_n: Dict[str, int] = {}
    t_min, t_max = None, None
    for ev in events:
        if ev.get("ph") != "X":
            continue
        dur = float(ev.get("dur", 0.0))
        name = ev.get("name", "")
        ts = ev.get("ts")
        if ts is not None:
            t_min = ts if t_min is None else min(t_min, ts)
            t_max = ts + dur if t_max is None else max(t_max, ts + dur)
        hlo = (ev.get("args") or {}).get("hlo_op")
        if hlo:
            if _CONTAINER_RE.match(hlo):
                continue
            fam = classify_event_name(hlo)
            fam_us[fam] += dur
            fam_n[fam] += 1
            base = _base_op(hlo)
            op_us[base] = op_us.get(base, 0.0) + dur
            op_n[base] = op_n.get(base, 0) + 1
        elif _ANNOTATION_RE.match(name):
            ann_us[name] = ann_us.get(name, 0.0) + dur
            ann_n[name] = ann_n.get(name, 0) + 1
        elif _TRANSFER_RE.search(name):
            fam_us["host_transfer"] += dur
            fam_n["host_transfer"] += 1
    total_us = sum(fam_us.values())
    families = {
        f: {"us": round(fam_us[f], 3), "count": fam_n[f],
            "fraction": round(fam_us[f] / total_us, 6) if total_us else 0.0}
        for f in FAMILIES}
    top = sorted(op_us, key=op_us.get, reverse=True)[:12]
    return {
        "totals": {
            "op_us": round(sum(fam_us[f] for f in FAMILIES
                               if f != "host_transfer"), 3),
            "family_us": round(total_us, 3),
            "wall_us": round((t_max - t_min), 3) if t_min is not None else 0.0,
        },
        "families": families,
        "top_ops": [{"name": o, "us": round(op_us[o], 3), "count": op_n[o]}
                    for o in top],
        "annotations": {a: {"us": round(ann_us[a], 3), "count": ann_n[a]}
                        for a in sorted(ann_us)},
        "host_syncs": fam_n["host_transfer"],
    }


def build_profile(kind: str, *,
                  trace_dir: Optional[str] = None,
                  events: Optional[Sequence[dict]] = None,
                  hardware: Optional[str] = None,
                  mesh: Optional[str] = None,
                  roofline: Optional[dict] = None,
                  extra: Optional[dict] = None) -> Dict[str, object]:
    """Assemble the ``PROFILE_*.json`` blob from a trace dir or raw events."""
    if events is None:
        if trace_dir is None:
            raise ValueError("build_profile needs trace_dir or events")
        events = load_trace_events(trace_dir)
    blob: Dict[str, object] = {
        "schema_version": PROFILE_SCHEMA_VERSION,
        "kind": kind,
        "hardware": hardware,
        "mesh": mesh,
    }
    blob.update(summarize_events(events))
    if roofline is not None:
        blob["roofline"] = roofline
    if extra:
        blob.update(extra)
    return blob


def validate_profile(blob: dict) -> dict:
    """Schema check for PROFILE_*.json (the CI profiling leg's assertion).

    Raises ``ValueError`` listing every violation; returns the blob so the
    call nests in expressions.  "Valid" = versioned, kind-tagged, all op
    families present with consistent numbers, and *nonzero* totals — a
    trace that captured nothing fails here rather than greening CI.
    """
    problems: List[str] = []
    if blob.get("schema_version") != PROFILE_SCHEMA_VERSION:
        problems.append(
            f"schema_version {blob.get('schema_version')!r} != "
            f"{PROFILE_SCHEMA_VERSION}")
    if not isinstance(blob.get("kind"), str) or not blob.get("kind"):
        problems.append("missing kind")
    fams = blob.get("families")
    if not isinstance(fams, dict):
        problems.append("missing families")
    else:
        for f in FAMILIES:
            entry = fams.get(f)
            if not isinstance(entry, dict):
                problems.append(f"families[{f!r}] missing")
                continue
            for field in ("us", "count", "fraction"):
                v = entry.get(field)
                if not isinstance(v, (int, float)) or v < 0:
                    problems.append(f"families[{f!r}].{field} bad: {v!r}")
    totals = blob.get("totals")
    if not isinstance(totals, dict):
        problems.append("missing totals")
    else:
        for field in ("op_us", "wall_us"):
            v = totals.get(field)
            if not isinstance(v, (int, float)):
                problems.append(f"totals.{field} bad: {v!r}")
            elif v <= 0:
                problems.append(f"totals.{field} must be > 0, got {v!r}")
    hs = blob.get("host_syncs")
    if not isinstance(hs, int) or hs < 0:
        problems.append(f"host_syncs bad: {hs!r}")
    if not isinstance(blob.get("annotations"), dict):
        problems.append("missing annotations")
    if not isinstance(blob.get("top_ops"), list):
        problems.append("missing top_ops")
    if problems:
        raise ValueError("invalid PROFILE blob: " + "; ".join(problems))
    return blob
