"""Profiling subsystem: capture -> per-op-family breakdown -> PROFILE_*.json.

The paper tunes by *measuring* each architecture; this package is the
measurement half for the jax port.  Three layers:

* :mod:`repro.profiling.tracer` — ``trace(...)`` (a ``jax.profiler`` trace
  scoped to a context manager, strict no-op when disabled) and
  ``annotate(...)`` (named markers the serve engine / trainer thread through
  their waves, visible in both the trace timeline and the HLO metadata);
* :mod:`repro.profiling.breakdown` — a stdlib-only Chrome-trace
  post-processor classifying device time into op families (collective vs
  GEMM vs attention vs host transfer) and counting host syncs, emitting the
  versioned ``PROFILE_*.json`` schema CI validates;
* ``scripts/profile.py`` — the CLI rendering a breakdown next to the
  roofline model (where the time goes vs where it could go).
"""
from repro.profiling.breakdown import (FAMILIES, PROFILE_SCHEMA_VERSION,
                                       build_profile, classify_event_name,
                                       load_trace_events, summarize_events,
                                       validate_profile)
from repro.profiling.tracer import TraceSession, annotate, trace

__all__ = [
    "trace", "annotate", "TraceSession",
    "load_trace_events", "summarize_events", "build_profile",
    "validate_profile", "classify_event_name",
    "FAMILIES", "PROFILE_SCHEMA_VERSION",
]
