"""Architecture catalog: --arch <id> resolves here."""
from repro.configs.base import ModelConfig

from repro.configs.llama_3_2_vision_11b import CONFIG as _vlm
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.llama3_2_1b import CONFIG as _llama1b
from repro.configs.chatglm3_6b import CONFIG as _chatglm
from repro.configs.stablelm_12b import CONFIG as _stablelm
from repro.configs.yi_9b import CONFIG as _yi
from repro.configs.mamba2_130m import CONFIG as _mamba
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.zamba2_2_7b import CONFIG as _zamba

ARCHITECTURES = {c.name: c for c in (
    _vlm, _olmoe, _moonshot, _llama1b, _chatglm, _stablelm, _yi,
    _mamba, _whisper, _zamba,
)}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHITECTURES[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHITECTURES)}")
