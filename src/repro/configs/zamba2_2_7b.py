"""zamba2-2.7b [hybrid] — 54 Mamba2 layers with ONE shared-weight attention
block applied every 6 layers (9 applications, distinct KV each).
[arXiv:2411.15242]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000, rope_theta=10000.0,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
    attn_period=6,
)
