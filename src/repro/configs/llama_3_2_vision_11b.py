"""llama-3.2-vision-11b [vlm] — 40L transformer backbone with cross-attention
image layers every 5th layer; vision frontend is a STUB (precomputed patch
embeddings via input_specs).  [hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0,
    cross_attn_period=5, num_image_tokens=1601,
)
