"""Model & shape configuration dataclasses.

One ``ModelConfig`` instance per assigned architecture lives in
``configs/<arch>.py``; ``reduced()`` derives the CPU smoke-test variant
(same family/topology, tiny dims) as required by the task spec.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    vocab_size: int
    num_heads: int = 0              # 0 => attention-free
    num_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 0               # 0 -> d_model // num_heads
    # norm / positions
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    use_rope: bool = True
    rope_theta: float = 500000.0
    rope_fraction: float = 1.0      # chatglm applies RoPE to half the head dim
    learned_positions: int = 0      # >0 => learned pos-emb table (whisper dec)
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    # hybrid (Zamba2): one SHARED attention block applied every N ssm layers
    attn_period: int = 0
    # VLM: layer unit = (cross_attn_period - 1) self layers + 1 cross layer
    cross_attn_period: int = 0
    num_image_tokens: int = 0
    # enc-dec (Whisper): encoder stack + frontend stub length
    encoder_layers: int = 0
    encoder_len: int = 0
    # numerics / memory
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    remat: bool = True
    remat_policy: str = "full"      # full | dots (save matmul outputs in bwd)
    logit_chunk: int = 0            # >0 => chunked loss over tokens
    attn_p_dtype: str = "float32"   # attention probabilities for the PV matmul
                                    # ("bfloat16" halves the dominant f32 buffer)
    attention_impl: str = "chunked"  # chunked (jnp) | flash (tuned Pallas
                                     # kernel for causal self-attention with
                                     # >1 query: training forwards AND serving
                                     # prefill, ragged rows included; decode/
                                     # cross-attn fall back, logged once)
    kv_quant: bool = False           # int8 KV cache (per-token-head scales):
                                     # halves the decode memory term

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence handling => may run long_500k."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are (or contain) decoders

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-topology variant for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            d_model=64,
            vocab_size=256,
            d_ff=128 if self.d_ff else 0,
            head_dim=16 if self.num_heads else 0,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            rope_theta=10000.0,
            dtype="float32",
            remat=False,
        )
        if self.family == "vlm":
            kw.update(num_layers=2 * self.cross_attn_period,
                      num_image_tokens=8)
        elif self.family == "hybrid":
            kw.update(num_layers=2 * self.attn_period)
        elif self.family == "audio":
            kw.update(num_layers=2, encoder_layers=2, encoder_len=16,
                      learned_positions=128 if self.learned_positions else 0)
        else:
            kw.update(num_layers=2)
        if self.num_experts:
            kw.update(num_experts=8, experts_per_token=2)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeSpec, ...]:
    """All four cells; long_500k only for sub-quadratic families
    (skip recorded by the dry-run driver, per DESIGN.md §4)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return tuple(out)
