"""stablelm-12b [dense] — 40L GQA kv=8, LayerNorm, partial RoPE (25%).
[hf:stabilityai/stablelm-2-12b]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=13824, vocab_size=100352, rope_theta=10000.0, rope_fraction=0.25,
    norm="layernorm",
)
