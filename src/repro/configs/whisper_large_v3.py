"""whisper-large-v3 [audio] — enc-dec, 32 encoder + 32 decoder layers, MHA.
Conv/mel frontend is a STUB (input_specs supplies (B, 1500, 1280) frame
embeddings).  Learned decoder positions extended to cover assigned shapes.
[arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, encoder_layers=32, encoder_len=1500,
    d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866, norm="layernorm",
    use_rope=False, learned_positions=32768,
)
