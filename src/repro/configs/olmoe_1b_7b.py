"""olmoe-1b-7b [moe] — 16L, 64 experts top-8, d_ff=1024/expert.
[arXiv:2409.02060]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304, rope_theta=10000.0,
    num_experts=64, experts_per_token=8,
)
