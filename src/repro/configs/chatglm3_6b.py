"""chatglm3-6b [dense] — 28L, GQA kv=2, RoPE on half the head dim ("2d RoPE"),
QKV bias.  [arXiv:2406.12793]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024, rope_theta=10000.0, rope_fraction=0.5,
)
