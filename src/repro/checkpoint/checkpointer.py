"""Sharded checkpointing with elastic restore.

Format: one directory per step containing
  * ``manifest.json`` — pytree structure, per-leaf shape/dtype, step, and a
    content checksum per leaf (corruption detection on restore);
  * one ``.npy`` per leaf (host-local full value on this single-host
    container; on a real multi-host cluster each host writes its local
    shards via the same interface — the manifest records the global shape
    either way).

Elastic restore: ``restore(..., shardings=...)`` re-shards every leaf to the
target mesh at load time (``jax.device_put`` with the new NamedSharding), so
a job restarted on a different mesh shape (e.g. after losing a pod) resumes
from the same global state — the elastic-scaling path required at 1000+
nodes.  An atomic rename makes partially-written checkpoints invisible.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "__".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any) -> str:
        flat, _ = _flatten_with_paths(state)
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for name, leaf in flat:
            arr = np.asarray(jax.device_get(leaf))
            logical_dtype = str(arr.dtype)
            # numpy can't serialize ml_dtypes (bf16 etc.): store a uint view
            if arr.dtype.kind == "V" or logical_dtype not in np.sctypeDict:
                arr = arr.view({1: np.uint8, 2: np.uint16,
                                4: np.uint32}[arr.dtype.itemsize])
            np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest["leaves"][name] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "logical_dtype": logical_dtype,
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)      # atomic publish
        self._gc()
        return final

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.directory)
                 if d.startswith("step_") and not d.endswith(".tmp")]
        return max(steps) if steps else None

    def restore(self, step: int, target: Any, shardings: Any = None) -> Any:
        """``target`` supplies the pytree structure (abstract or concrete).
        ``shardings``: optional matching pytree of NamedSharding for elastic
        re-sharding onto the current mesh."""
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat, treedef = _flatten_with_paths(target)
        shard_flat = None
        if shardings is not None:
            shard_flat = [s for _, s in _flatten_with_paths(shardings)[0]]
        leaves = []
        for i, (name, leaf) in enumerate(flat):
            arr = np.load(os.path.join(path, name + ".npy"))
            meta = manifest["leaves"][name]
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption in leaf {name!r}")
            logical = meta.get("logical_dtype", str(arr.dtype))
            if logical != str(arr.dtype):  # stored as uint view of bf16 etc.
                arr = arr.view(jnp.dtype(logical))
            want_dtype = getattr(leaf, "dtype", arr.dtype)
            out = jnp.asarray(arr, dtype=want_dtype)
            if shard_flat is not None:
                out = jax.device_put(out, shard_flat[i])
            leaves.append(out)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # ------------------------------------------------------------------
    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
