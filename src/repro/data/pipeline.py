"""Deterministic token data pipeline.

Design constraints for 1000+-node fault tolerance:
  * every batch is a pure function of (seed, step) — restart at step k
    replays the exact token stream with no data-loader state to checkpoint;
  * per-host sharding: each host materializes only its slice of the global
    batch (here: single-host container, the slice is the whole batch);
  * two sources: synthetic (markov-ish structured stream so loss can
    actually decrease) and file-backed (memory-mapped token binary).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"        # synthetic | file
    path: Optional[str] = None


class TokenPipeline:
    """batch(step) -> {"tokens": (B, S) int32, "labels": (B, S) int32}."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.source == "file":
            if not cfg.path:
                raise ValueError("file source needs a path")
            self._data = np.memmap(cfg.path, dtype=np.int32, mode="r")
        else:
            self._data = None
        # Fixed structured transition table for the synthetic stream:
        # tokens follow t' = (a*t + b + noise) mod V with a few modes, which
        # a model can learn (loss decreases) yet is stateless to generate.
        rng = np.random.default_rng(cfg.seed)
        self._a = np.ones(8, np.int64)                      # t' = t + b + noise
        self._b = rng.integers(1, 9, size=8).astype(np.int64)

    def batch(self, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        if self._data is not None:
            return self._file_batch(step)
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        mode = rng.integers(0, 8, size=(b, 1))
        start = rng.integers(0, v, size=(b, 1)).astype(np.int64)
        toks = np.empty((b, s + 1), np.int64)
        toks[:, :1] = start
        a = self._a[mode]
        bb = self._b[mode]
        noise = rng.integers(0, 3, size=(b, s))
        for i in range(s):
            toks[:, i + 1] = (a[:, 0] * toks[:, i] + bb[:, 0] + noise[:, i]) % v
        return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                "labels": jnp.asarray(toks[:, 1:], jnp.int32)}

    def _file_batch(self, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        b, s = cfg.global_batch, cfg.seq_len
        need = b * (s + 1)
        total = len(self._data) - need - 1
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        offs = rng.integers(0, max(total, 1), size=b)
        rows = np.stack([np.asarray(self._data[o:o + s + 1]) for o in offs])
        rows = rows % cfg.vocab_size
        return {"tokens": jnp.asarray(rows[:, :-1], jnp.int32),
                "labels": jnp.asarray(rows[:, 1:], jnp.int32)}

    def __call__(self, step: int):
        return self.batch(step)

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, jax.Array]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1
