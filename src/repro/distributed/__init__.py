from repro.distributed.ctx import (  # noqa: F401
    constrain, current_mesh, current_rules, use_mesh,
)
from repro.distributed.sharding import (  # noqa: F401
    ShardingRules, batch_shardings, cache_shardings, local_gemm_divisors,
    param_shardings, param_specs, rules_for_mesh, shard_params,
    sharding_summary,
)
