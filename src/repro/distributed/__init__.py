from repro.distributed.sharding import (  # noqa: F401
    ShardingRules, batch_shardings, cache_shardings, param_shardings,
    param_specs, rules_for_mesh,
)
