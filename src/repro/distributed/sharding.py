"""Logical-axis -> mesh-axis sharding rules (DP/FSDP/TP/EP/SP).

Parameters carry logical axis names in their ``ParamSpec`` (models/params.py);
this module maps them to ``PartitionSpec`` for a given mesh.  The mapping is
the framework-level counterpart of the paper's per-architecture tuning table:
a small set of knobs, applied outside the model code, adapts the same model
source to any mesh.

Rules of thumb implemented here:
  * "vocab" / "ff" / "expert"  -> "model"  (tensor / expert parallel)
  * "embed" (d_model dims)     -> "data"   (FSDP) when enabled
  * 1-D params (norm scales, biases) are replicated
  * a mesh axis is used at most once per spec (first dim wins)
  * dims not divisible by the axis size fall back to replicated
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import ParamSpec, is_spec


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """The tuning knobs of the distribution layer."""
    tensor_axis: Optional[str] = "model"     # TP/EP target axis
    fsdp_axis: Optional[str] = "data"        # weight-shard axis (None = pure DP)
    batch_axes: Tuple[str, ...] = ("data",)  # activation batch axes
    sequence_axis: Optional[str] = None      # SP: shard activation seq dim

    def logical_map(self):
        return {
            "vocab": self.tensor_axis,
            "ff": self.tensor_axis,
            "expert": self.tensor_axis,
            "embed": self.fsdp_axis,
            "layer": None,
            None: None,
        }


def rules_for_mesh(mesh: Mesh, *, fsdp: bool = True,
                   sequence_parallel: bool = False) -> ShardingRules:
    axes = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in axes) or (axes[0],)
    return ShardingRules(
        tensor_axis="model" if "model" in axes else None,
        fsdp_axis="data" if (fsdp and "data" in axes) else None,
        batch_axes=batch_axes,
        sequence_axis="model" if (sequence_parallel and "model" in axes) else None,
    )


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


#: public alias — consumers (serve engine telemetry) need the same
#: axis-or-axes size resolution the spec builders use
axis_size = _axis_size


def spec_for_param(mesh: Mesh, rules: ShardingRules, spec: ParamSpec) -> P:
    if len(spec.shape) <= 1:
        return P()
    mapping = rules.logical_map()
    used = set()
    out = []
    for dim, axis_name in zip(spec.shape, spec.axes):
        mesh_axis = mapping.get(axis_name)
        if (mesh_axis is None or mesh_axis in used
                or dim % _axis_size(mesh, mesh_axis) != 0):
            out.append(None)
        else:
            out.append(mesh_axis)
            used.add(mesh_axis)
    return P(*out)


def param_specs(mesh: Mesh, rules: ShardingRules, template):
    return jax.tree_util.tree_map(
        lambda s: spec_for_param(mesh, rules, s), template, is_leaf=is_spec)


def param_shardings(mesh: Mesh, rules: ShardingRules, template):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, spec_for_param(mesh, rules, s)),
        template, is_leaf=is_spec)


def shard_params(params, mesh: Mesh, rules: ShardingRules, template):
    """Place an (already materialized) param pytree by the rules.

    ``jax.device_put`` reshards committed arrays in place, so this works both
    for fresh ``init_params`` output and for checkpoint-restored params.
    """
    return jax.device_put(params, param_shardings(mesh, rules, template))


def sharding_summary(mesh: Mesh, rules: ShardingRules, template) -> dict:
    """JSON-friendly provenance: how many param leaves each spec shape got.

    e.g. ``{"('data', 'model')": 9, "()": 14}`` — surfaced by
    ``Engine.stats()["sharding"]`` next to the rules' axis mapping.
    """
    counts: dict = {}
    for spec in jax.tree_util.tree_leaves(template, is_leaf=is_spec):
        key = str(tuple(spec_for_param(mesh, rules, spec)))
        counts[key] = counts.get(key, 0) + 1
    return counts


def local_gemm_divisors(mesh: Mesh, rules: ShardingRules, template):
    """``{(k, n): ((div_k, div_n), ...)}`` over the template's matmul weights.

    A GEMM traced with *global* operand shapes runs per shard on the
    *local* shapes ``(m/div_m, k/div_k, n/div_n)`` — under TP the tuned-tile
    entry that actually matters is the local one.  The last two dims of each
    >=2-D param are the ``(K, N)`` the single matmul entry point sees (scanned
    stacks index their leading layer axis away), and the divisor of a dim is
    the size of the mesh axes its spec shards it over.

    Two weights can share a global ``(K, N)`` but shard it differently —
    e.g. square attention projections, where ``wq`` is ``(embed, ff)`` but
    ``wo`` is ``(ff, embed)`` — so every *distinct* divisor pair is returned
    (sorted, deterministic) and consumers surface each local variant rather
    than silently picking whichever leaf the pytree happens to visit first.
    """
    out: dict = {}
    for spec in jax.tree_util.tree_leaves(template, is_leaf=is_spec):
        if len(spec.shape) < 2:
            continue
        sp = spec_for_param(mesh, rules, spec)
        padded = tuple(sp) + (None,) * (len(spec.shape) - len(tuple(sp)))
        k, n = spec.shape[-2], spec.shape[-1]
        dk = _axis_size(mesh, padded[len(spec.shape) - 2])
        dn = _axis_size(mesh, padded[len(spec.shape) - 1])
        out.setdefault((k, n), set()).add((dk, dn))
    return {key: tuple(sorted(vals)) for key, vals in out.items()}


# ---------------------------------------------------------------------------
# Activation / batch / cache specs
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, rules: ShardingRules, batch_size: int, rank: int) -> P:
    """Spec for a (B, ...) activation-like array."""
    ba = rules.batch_axes
    if batch_size % _axis_size(mesh, ba) == 0:
        return P(ba, *([None] * (rank - 1)))
    # try fewer axes (e.g. B=1 long-context: replicate batch dim)
    for sub in (ba[:1],):
        if batch_size % _axis_size(mesh, sub) == 0:
            return P(sub, *([None] * (rank - 1)))
    return P(*([None] * rank))


def batch_shardings(mesh: Mesh, rules: ShardingRules, batch_abstract):
    def leaf(x):
        return NamedSharding(mesh, batch_spec(mesh, rules, x.shape[0], x.ndim))
    return jax.tree_util.tree_map(leaf, batch_abstract)


def cache_spec(mesh: Mesh, rules: ShardingRules, shape: Tuple[int, ...],
               batch_dim: int, seq_dim: Optional[int] = None,
               head_dim: Optional[int] = None) -> P:
    """Spec for KV caches / recurrent states with a leading layer axis.

    Prefer sharding batch over the DP axes; if the batch dim is too small
    (long-context B=1), shard the sequence dim instead.  Heads go on the
    tensor axis when divisible.
    """
    out = [None] * len(shape)
    ba = rules.batch_axes
    if shape[batch_dim] % _axis_size(mesh, ba) == 0:
        out[batch_dim] = ba
    elif seq_dim is not None and shape[seq_dim] % _axis_size(mesh, ba) == 0:
        out[seq_dim] = ba
    ta = rules.tensor_axis
    if ta:
        if (head_dim is not None
                and shape[head_dim] % _axis_size(mesh, ta) == 0):
            out[head_dim] = ta
        elif (seq_dim is not None and out[seq_dim] is None
                and shape[seq_dim] % _axis_size(mesh, ta) == 0):
            # few KV heads (GQA kv < model axis): shard cache sequence on the
            # tensor axis instead — softmax/contractions over the sharded seq
            # lower to the standard partial-reduce + all-reduce pattern.
            out[seq_dim] = ta
    return P(*out)


def cache_shardings(mesh: Mesh, rules: ShardingRules, cache_abstract):
    """Heuristic spec derivation for the whole cache pytree.

    Leaves are one of:
      KV cache       (L..., B, S, KV, hd)   rank >= 5
      ssm state      (L..., B, H, N, P)     rank >= 5 (no seq dim)
      conv state     (L..., B, K-1, C)      rank >= 4
    We identify the batch dim as the first dim matching the cache batch size
    recorded by the caller via closure — instead we use the structure: leaves
    under key "self"/"cross" are KV; under "ssm" are states.
    """
    def walk(tree, kind=None):
        if isinstance(tree, dict):
            return {k: walk(v, {"self": "kv", "cross": "kv",
                                "ssm": "ssm", "conv": "conv",
                                "q": kind, "s": "kv_scale"}.get(k, kind))
                    for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            t = type(tree)
            return t(walk(v, kind) for v in tree)
        shape = tree.shape
        if kind == "kv_scale":
            # int8-quant scale slab (L..., B, S, KV): batch=-3, seq=-2, heads=-1
            sp = cache_spec(mesh, rules, shape, len(shape) - 3,
                            seq_dim=len(shape) - 2, head_dim=len(shape) - 1)
        elif kind == "kv":
            # (L..., B, S, KV, hd): batch = -4, seq = -3, heads = -2
            sp = cache_spec(mesh, rules, shape, len(shape) - 4,
                            seq_dim=len(shape) - 3, head_dim=len(shape) - 2)
        elif kind == "conv":
            # (L..., B, K-1, C): batch = -3, channels = -1
            sp = cache_spec(mesh, rules, shape, len(shape) - 3,
                            head_dim=len(shape) - 1)
        else:
            # ssm state (L..., B, H, N, P): batch = -4, heads = -3
            sp = cache_spec(mesh, rules, shape, len(shape) - 4,
                            head_dim=len(shape) - 3)
        return NamedSharding(mesh, sp)

    return walk(cache_abstract)
