"""Ambient activation-sharding policy.

GSPMD propagation alone can pick pathological layouts deep inside a scanned
step (verified: it replicated the batch dim of attention scores and ran the
full-vocab unembed per device).  The fix, as in MaxText-class frameworks, is
explicit ``with_sharding_constraint`` pins on the residual stream and logits.

Model code stays mesh-agnostic: it calls ``constrain(x, kind)``; the policy
(mesh + rules) is installed by the launcher/trainer around tracing, and the
call is a no-op when no policy is installed (single-device tests).

This module is also the *topology layer*: :func:`use_mesh` installs an
ambient ``(mesh, rules)`` pair that mesh-aware consumers (``serve.Engine``,
``Model.init``, launchers) pick up via :func:`current_mesh` /
:func:`current_rules` when they are not handed one explicitly — the
distribution-layer analogue of ``execution_context(hardware=...)``.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_TLS = threading.local()


class ActivationPolicy:
    def __init__(self, mesh: Mesh, rules):
        self.mesh = mesh
        self.rules = rules

    def spec_for(self, kind: str, rank: int, batch_size: int) -> Optional[P]:
        r = self.rules
        ba = r.batch_axes
        # batch shardable?
        size = 1
        for a in (ba if isinstance(ba, tuple) else (ba,)):
            size *= self.mesh.shape[a]
        bspec = ba if batch_size % size == 0 else None
        seq = r.sequence_axis
        if kind == "hidden":        # (B, S, D)
            return P(bspec, seq, None)
        if kind == "tokens":        # (B, S)
            return P(bspec, seq)
        if kind == "logits":        # (B, S, V) or (B, V)
            ta = r.tensor_axis
            if rank == 3:
                # under SP the tensor axis is on the sequence dim already
                return P(bspec, seq, None if seq == ta else ta)
            return P(bspec, ta)
        if kind == "batch_only":    # (B, ...)
            return P(*([bspec] + [None] * (rank - 1)))
        if kind == "moe_dispatch":  # (B, E, C, D): experts on the tensor axis
            # Pinning the expert dim forces the B-shard -> E-shard transition
            # to lower as all-to-all instead of a full all-gather.
            return P(bspec, r.tensor_axis, None, None)
        return None


def set_policy(policy: Optional[ActivationPolicy]):
    _TLS.policy = policy


def get_policy() -> Optional[ActivationPolicy]:
    return getattr(_TLS, "policy", None)


@contextlib.contextmanager
def activation_policy(mesh: Mesh, rules):
    old = get_policy()
    set_policy(ActivationPolicy(mesh, rules))
    try:
        yield
    finally:
        set_policy(old)


# ---------------------------------------------------------------------------
# Ambient mesh topology (the --mesh knob, as a context)
# ---------------------------------------------------------------------------

def set_mesh(mesh: Optional[Mesh], rules=None):
    _TLS.mesh = mesh
    _TLS.rules = rules


def current_mesh() -> Optional[Mesh]:
    """The ambient mesh installed by :func:`use_mesh` (None = single device)."""
    return getattr(_TLS, "mesh", None)


def current_rules():
    """The ambient :class:`ShardingRules` installed by :func:`use_mesh`."""
    return getattr(_TLS, "rules", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules=None):
    """Install ``(mesh, rules)`` as the ambient topology.

    Derives ``rules`` via ``rules_for_mesh`` when omitted, and installs the
    matching activation policy so every ``constrain`` call inside the scope
    pins to this mesh.  ``use_mesh(None)`` *clears* the ambient topology for
    the scope — inside an outer ``use_mesh(mesh)`` it restores single-device
    behavior (e.g. to build an unsharded reference engine for parity checks).
    """
    if mesh is None:
        old = (current_mesh(), current_rules())
        old_policy = get_policy()
        set_mesh(None, None)
        set_policy(None)
        try:
            yield None
        finally:
            set_mesh(*old)
            set_policy(old_policy)
        return
    if rules is None:
        from repro.distributed.sharding import rules_for_mesh
        rules = rules_for_mesh(mesh)
    old = (current_mesh(), current_rules())
    set_mesh(mesh, rules)
    try:
        with activation_policy(mesh, rules):
            yield rules
    finally:
        set_mesh(*old)


def constrain(x: jax.Array, kind: str) -> jax.Array:
    """Pin ``x`` to the policy's layout; identity when no policy installed."""
    pol = get_policy()
    if pol is None:
        return x
    spec = pol.spec_for(kind, x.ndim, x.shape[0])
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(pol.mesh, spec))
