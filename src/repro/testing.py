"""Property-testing compatibility layer: real ``hypothesis`` when installed,
otherwise a deterministic miniature fallback.

The test suite's property tests only need ``@given``/``@settings`` plus the
``integers`` and ``sampled_from`` strategies.  Environments built from
``pip install -e .[dev]`` get the real library (declared in pyproject.toml);
hermetic containers without it still collect and run every test — each
``@given`` test executes ``max_examples`` deterministic pseudo-random draws
from a seed derived from the test name, so failures reproduce exactly.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A sampleable value source (subset of hypothesis' SearchStrategy)."""

        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rng: elems[rng.randrange(len(elems))])

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    strategies = _Strategies()

    def settings(max_examples: int = 10, **_ignored):
        """Record ``max_examples`` on the (already @given-wrapped) test."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 10)
                seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
                rng = random.Random(seed)
                for _ in range(n):
                    draw = {k: s.example_from(rng) for k, s in strats.items()}
                    fn(*args, **kwargs, **draw)

            # hide the strategy parameters from pytest's fixture resolution
            # (real hypothesis does the same): present a zero-arg signature.
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
