"""Static invariant checking for the single-source, many-target thesis.

The paper's portability claim only holds while the code keeps its
invariants — no hidden host round-trips inside traced regions, tile
configs legal for every profile they're committed for, every param leaf
covered by a sharding rule.  This package checks those *statically*
(stdlib ``ast`` + artifact re-validation, no accelerator needed) so CI
catches rot before a benchmark has to:

* :mod:`~repro.analysis.callgraph` — module index + the traced-region
  call graph (what is reachable from ``jax.jit``/``pallas_call``/
  ``lax.*`` bodies);
* :mod:`~repro.analysis.purity`   — TP00x trace-purity lint over that
  graph (host syncs, coercions, traced control flow, nondeterminism,
  missing ``profiling.annotate`` scopes);
* :mod:`~repro.analysis.artifacts` — AR00x/BA00x validation of
  ``tuned/*.json`` against their ``HardwareProfile`` and of
  ``benchmarks/baselines/BENCH_*.json`` schemas;
* :mod:`~repro.analysis.coverage` — SH00x sharding-rule coverage of all
  model families' abstract param trees;
* :mod:`~repro.analysis.findings` — the :class:`Finding` record and the
  committed-baseline ratchet (``tests/analysis_baseline.json``);
* :mod:`~repro.analysis.pragmas`  — the ``# analysis: allow`` waiver
  ledger and the PR900 unused-pragma check;
* :mod:`~repro.analysis.ir`      — IR-level contracts (IR000-IR005): the
  config matrix is dry-traced (``jit(...).lower()``, no execution) and
  the lowered jaxpr/HLO checked for collective placement, numerics,
  memory budget, jit-key fan-out, and program-fingerprint drift.

Entry point: :mod:`repro.analysis.cli` — ``python -m repro.analysis``,
``scripts/analyze.py`` (shim), or the ``repro-analyze`` console script
(``lint | artifacts | coverage | stats | ir | pragmas | report``);
catalog and workflow: ``docs/STATIC_ANALYSIS.md``.
"""
from repro.analysis.findings import (BASELINE_SCHEMA_VERSION, Finding,
                                     SEV_ERROR, SEV_WARNING,
                                     default_baseline_path, load_baseline,
                                     ratchet, save_baseline, sort_findings)

__all__ = [
    "BASELINE_SCHEMA_VERSION", "Finding", "SEV_ERROR", "SEV_WARNING",
    "default_baseline_path", "load_baseline", "ratchet", "save_baseline",
    "sort_findings",
]
