"""Sharding-coverage check: the SH00x family.

``distributed/sharding.py`` maps logical param axes (``vocab``, ``ff``,
``embed``, ...) to mesh axes.  Nothing guarantees every model family's
param tree speaks that vocabulary: a new module can introduce an axis name
the rules have never heard of, and ``spec_for_param`` will silently
replicate the leaf — correct but quietly unscaled, the exact failure PR 6's
profiling surfaced as all-gather storms.  This check instantiates each
model family's parameter tree **abstractly** (the spec-first ``template``
pytree — shapes and logical axes, no device arrays, the static counterpart
of ``jax.eval_shape``) and audits it against both rule sets the repo
serves with (FSDP for training, inference-TP for serving):

==========  =========  =====================================================
check id    severity   fires on
==========  =========  =====================================================
``SH001``   error      a leaf carrying a logical axis name absent from
                       ``ShardingRules.logical_map`` — no rule matches; the
                       leaf is silently replicated forever
``SH002``   warning    two dims of one leaf mapping to the same mesh axis —
                       first-dim-wins applies, the second dim is quietly
                       replicated (make the intent explicit in the spec)
``SH003``   warning    a dead rule: a logical axis the rule set maps to a
                       mesh axis that **no** leaf of any family uses
``SH004``   warning    a >=2-D leaf whose spec is fully replicated under
                       the rule mapping (every dim maps to None) — legal,
                       but worth knowing when it is a large matrix
==========  =========  =====================================================

The audit runs on a size-1 stub mesh so divisibility never masks a mapping
question: what is checked is the *rule coverage*, not a particular
topology's divisor accidents.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.analysis.findings import Finding, SEV_ERROR, SEV_WARNING

SLUGS = {
    "SH001": "unmatched-leaf",
    "SH002": "multi-dim-same-axis",
    "SH003": "dead-rule",
    "SH004": "replicated-matrix",
}

#: one representative per model family (dense / ssm / moe / vlm / audio) —
#: the same five the engine mesh-parity tests serve
COVERAGE_FAMILIES = ("llama3.2-1b", "mamba2-130m", "olmoe-1b-7b",
                    "llama-3.2-vision-11b", "whisper-large-v3")

#: the two rule sets the repo actually runs: FSDP training, inference TP
RULE_SET_KINDS = ("fsdp", "inference-tp")

_PATH = "src/repro/distributed/sharding.py"


class _StubMesh:
    """Duck-typed mesh: rules_for_mesh/_axis_size read only axis_names and
    shape.  Size-1 axes make every dim divisible, so the audit sees the
    pure rule mapping rather than one topology's divisor accidents."""
    axis_names = ("data", "model")
    shape = {"data": 1, "model": 1}


def _rule_sets():
    from repro.distributed.sharding import rules_for_mesh
    mesh = _StubMesh()
    return {"fsdp": rules_for_mesh(mesh, fsdp=True),
            "inference-tp": rules_for_mesh(mesh, fsdp=False)}


def _leaf_items(family: str) -> List[Tuple[str, object]]:
    """(path, ParamSpec) pairs of one family's abstract param template."""
    import jax

    from repro.configs.catalog import ARCHITECTURES
    from repro.models import build_model
    from repro.models.params import is_spec

    cfg = ARCHITECTURES[family].reduced()
    template = build_model(cfg).template
    flat = jax.tree_util.tree_flatten_with_path(template, is_leaf=is_spec)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def check_coverage(families=COVERAGE_FAMILIES) -> List[Finding]:
    findings: List[Finding] = []
    rule_sets = _rule_sets()
    # logical axes seen on any leaf of any family, per rule set relevance
    seen_axes: set = set()

    for family in families:
        for path, spec in _leaf_items(family):
            axes = tuple(spec.axes)
            seen_axes.update(a for a in axes if a is not None)
            for kind, rules in rule_sets.items():
                mapping = rules.logical_map()
                scope = f"{family}:{path}[{kind}]"
                unknown = sorted({a for a in axes
                                  if a is not None and a not in mapping})
                if unknown:
                    findings.append(Finding(
                        check_id="SH001", severity=SEV_ERROR, path=_PATH,
                        line=0, scope=scope,
                        message=(f"logical axes {unknown} match no rule in "
                                 f"ShardingRules.logical_map — leaf "
                                 f"{spec.shape} silently replicated")))
                    continue
                mapped = [mapping.get(a) for a in axes]
                hits = [m for m in mapped if m is not None]
                if len(hits) != len(set(hits)):
                    dup = sorted({m for m in hits if hits.count(m) > 1})
                    findings.append(Finding(
                        check_id="SH002", severity=SEV_WARNING, path=_PATH,
                        line=0, scope=scope,
                        message=(f"dims {axes} map {dup} twice — "
                                 f"first-dim-wins replicates the rest")))
                # a matrix is worth a warning only when >= 2 of its dims
                # carry real (non-layer-stacking) logical names and still
                # none of them sharded — a 'layer'-stacked norm scale or a
                # replicated position embedding is business as usual
                named = [a for a in axes if a not in (None, "layer")]
                if len(named) >= 2 and not hits:
                    findings.append(Finding(
                        check_id="SH004", severity=SEV_WARNING, path=_PATH,
                        line=0, scope=scope,
                        message=(f"{len(spec.shape)}-D leaf {spec.shape} "
                                 f"with axes {axes} is fully replicated "
                                 f"under the {kind} rules")))

    # dead rules: mapped logical axes no family's template ever mentions
    for kind, rules in rule_sets.items():
        mapping = rules.logical_map()
        for logical, mesh_axis in mapping.items():
            if logical is None or mesh_axis is None:
                continue
            if logical not in seen_axes:
                findings.append(Finding(
                    check_id="SH003", severity=SEV_WARNING, path=_PATH,
                    line=0, scope=f"{logical}[{kind}]",
                    message=(f"rule {logical!r} -> {mesh_axis!r} matches "
                             f"no param leaf of any model family — dead "
                             f"rule (or a family lost its axis names)")))
    return findings


def coverage_summary(families=COVERAGE_FAMILIES) -> Dict[str, dict]:
    """Per-family leaf/spec statistics for the CLI report."""
    rule_sets = _rule_sets()
    from repro.distributed.sharding import spec_for_param
    mesh = _StubMesh()
    out: Dict[str, dict] = {}
    for family in families:
        items = _leaf_items(family)
        per_kind = {}
        for kind, rules in rule_sets.items():
            sharded = sum(
                1 for _p, s in items
                if any(a is not None
                       for a in tuple(spec_for_param(mesh, rules, s)))
            )
            per_kind[kind] = {"leaves": len(items), "sharded": sharded}
        out[family] = per_kind
    return out
