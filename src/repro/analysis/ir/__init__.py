"""IR-level program contract checker: dry-trace the config matrix, lint
the lowered jaxpr/HLO.

The AST lint (:mod:`repro.analysis.purity`) sees source text; the PR 6
profiler sees one live run.  A whole regression class lives in between —
visible only in the *traced program*: a collective the partitioner placed
inside the fused decode loop, a silent dtype promotion, a bucket edit
that fans the jit cache out.  This package verifies those contracts on
the lowered IR itself via ``jit(...).lower()``/``.trace()`` — tracing and
XLA compilation only, **zero device execution** — for every (model family
x scheduler x mesh x dtype) cell the paper's thesis claims to ship.

Modules:

* :mod:`~repro.analysis.ir.matrix`       — the IRCase config matrix;
* :mod:`~repro.analysis.ir.trace`        — dry-lowering + the check-ready
  EntrySummary extraction + the ``.ir_cache/`` summary cache;
* :mod:`~repro.analysis.ir.checks`       — IR000 (trace failure), IR001
  (decode-loop collective placement), IR002 (numerics), IR003 (memory
  budget vs ``HardwareProfile.hbm_bytes``);
* :mod:`~repro.analysis.ir.recompile`    — IR004 static jit-key
  enumeration (the static twin of tests/test_recompile_count.py);
* :mod:`~repro.analysis.ir.fingerprints` — IR005 jaxpr fingerprints vs
  the committed ``tests/ir_fingerprints.json``;
* :mod:`~repro.analysis.ir.runner`       — orchestration -> (findings,
  IR_REPORT blob).

Entry point: ``scripts/analyze.py ir`` / ``python -m repro.analysis ir``;
catalog and re-bless workflow: docs/STATIC_ANALYSIS.md.
"""
from repro.analysis.ir.matrix import (DTYPES, FAMILIES, IRCase, SCHEDULERS,
                                      default_matrix, smoke_matrix)
from repro.analysis.ir.runner import run_ir

__all__ = ["DTYPES", "FAMILIES", "IRCase", "SCHEDULERS", "default_matrix",
           "run_ir", "smoke_matrix"]
