"""Orchestrate the IR pass: trace (or cache-load) every case, run IR000-
IR003, enumerate IR004 key counts, and diff IR004/IR005 against the
committed fingerprint file.  Returns ``(findings, report_blob)`` — the
findings feed the shared baseline ratchet exactly like the AST lint's,
and the blob is the ``IR_REPORT.json`` artifact the CI step summary
renders per-config tables from.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding, SEV_ERROR
from repro.analysis.ir import checks, fingerprints, recompile
from repro.analysis.ir.matrix import IRCase
from repro.analysis.ir.trace import (CaseResult, source_digest,
                                     traced_case_cached)


def run_ir(cases: Sequence[IRCase], *,
           use_cache: bool = True,
           cache_dir: Optional[str] = None,
           write_fingerprints: bool = False,
           fingerprint_path: Optional[str] = None,
           ) -> Tuple[List[Finding], dict]:
    import jax

    t0 = time.time()
    src_digest = source_digest()
    committed = fingerprints.load_fingerprints(fingerprint_path)
    jax_matches = committed.get("jax_version") == jax.__version__

    findings: List[Finding] = []
    rows: List[dict] = []
    records: Dict[str, dict] = {}
    for case in cases:
        result: CaseResult = traced_case_cached(
            case, cache_dir=cache_dir, src_digest=src_digest,
            use_cache=use_cache)
        case_findings = checks.check_case(result)
        unroll = recompile.resolve_static_unroll(case, result.hardware)
        jit_keys = recompile.enumerate_jit_keys(case, unroll)
        record = fingerprints.case_record(result, jit_keys)
        records[case.case_id] = record
        if not write_fingerprints:
            case_findings += fingerprints.compare_case(
                case.case_id, record, committed, jax_matches)
        findings += case_findings
        rows.append({
            "case": case.case_id,
            "entries": sorted(result.entries),
            "failed_entries": sorted(result.errors),
            "jit_keys": jit_keys,
            "peak_bytes": {e: checks.peak_bytes(s)
                           for e, s in sorted(result.entries.items())},
            "while_collectives": sum(len(s.while_collectives)
                                     for s in result.entries.values()),
            "errors": sum(1 for f in case_findings
                          if f.severity == SEV_ERROR),
            "warnings": sum(1 for f in case_findings
                            if f.severity != SEV_ERROR),
            "cached": result.cached,
            "seconds": result.seconds,
        })

    blessed_path = None
    if write_fingerprints:
        blessed_path = fingerprints.merge_fingerprints(
            records, jax.__version__, fingerprint_path)

    blob = {
        "ir_cases": rows,
        "jax_version": jax.__version__,
        "fingerprint_jax_version": committed.get("jax_version"),
        "hash_gate_active": jax_matches,
        "source_digest": src_digest[:16],
        "blessed_path": blessed_path,
        "seconds": round(time.time() - t0, 2),
    }
    return findings, blob
