"""IR004 — static enumeration of the jit cache keys a serve config implies.

``tests/test_recompile_count.py`` proves *dynamically* that observed
compile counts stay within the engine's bucket sets — but only for the
workloads the test happens to run.  This module derives the same bound
*statically*: it replays the engine's documented bucketing policy
(`serve.engine._bucket_len` and the width/plen resolution in
``_run_wave``/``_admit_some``/``_run_chunk``) over the **entire feasible
input domain** of a :class:`ServeConfig`, producing the exact set of
distinct jit cache keys each entry point can ever be called with.

The per-entry counts are pinned in ``tests/ir_fingerprints.json``; a
bucketing change (new bucket floor, changed clamp, a static arg leaking
into the key) shifts a count and fails IR004 with a diff naming the entry
point — a recompile storm caught before a single trace runs.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.analysis.ir.matrix import SERVE_KW, IRCase
from repro.serve.engine import _bucket_len


def wave_keys(max_len: int, unroll: int) -> Dict[str, List[Tuple]]:
    """Distinct jit keys of the wave engine's entry points.

    Feasible domain: ``1 <= longest``, ``1 <= need``,
    ``longest + need <= max_len`` (the ``_run_wave`` guard).  Batch rows
    are always padded to ``max_batch``, so only (plen, width, unroll) vary.
    """
    prefill: set = set()
    loop: set = set()
    for need in range(1, max_len):
        width = _bucket_len(need)
        loop.add((width, min(unroll, width)))
        for longest in range(1, max_len - need + 1):
            plen = _bucket_len(longest, max_len - width)
            if plen < longest:
                plen = _bucket_len(longest, max_len - need)
            if plen < longest:
                plen = longest
            prefill.add((plen,))
    return {"prefill": sorted(prefill), "decode_loop": sorted(loop)}


def continuous_keys(max_len: int, max_batch: int, chunk: int, unroll: int,
                    capacity_tokens: Optional[int] = None
                    ) -> Dict[str, List[Tuple]]:
    """Distinct jit keys of the continuous engine's entry points.

    Admission buckets the longest admitted prompt uncapped; a row's length
    (prompt + generated so far) is bounded by the pool capacity, and the
    chunk width buckets ``length + chunk``.  ``unroll`` is clamped to a
    divisor of ``chunk`` exactly as ``_run_chunk`` does.
    """
    capacity = capacity_tokens or max_batch * max_len
    admit = {(_bucket_len(p),) for p in range(1, capacity)}
    u = min(unroll, chunk)
    while chunk % u:
        u -= 1
    widths = {(_bucket_len(length + chunk), chunk, u)
              for length in range(1, capacity + 1)}
    return {"admit": sorted(admit), "decode_chunk": sorted(widths)}


def resolve_static_unroll(case: IRCase, hardware: str) -> int:
    """The unroll the engine would resolve for this case — same chain as
    ``Engine._resolve_unroll`` (tuned ``decode_loop`` entry keyed by mesh
    label, else the mesh heuristic), evaluated without building an engine."""
    from repro.core.registry import GLOBAL_REGISTRY, OP_DECODE_LOOP
    res = GLOBAL_REGISTRY.lookup_op(
        OP_DECODE_LOOP, hardware, case.dtype,
        (SERVE_KW["max_batch"], SERVE_KW["max_len"]),
        mesh=None if case.mesh_name == "single" else case.mesh_name)
    if res.source in ("exact", "nearest", "generic"):
        return max(int(res.config.unroll), 1)
    return 4 if case.mesh_spec else 1


def enumerate_jit_keys(case: IRCase, unroll: int,
                       max_batch: Optional[int] = None,
                       max_len: Optional[int] = None,
                       chunk: int = 8,
                       capacity_tokens: Optional[int] = None
                       ) -> Dict[str, int]:
    """-> ``{entry: distinct-key count, "total": sum}`` for one case,
    defaulting to the matrix's ``SERVE_KW`` serve shape."""
    max_batch = max_batch or SERVE_KW["max_batch"]
    max_len = max_len or SERVE_KW["max_len"]
    if case.scheduler == "wave":
        keys = wave_keys(max_len, unroll)
        # train_step lowers for exactly one (state, batch) spec per case
        keys["train_step"] = [("ir_train",)]
    else:
        keys = continuous_keys(max_len, max_batch, chunk, unroll,
                               capacity_tokens)
    counts = {entry: len(ks) for entry, ks in keys.items()}
    counts["total"] = sum(counts.values())
    return counts
