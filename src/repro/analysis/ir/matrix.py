"""The IR checker's configuration matrix.

The unit of verification is an :class:`IRCase` — one (model family x
scheduler x mesh spec x dtype) cell of the product the paper ships.  Every
cell names the serve/train entry points its scheduler actually jits
(``prefill`` + fused ``decode_loop`` + ``train_step`` for the wave engine;
``admit`` + fused ``decode_chunk`` for continuous batching), and the
tracer (:mod:`~repro.analysis.ir.trace`) dry-lowers exactly those.

This module is pure bookkeeping: importing it never touches jax device
state, so the CLI can enumerate/filter the matrix (``analyze.py ir
--families ...``) before deciding whether to pay for a trace.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

#: the five model families of the serve acceptance matrix
#: (tests/test_serve_engine.py FLASH_FAMILIES): dense, MoE, vision-language,
#: audio encoder-decoder, hybrid attention+SSM.
FAMILIES = ("llama3.2-1b", "olmoe-1b-7b", "llama-3.2-vision-11b",
            "whisper-large-v3", "zamba2-2.7b")

SCHEDULERS = ("wave", "continuous")
DTYPES = ("float32", "bfloat16")

#: jitted entry points per scheduler.  ``train_step`` rides with the wave
#: cases only — training has no scheduler axis, and duplicating it under
#: "continuous" would double the matrix for identical programs.
WAVE_ENTRIES = ("prefill", "decode_loop", "train_step")
CONTINUOUS_ENTRIES = ("admit", "decode_chunk")

#: ServeConfig knobs every case is traced with — small enough to lower in
#: seconds on a CPU host, big enough that plen/width bucketing is exercised.
SERVE_KW = dict(max_batch=4, max_len=64)


def mesh_label(mesh_spec: Optional[str]) -> str:
    """Mesh coordinate of a case id: ``"single"`` or ``"data4xmodel2"``
    (same label :func:`repro.launch.mesh.mesh_axis_label` derives from the
    built mesh, computed here without touching jax devices)."""
    if not mesh_spec:
        return "single"
    from repro.launch.mesh import parse_mesh_spec
    return "x".join(f"{k}{v}" for k, v in parse_mesh_spec(mesh_spec).items())


@dataclasses.dataclass(frozen=True, order=True)
class IRCase:
    """One cell of the config matrix the IR checker dry-traces."""
    family: str
    scheduler: str                 # "wave" | "continuous"
    mesh_spec: Optional[str]       # None = single device; else "data=4,model=2"
    dtype: str                     # "float32" | "bfloat16"

    @property
    def mesh_name(self) -> str:
        return mesh_label(self.mesh_spec)

    @property
    def case_id(self) -> str:
        """Stable identity: finding paths, fingerprint keys, cache keys."""
        return f"{self.family}/{self.scheduler}/{self.mesh_name}/{self.dtype}"

    @property
    def entries(self) -> Tuple[str, ...]:
        return WAVE_ENTRIES if self.scheduler == "wave" else CONTINUOUS_ENTRIES


def default_matrix(mesh_specs: Sequence[Optional[str]] = (None,),
                   families: Sequence[str] = FAMILIES,
                   schedulers: Sequence[str] = SCHEDULERS,
                   dtypes: Sequence[str] = DTYPES) -> List[IRCase]:
    """The full cross product, sorted for deterministic report order.
    Sorts on case_id — mesh_spec itself mixes None and str."""
    return sorted((IRCase(f, s, m, d)
                   for f in families for s in schedulers
                   for m in mesh_specs for d in dtypes),
                  key=lambda c: c.case_id)


def smoke_matrix() -> List[IRCase]:
    """Cheap subset for ``report --ir smoke``: one family, both schedulers,
    single device, bf16 — enough to catch wiring rot in seconds."""
    return default_matrix(mesh_specs=(None,), families=("llama3.2-1b",),
                          dtypes=("bfloat16",))
