"""IR check families over traced-case summaries: IR001 collective
placement, IR002 numerics, IR003 memory budget.

Each check is a pure function of a :class:`~repro.analysis.ir.trace.
CaseResult` (which may have come straight off the ``.ir_cache/`` disk
cache) plus the case's :class:`~repro.core.hardware.HardwareProfile` — no
jax, no re-tracing.  Findings use ``path="ir:<case_id>"`` and
``scope=<entry>``, so the ratchet identity survives line churn the same
way the AST lint's does.

Check semantics (catalog: docs/STATIC_ANALYSIS.md):

* **IR000** — an entry that failed to trace/lower/compile at all.  The
  matrix is the product the paper ships; a cell that stopped lowering is
  a shipped configuration that stopped existing.
* **IR001** — a weight-sized all-gather/all-reduce reachable from a while
  body of a fused *decode* entry (``decode_loop``/``decode_chunk``).
  This is exactly the PR 6 regression (FSDP rules leaking into serving:
  per-step weight gathers serialized the decode loop at 57% of device
  time), promoted from a profiler discovery to a static gate.  "Weight-
  sized" = result *shape* equal to some >=2-d params leaf (or its
  scan-sliced variant) of ``WEIGHT_NUMEL_MIN``+ elements — activation
  psums (batch x vocab) pass, as do activations whose element count
  merely collides with a weight's.
* **IR002** — numerics: any f64 value anywhere (silent x64 promotion);
  a bf16->f32 convert of a weight-shaped array inside a bf16-case *serve*
  program (the whole weight upcast, paying the f32 bandwidth the dtype
  knob was meant to save; ``train_step`` is exempt — f32 master params
  and optimizer moments are the mixed-precision recipe); a dot_general
  whose accumulate dtype transition is not in the explicit ``ACC_ALLOW``
  allowlist.
* **IR003** — live-buffer peak (XLA buffer assignment; argument+output+
  temp fallback where the backend reports no peak) vs the profile's
  ``hbm_bytes`` capacity: error over budget, warning within
  ``HEADROOM_WARN`` of it.
"""
from __future__ import annotations

from typing import List

from repro.analysis.findings import Finding, SEV_ERROR, SEV_WARNING
from repro.analysis.ir.trace import CaseResult, EntrySummary
from repro.core.hardware import HardwareProfile, find_profile

#: decode entries whose while bodies must stay free of weight-sized
#: collectives (training legitimately all-gathers FSDP-sharded weights)
DECODE_ENTRIES = ("decode_loop", "decode_chunk")

#: collective ops that move whole buffers (permutes move shards and are
#: how sharded KV caches legitimately rotate)
WEIGHT_MOVING_OPS = ("all-gather", "all-reduce")

#: sanctioned (operand dtype -> accumulate dtype) transitions for
#: dot_general.  Everything else that changes dtype across a dot is a
#: silent promotion IR002 flags.
ACC_ALLOW = {
    ("bfloat16", "float32"),
    ("float16", "float32"),
    ("int8", "int32"),
    ("uint8", "int32"),
}

#: IR003 warns when the peak exceeds this fraction of hbm_bytes
HEADROOM_WARN = 0.8


def _finding(check_id: str, severity: str, case: CaseResult, entry: str,
             message: str) -> Finding:
    return Finding(check_id=check_id, severity=severity,
                   path=f"ir:{case.case_id}", line=0, scope=entry,
                   message=message)


def check_trace_errors(case: CaseResult) -> List[Finding]:
    """IR000 — an entry of the shipped matrix no longer lowers."""
    return [_finding("IR000", SEV_ERROR, case, entry,
                     f"entry failed to trace/lower: {err}")
            for entry, err in sorted(case.errors.items())]


def check_collectives(case: CaseResult) -> List[Finding]:
    """IR001 — weight-sized collectives inside fused decode loops."""
    out: List[Finding] = []
    weights = {tuple(s) for s in case.weight_shapes}
    for entry in DECODE_ENTRIES:
        summary = case.entries.get(entry)
        if summary is None:
            continue
        flagged = {}
        for rec in summary.while_collectives:
            if (rec["op"] in WEIGHT_MOVING_OPS
                    and tuple(rec.get("dims", ())) in weights):
                key = (rec["op"], tuple(rec["dims"]))
                flagged[key] = flagged.get(key, 0) + 1
        for (op, dims), count in sorted(flagged.items()):
            shape = "x".join(map(str, dims))
            out.append(_finding(
                "IR001", SEV_ERROR, case, entry,
                f"{count}x weight-shaped `{op}` ({shape}) inside "
                f"the fused decode loop — weights are being re-gathered "
                f"per step (FSDP rules leaking into serving; use "
                f"inference-TP rules: rules_for_mesh(mesh, fsdp=False))"))
    return out


def _entry_numeric_findings(case: CaseResult, entry: str,
                            summary: EntrySummary) -> List[Finding]:
    out: List[Finding] = []
    if summary.f64_avals:
        out.append(_finding(
            "IR002", SEV_ERROR, case, entry,
            f"{summary.f64_avals} float64 value(s) in the traced program — "
            f"silent x64 promotion; no profile budgets f64"))
    model_dtype = case.case_id.rsplit("/", 1)[1]
    # train_step legitimately promotes whole weights: mixed-precision
    # training keeps f32 master params and optimizer moments by design.
    # The bandwidth-sensitive contract is on the serve path only.
    if model_dtype == "bfloat16" and entry != "train_step":
        weights = {tuple(s) for s in case.weight_shapes}
        upcasts = [c for c in summary.converts
                   if c["src"] == "bfloat16" and c["dst"] == "float32"
                   and tuple(c.get("dims", ())) in weights]
        if upcasts:
            total = sum(c["numel"] for c in upcasts)
            shapes = sorted({"x".join(map(str, c["dims"])) for c in upcasts})
            out.append(_finding(
                "IR002", SEV_ERROR, case, entry,
                f"{len(upcasts)} weight-shaped bf16->f32 upcast(s) "
                f"({', '.join(shapes)}; {total} elements) — whole weights "
                f"promoted to f32 inside a bf16 program defeats the dtype "
                f"knob"))
    bad_accs = sorted({(d["lhs"], d["out"]) for d in summary.dots
                       if d["lhs"] != d["out"]
                       and (d["lhs"], d["out"]) not in ACC_ALLOW})
    for lhs, acc in bad_accs:
        out.append(_finding(
            "IR002", SEV_ERROR, case, entry,
            f"dot_general accumulates {lhs} into {acc}, which is not in "
            f"the accumulate-dtype allowlist {sorted(ACC_ALLOW)}"))
    return out


def check_numerics(case: CaseResult) -> List[Finding]:
    """IR002 — silent upcasts / promotions in the traced programs."""
    out: List[Finding] = []
    for entry, summary in sorted(case.entries.items()):
        out += _entry_numeric_findings(case, entry, summary)
    return out


def peak_bytes(summary: EntrySummary) -> int:
    """Live-buffer peak: XLA's own number when the backend reports one,
    else the argument+output+temp sum (the CPU backend omits peak)."""
    mem = summary.memory
    if mem.get("peak_bytes"):
        return int(mem["peak_bytes"])
    return sum(int(mem.get(k) or 0) for k in
               ("argument_bytes", "output_bytes", "temp_bytes"))


def check_memory(case: CaseResult) -> List[Finding]:
    """IR003 — peak live bytes vs the hardware profile's HBM capacity."""
    profile: HardwareProfile = find_profile(case.hardware)
    if profile is None:
        return [_finding("IR003", SEV_ERROR, case, "-",
                         f"case traced against unregistered hardware "
                         f"{case.hardware!r}; no capacity to budget against")]
    budget = profile.hbm_bytes
    out: List[Finding] = []
    for entry, summary in sorted(case.entries.items()):
        peak = peak_bytes(summary)
        if peak > budget:
            out.append(_finding(
                "IR003", SEV_ERROR, case, entry,
                f"live-buffer peak {peak / 2**30:.2f} GiB exceeds "
                f"{profile.name} HBM capacity {budget / 2**30:.2f} GiB"))
        elif peak > HEADROOM_WARN * budget:
            out.append(_finding(
                "IR003", SEV_WARNING, case, entry,
                f"live-buffer peak {peak / 2**30:.2f} GiB is within "
                f"{(1 - HEADROOM_WARN) * 100:.0f}% of {profile.name} HBM "
                f"capacity {budget / 2**30:.2f} GiB"))
    return out


def check_case(case: CaseResult) -> List[Finding]:
    """All per-case checks (IR000-IR003); IR004/IR005 live in
    :mod:`~repro.analysis.ir.fingerprints` because they compare against the
    committed baseline file rather than the case alone."""
    return (check_trace_errors(case) + check_collectives(case)
            + check_numerics(case) + check_memory(case))
