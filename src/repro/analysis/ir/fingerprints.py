"""IR005 program fingerprints + the committed ``tests/ir_fingerprints.json``.

Every traced case commits, per entry point, a canonical jaxpr hash and the
primitive histogram behind it, plus the IR004 static jit-key counts.  An
unintended trace change — an op sneaking into the fused loop, a remat
policy flipping, a bucketing edit — fails CI with a *structural* diff
("+2 convert_element_type, -1 dot_general in decode_chunk") instead of
silently shifting perf three PRs later.

Blessing workflow (docs/STATIC_ANALYSIS.md): make the change, eyeball the
diff IR005 prints, then re-bless with::

    PYTHONPATH=src python scripts/analyze.py ir --write-fingerprints
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python scripts/analyze.py ir \\
        --mesh data=4,model=2 --write-fingerprints

Writes *merge* per case, so the single-device and mesh legs maintain one
file.  Hashes are only comparable within one jax version (lowering changes
move them); the file records the version it was blessed under, and on a
version mismatch IR005 degrades to a warning-severity structural
comparison instead of failing the gate — IR004 key counts are pure bucket
math and gate on every version.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.analysis.findings import Finding, SEV_ERROR, SEV_WARNING
from repro.analysis.ir.trace import CaseResult

FINGERPRINT_SCHEMA_VERSION = 1


def default_fingerprint_path() -> str:
    here = os.path.abspath(os.path.dirname(__file__))  # src/repro/analysis/ir
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(here))))
    return os.path.join(root, "tests", "ir_fingerprints.json")


def case_record(case: CaseResult, jit_keys: Dict[str, int]) -> dict:
    """The per-case blob committed to the fingerprint file."""
    return {
        "jit_keys": dict(sorted(jit_keys.items())),
        "entries": {
            entry: {"jaxpr_hash": s.jaxpr_hash,
                    "prims": dict(sorted(s.prim_histogram.items()))}
            for entry, s in sorted(case.entries.items())
        },
    }


def load_fingerprints(path: Optional[str] = None) -> dict:
    path = path or default_fingerprint_path()
    if not os.path.exists(path):
        return {"schema_version": FINGERPRINT_SCHEMA_VERSION,
                "jax_version": None, "cases": {}}
    with open(path) as f:
        blob = json.load(f)
    ver = blob.get("schema_version")
    if ver != FINGERPRINT_SCHEMA_VERSION:
        raise ValueError(
            f"{path}: ir_fingerprints schema_version {ver!r} != supported "
            f"{FINGERPRINT_SCHEMA_VERSION}; regenerate with "
            f"`python scripts/analyze.py ir --write-fingerprints`")
    return blob


def merge_fingerprints(records: Dict[str, dict], jax_version: str,
                       path: Optional[str] = None) -> str:
    """Bless ``{case_id: case_record}`` into the committed file, keeping
    cases from other legs (the mesh matrix) untouched."""
    path = path or default_fingerprint_path()
    blob = load_fingerprints(path)
    blob["cases"].update(records)
    out = {
        "schema_version": FINGERPRINT_SCHEMA_VERSION,
        "jax_version": jax_version,
        "note": ("Per-config program fingerprints (IR005) and static "
                 "jit-key counts (IR004).  Re-bless after an intended "
                 "trace change with `python scripts/analyze.py ir "
                 "--write-fingerprints` (see docs/STATIC_ANALYSIS.md)."),
        "cases": {k: blob["cases"][k] for k in sorted(blob["cases"])},
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def structural_diff(old_prims: Dict[str, int],
                    new_prims: Dict[str, int]) -> str:
    """Readable primitive-histogram delta: ``+2 convert_element_type, -1
    dot_general`` (empty string when histograms match — the change is
    below the primitive level, e.g. shapes or params)."""
    deltas = []
    for prim in sorted(set(old_prims) | set(new_prims)):
        d = new_prims.get(prim, 0) - old_prims.get(prim, 0)
        if d:
            deltas.append(f"{d:+d} {prim}")
    return ", ".join(deltas)


def _finding(check_id: str, severity: str, case_id: str, scope: str,
             message: str) -> Finding:
    return Finding(check_id=check_id, severity=severity,
                   path=f"ir:{case_id}", line=0, scope=scope, message=message)


def compare_case(case_id: str, record: dict, committed: dict,
                 jax_matches: bool) -> List[Finding]:
    """Diff one case's fresh record against the committed fingerprints.

    IR004 (jit-key counts) always gates; IR005 (jaxpr hashes) gates only
    when the running jax version matches the blessed one, else downgrades
    to structural warnings (lowering differences across jax versions move
    hashes without any repo change).
    """
    out: List[Finding] = []
    base = committed.get("cases", {}).get(case_id)
    if base is None:
        out.append(_finding(
            "IR005", SEV_ERROR, case_id, "-",
            "config has no committed fingerprint — new matrix cell; bless "
            "with `analyze.py ir --write-fingerprints`"))
        return out

    for entry in sorted(set(base["jit_keys"]) | set(record["jit_keys"])):
        old = base["jit_keys"].get(entry)
        new = record["jit_keys"].get(entry)
        if old != new:
            out.append(_finding(
                "IR004", SEV_ERROR, case_id, entry,
                f"static jit-key count changed: {old} -> {new} (bucket "
                f"policy or static-arg signature moved; expected? re-bless "
                f"with --write-fingerprints)"))

    for entry in sorted(set(base["entries"]) | set(record["entries"])):
        old = base["entries"].get(entry)
        new = record["entries"].get(entry)
        if old is None or new is None:
            out.append(_finding(
                "IR005", SEV_ERROR, case_id, entry,
                f"entry {'appeared' if old is None else 'disappeared'} "
                f"relative to the committed fingerprint"))
            continue
        if old["jaxpr_hash"] == new["jaxpr_hash"]:
            continue
        diff = structural_diff(old["prims"], new["prims"])
        detail = (f"primitive delta: {diff}" if diff else
                  "same primitive histogram — shape/param-level change")
        if jax_matches:
            out.append(_finding(
                "IR005", SEV_ERROR, case_id, entry,
                f"traced program changed ({detail}); if intended, re-bless "
                f"with `analyze.py ir --write-fingerprints`"))
        else:
            out.append(_finding(
                "IR005", SEV_WARNING, case_id, entry,
                f"jaxpr hash differs under jax "
                f"{'?' if not committed.get('jax_version') else committed['jax_version']}"
                f"-blessed fingerprints ({detail}); hash gate inactive "
                f"across jax versions"))
    return out
