"""Dry-trace one :class:`~repro.analysis.ir.matrix.IRCase` and distill the
lowered program into a check-ready :class:`EntrySummary`.

The tracer builds a *real* engine (tiny ``.reduced()`` params, so a CPU
host pays seconds, not minutes), then lowers each jitted entry point with
``jitted.lower(...)`` / ``jitted.trace(...)`` — tracing and XLA compilation
only, **no device execution**.  Compiling matters: SPMD partitioning (and
therefore every collective the program will issue) only exists in
``lowered.compile().as_text()``, not in the pre-partitioning StableHLO, so
a collective-placement check that skipped compile would be checking air.

Everything the check families need is extracted *here*, at trace time,
into a JSON-serializable summary: jaxpr hash + primitive histogram
(IR005), dtype converts and dot accumulate dtypes (IR002), buffer
assignment numbers (IR003), and the collectives reachable from while-loop
bodies (IR001, reusing :mod:`repro.launch.hlo_stats`'s HLO parser).  The
summary — never the multi-MB HLO text — is what lands in the ``.ir_cache/``
disk cache, keyed on (source tree digest, jax version, case id), so checks
re-run instantly while nothing changed.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.ir.matrix import SERVE_KW, IRCase
from repro.launch.hlo_stats import (COLLECTIVE_OPS, _parse_computations,
                                    _shape_numel_bytes)

#: bump when the summary extraction changes shape — invalidates .ir_cache
SUMMARY_SCHEMA_VERSION = 3

#: params leaves at least this many elements wide (and >= 2-d) count as
#: "weights" for the weight-sized-collective and weight-upcast checks
WEIGHT_NUMEL_MIN = 1024

# pointer reprs (bound methods, closures) that leak into jaxpr pretty-prints
_ADDR_RE = re.compile(r"0x[0-9a-fA-F]+")


@dataclasses.dataclass
class EntrySummary:
    """Check-ready distillation of one lowered entry point."""
    entry: str
    jaxpr_hash: str
    prim_histogram: Dict[str, int]
    # convert_element_type sites: {"src", "dst", "numel", "dims"}
    converts: List[dict]
    # dot_general sites: {"lhs", "rhs", "out"}
    dots: List[dict]
    f64_avals: int
    # compiled buffer assignment: argument/output/temp/peak bytes (None
    # where the backend does not report a field — CPU omits peak)
    memory: Dict[str, Optional[int]]
    # collectives reachable from while-loop bodies:
    # {"op", "numel", "bytes", "dims"}
    while_collectives: List[dict]
    # all collectives in the compiled module (same record shape)
    collectives: List[dict]
    seconds: float = 0.0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, blob: dict) -> "EntrySummary":
        return cls(**blob)


@dataclasses.dataclass
class CaseResult:
    """All entry summaries of one case plus the case-level context the
    checks key on (weight sizes, resolved hardware, failures)."""
    case_id: str
    entries: Dict[str, EntrySummary]
    # >=2-d, >=WEIGHT_NUMEL_MIN-element params leaf shapes, plus their
    # leading-dim-sliced variants (what a layer scan's body sees of a
    # stacked (L, ...) leaf) — the identity "weight-sized" checks match on
    weight_shapes: List[List[int]]
    params_bytes: int
    hardware: str
    jax_version: str
    # entry -> "ExcType: message" for entries that failed to trace/compile
    errors: Dict[str, str] = dataclasses.field(default_factory=dict)
    cached: bool = False
    seconds: float = 0.0

    def to_json(self) -> dict:
        blob = dataclasses.asdict(self)
        blob["schema_version"] = SUMMARY_SCHEMA_VERSION
        return blob

    @classmethod
    def from_json(cls, blob: dict) -> "CaseResult":
        blob = dict(blob)
        blob.pop("schema_version", None)
        blob["entries"] = {k: EntrySummary.from_json(v)
                           for k, v in blob["entries"].items()}
        return cls(**blob)


# ---------------------------------------------------------------------------
# jaxpr distillation
# ---------------------------------------------------------------------------

def _sub_jaxprs(value):
    """Yield every Jaxpr nested in an eqn param value (ClosedJaxpr, bare
    Jaxpr, or tuples of either — cond branches)."""
    vals = value if isinstance(value, (tuple, list)) else (value,)
    for v in vals:
        inner = getattr(v, "jaxpr", v)
        if hasattr(inner, "eqns"):
            yield inner


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _iter_eqns(sub)


def _numel(aval) -> int:
    n = 1
    for d in getattr(aval, "shape", ()):
        n *= int(d)
    return n


def canonical_jaxpr_text(jaxpr) -> str:
    """Pretty-printed jaxpr with process-specific noise (object addresses in
    embedded callable reprs) scrubbed, so the hash is stable across
    processes on one jax version."""
    return _ADDR_RE.sub("0x?", str(jaxpr))


def summarize_jaxpr(closed_jaxpr) -> Tuple[str, Dict[str, int], List[dict],
                                           List[dict], int]:
    """-> (hash, prim histogram, converts, dots, f64 aval count)."""
    text = canonical_jaxpr_text(closed_jaxpr)
    digest = hashlib.sha256(text.encode()).hexdigest()
    hist: Dict[str, int] = {}
    converts: List[dict] = []
    dots: List[dict] = []
    f64 = 0
    root = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    for eqn in _iter_eqns(root):
        name = eqn.primitive.name
        hist[name] = hist.get(name, 0) + 1
        out_aval = eqn.outvars[0].aval if eqn.outvars else None
        for ov in eqn.outvars:
            if str(getattr(ov.aval, "dtype", "")) == "float64":
                f64 += 1
        if name == "convert_element_type" and out_aval is not None:
            src = str(eqn.invars[0].aval.dtype)
            dst = str(out_aval.dtype)
            if src != dst:
                converts.append({"src": src, "dst": dst,
                                 "numel": _numel(out_aval),
                                 "dims": [int(d) for d in out_aval.shape]})
        elif name == "dot_general" and out_aval is not None:
            dots.append({"lhs": str(eqn.invars[0].aval.dtype),
                         "rhs": str(eqn.invars[1].aval.dtype),
                         "out": str(out_aval.dtype)})
    return digest, hist, converts, dots, f64


# ---------------------------------------------------------------------------
# compiled-HLO distillation
# ---------------------------------------------------------------------------

_SHAPE_DIMS_RE = re.compile(r"[a-z][a-z0-9]*\[([\d,]*)\]")


def _collective_record(op: str, instr) -> dict:
    base = op.replace("-start", "")
    numel, nbytes = _shape_numel_bytes(instr.type_tok)
    if op.endswith("-start") and base in ("all-gather", "all-reduce"):
        numel //= 2      # -start returns an (operand, result) tuple
        nbytes //= 2
    # result dims: the last shape token (for -start tuples the second
    # element is the gathered result; plain ops have one token)
    toks = _SHAPE_DIMS_RE.findall(instr.type_tok)
    dims = [int(d) for d in toks[-1].split(",") if d] if toks else []
    return {"op": base, "numel": numel, "bytes": nbytes, "dims": dims}


def hlo_collectives(text: str) -> Tuple[List[dict], List[dict]]:
    """-> (all collectives, collectives reachable from while bodies).

    Reachability follows ``calls=`` / ``body=`` / ``condition=`` edges from
    every while instruction's body, so a collective hidden two fusions deep
    inside the fused decode loop still counts as "inside the loop".
    """
    comps = _parse_computations(text)
    edge_re = re.compile(r"(?:calls|body|condition|branch_computations)="
                         r"\{?%?([\w.\-, %]+)\}?")
    body_re = re.compile(r"body=%?([\w.\-]+)")

    edges: Dict[str, List[str]] = {}
    roots: List[str] = []
    for cname, comp in comps.items():
        outs: List[str] = []
        for ins in comp.instrs:
            for m in edge_re.finditer(ins.line):
                for tgt in m.group(1).split(","):
                    tgt = tgt.strip().lstrip("%")
                    if tgt in comps:
                        outs.append(tgt)
            if ins.op == "while":
                bm = body_re.search(ins.line)
                if bm and bm.group(1) in comps:
                    roots.append(bm.group(1))
        edges[cname] = outs

    in_while: set = set()
    stack = list(roots)
    while stack:
        name = stack.pop()
        if name in in_while:
            continue
        in_while.add(name)
        stack.extend(edges.get(name, ()))

    every: List[dict] = []
    while_body: List[dict] = []
    for cname, comp in comps.items():
        for ins in comp.instrs:
            base = ins.op.replace("-start", "")
            if base in COLLECTIVE_OPS and not ins.op.endswith("-done"):
                rec = _collective_record(ins.op, ins)
                every.append(rec)
                if cname in in_while:
                    while_body.append(rec)
    return every, while_body


def _memory_record(compiled) -> Dict[str, Optional[int]]:
    try:
        mem = compiled.memory_analysis()
    except Exception:
        return {"argument_bytes": None, "output_bytes": None,
                "temp_bytes": None, "peak_bytes": None}
    def _get(attr):
        v = getattr(mem, attr, None)
        return int(v) if v is not None else None
    return {"argument_bytes": _get("argument_size_in_bytes"),
            "output_bytes": _get("output_size_in_bytes"),
            "temp_bytes": _get("temp_size_in_bytes"),
            "peak_bytes": _get("peak_memory_in_bytes")}


def summarize_entry(entry: str, jitted, *args, **static) -> EntrySummary:
    """Lower + trace + compile one jitted entry point (never execute it)."""
    t0 = time.time()
    traced = jitted.trace(*args, **static)
    digest, hist, converts, dots, f64 = summarize_jaxpr(traced.jaxpr)
    compiled = jitted.lower(*args, **static).compile()
    collectives, while_collectives = hlo_collectives(compiled.as_text())
    return EntrySummary(
        entry=entry, jaxpr_hash=digest, prim_histogram=hist,
        converts=converts, dots=dots, f64_avals=f64,
        memory=_memory_record(compiled),
        while_collectives=while_collectives, collectives=collectives,
        seconds=round(time.time() - t0, 2))


# ---------------------------------------------------------------------------
# case tracing
# ---------------------------------------------------------------------------

def _weight_shapes(params) -> List[List[int]]:
    """Exact shapes that identify "a weight" in the traced programs: every
    >=2-d, >=WEIGHT_NUMEL_MIN-element params leaf, plus the leading-dim
    slice of stacked (L, ...) leaves — what a layer scan's body sees.
    Matching on full shape (not numel) keeps activations whose element
    count happens to collide with a weight's out of IR001/IR002."""
    import jax
    out = set()
    for leaf in jax.tree_util.tree_leaves(params):
        if getattr(leaf, "ndim", 0) >= 2 and leaf.size >= WEIGHT_NUMEL_MIN:
            shape = tuple(int(d) for d in leaf.shape)
            out.add(shape)
            if len(shape) >= 3:
                sliced = shape[1:]
                n = 1
                for d in sliced:
                    n *= d
                if n >= WEIGHT_NUMEL_MIN:
                    out.add(sliced)
    return sorted(list(s) for s in out)


def _params_bytes(params) -> int:
    import jax
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(params))


def _extras(model, b):
    """Zero-filled extra model inputs (image tiles, audio features) shaped
    like the engine pads them — so VLM/audio towers are part of the trace."""
    import jax.numpy as jnp
    return {name: jnp.zeros(sds.shape, sds.dtype)
            for name, sds in model.extra_inputs(b).items()}


def _trace_wave_entries(eng, model, case: IRCase, plen: int,
                        out: Dict[str, EntrySummary],
                        errors: Dict[str, str]) -> None:
    import jax
    import jax.numpy as jnp
    b = eng.cfg.max_batch
    batch = {"tokens": jnp.zeros((b, plen), jnp.int32),
             "kv_start": jnp.zeros((b,), jnp.int32), **_extras(model, b)}
    batch = eng._place_batch(batch)
    cache = eng._ensure_cache()
    try:
        out["prefill"] = summarize_entry(
            "prefill", eng._prefill, eng.params, batch, cache)
    except Exception as e:
        errors["prefill"] = f"{type(e).__name__}: {e}"
    try:
        logits_aval = jax.eval_shape(eng._prefill, eng.params, batch, cache)[0]
        loop = eng._loop or eng._build_loop()
        eng._loop = loop
        width = 8
        unroll = min(eng._resolve_unroll(), width)
        out["decode_loop"] = summarize_entry(
            "decode_loop", loop, eng.params, cache,
            jnp.zeros(logits_aval.shape, logits_aval.dtype),
            jax.random.PRNGKey(0), jnp.zeros((b,), jnp.int32),
            jnp.zeros((b,), jnp.int32), jnp.int32(plen),
            width=width, unroll=unroll)
    except Exception as e:
        errors["decode_loop"] = f"{type(e).__name__}: {e}"


def _trace_train_entry(model, case: IRCase, mesh,
                       out: Dict[str, EntrySummary],
                       errors: Dict[str, str]) -> None:
    """Train-step lowering, abstract end to end (the dryrun.py pattern):
    ShapeDtypeStruct state/batch, explicit shardings on a mesh."""
    import jax
    from repro.configs.base import ShapeSpec
    from repro.distributed import sharding as sh
    from repro.launch import specs as specs_mod
    from repro.optim.adamw import AdamW
    from repro.train import trainer as tr
    try:
        shape = ShapeSpec("ir_train", 32, 8 if mesh is not None else 4,
                          "train")
        batch = specs_mod.train_batch_specs(model, shape)
        optimizer = AdamW(learning_rate=1e-4)
        state_abs = tr.abstract_train_state(model, optimizer)
        step = tr.make_train_step(model, optimizer)
        if mesh is not None:
            rules = sh.rules_for_mesh(mesh)      # FSDP: the training rules
            from repro.distributed.ctx import activation_policy
            with mesh, activation_policy(mesh, rules):
                jitted = jax.jit(
                    step,
                    in_shardings=(tr.state_shardings(mesh, rules, model),
                                  sh.batch_shardings(mesh, rules, batch)),
                    out_shardings=(tr.state_shardings(mesh, rules, model),
                                   None),
                    donate_argnums=(0,))
                out["train_step"] = summarize_entry(
                    "train_step", jitted, state_abs, batch)
        else:
            jitted = jax.jit(step, donate_argnums=(0,))
            out["train_step"] = summarize_entry(
                "train_step", jitted, state_abs, batch)
    except Exception as e:
        errors["train_step"] = f"{type(e).__name__}: {e}"


def _trace_continuous_entries(eng, model, case: IRCase, plen: int,
                              out: Dict[str, EntrySummary],
                              errors: Dict[str, str]) -> None:
    import jax
    import jax.numpy as jnp
    b = eng.cfg.max_batch
    eng._ensure_pool()
    key = jax.random.PRNGKey(0)
    try:
        batch = {"tokens": jnp.zeros((b, plen), jnp.int32),
                 "kv_start": jnp.zeros((b,), jnp.int32), **_extras(model, b)}
        batch = eng._place_batch(batch)
        scratch = eng._scratch_cache(plen)
        admit = eng._admit_fn or eng._build_admit_fn()
        eng._admit_fn = admit
        out["admit"] = summarize_entry(
            "admit", admit, eng.params, batch, scratch, eng._pools,
            eng._fixed, eng._cur, key, jnp.zeros((b, plen), jnp.int32),
            jnp.zeros((b,), jnp.int32))
    except Exception as e:
        errors["admit"] = f"{type(e).__name__}: {e}"
    try:
        chunk = eng._chunk
        width = 16
        unroll = min(eng._resolve_unroll(), chunk)
        while chunk % unroll:
            unroll -= 1
        chunk_fn = eng._chunk_fn or eng._build_chunk_fn()
        eng._chunk_fn = chunk_fn
        out["decode_chunk"] = summarize_entry(
            "decode_chunk", chunk_fn, eng.params, eng._pools, eng._fixed,
            eng._cur, key, jnp.zeros((b, width), jnp.int32),
            jnp.zeros((b, chunk), jnp.int32), jnp.zeros((b,), jnp.int32),
            jnp.zeros((b,), jnp.int32), width=width, chunk=chunk,
            unroll=unroll)
    except Exception as e:
        errors["decode_chunk"] = f"{type(e).__name__}: {e}"


def trace_case(case: IRCase, rules_override=None) -> CaseResult:
    """Dry-trace every entry point of one case.

    ``rules_override`` installs explicit ambient sharding rules (via
    ``distributed.ctx.use_mesh``) instead of the engine's own inference-TP
    default — how the seeded-regression test re-creates the PR 6 bug
    (``fsdp=True`` rules putting weight all-gathers inside the decode loop)
    without editing engine code.
    """
    import contextlib
    import dataclasses as _dc

    import jax

    from repro.configs.catalog import ARCHITECTURES
    from repro.distributed import ctx as dctx
    from repro.launch.mesh import build_mesh
    from repro.models import build_model
    from repro.serve.engine import Engine, ServeConfig

    t0 = time.time()
    cfg = ARCHITECTURES[case.family].reduced()
    cfg = _dc.replace(cfg, dtype=case.dtype)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    mesh = build_mesh(case.mesh_spec)
    scope = (dctx.use_mesh(mesh, rules_override)
             if rules_override is not None and mesh is not None
             else contextlib.nullcontext())
    with scope:
        eng = Engine(model, params, ServeConfig(
            scheduler=case.scheduler,
            mesh=None if rules_override is not None else case.mesh_spec,
            **SERVE_KW))

    plen = 16
    out: Dict[str, EntrySummary] = {}
    errors: Dict[str, str] = {}
    if case.scheduler == "wave":
        _trace_wave_entries(eng, model, case, plen, out, errors)
        _trace_train_entry(model, case, mesh, out, errors)
    else:
        if eng._scheduler != "continuous":
            errors["admit"] = (f"RuntimeError: engine forced scheduler "
                               f"{eng._scheduler!r} ({eng._scheduler_forced})")
        else:
            _trace_continuous_entries(eng, model, case, plen, out, errors)

    return CaseResult(
        case_id=case.case_id, entries=out,
        weight_shapes=_weight_shapes(eng.params),
        params_bytes=_params_bytes(eng.params),
        hardware=eng.hardware, jax_version=jax.__version__,
        errors=errors, seconds=round(time.time() - t0, 2))


# ---------------------------------------------------------------------------
# disk cache
# ---------------------------------------------------------------------------

def repo_root() -> str:
    here = os.path.abspath(os.path.dirname(__file__))   # src/repro/analysis/ir
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(here))))


def source_digest(root: Optional[str] = None) -> str:
    """Digest of every ``src/repro/**/*.py`` — the cache invalidation key.
    Any source edit retraces everything; a docs/CI edit retraces nothing."""
    root = root or repo_root()
    src = os.path.join(root, "src", "repro")
    h = hashlib.sha256()
    for dirpath, dirnames, filenames in sorted(os.walk(src)):
        dirnames.sort()
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            h.update(os.path.relpath(path, src).encode())
            with open(path, "rb") as f:
                h.update(f.read())
    return h.hexdigest()


def default_cache_dir() -> str:
    return os.path.join(repo_root(), ".ir_cache")


def cache_key(case: IRCase, src_digest: str) -> str:
    import jax
    raw = (f"v{SUMMARY_SCHEMA_VERSION}:{src_digest}:{jax.__version__}:"
           f"{case.case_id}:{sorted(SERVE_KW.items())}")
    return hashlib.sha256(raw.encode()).hexdigest()[:32]


def traced_case_cached(case: IRCase, *, cache_dir: Optional[str] = None,
                       src_digest: Optional[str] = None,
                       use_cache: bool = True) -> CaseResult:
    """`trace_case` behind the ``.ir_cache/`` summary cache."""
    cache_dir = cache_dir or default_cache_dir()
    src_digest = src_digest or source_digest()
    path = os.path.join(cache_dir, f"{cache_key(case, src_digest)}.json")
    if use_cache and os.path.exists(path):
        try:
            with open(path) as f:
                result = CaseResult.from_json(json.load(f))
            result.cached = True
            return result
        except Exception:
            pass                          # corrupt entry: retrace
    result = trace_case(case)
    if use_cache:
        os.makedirs(cache_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(result.to_json(), f, indent=1, sort_keys=True)
    return result
