"""ST001: the ``Engine.stats()`` key set must match ``stats_schema``.

The stats schema (:mod:`repro.serve.stats_schema`) is the documented,
versioned contract that launchers, benchmarks and the CI step summary
render from.  The engine *emits* that dict imperatively — a seeded
``self._stats`` counter literal plus ``out["..."] = ...`` assignments in
``stats()`` — so nothing ties emission to documentation at runtime except
the tests that happen to call :func:`~repro.serve.stats_schema
.validate_stats`.  This check closes the loop statically: it AST-scans
``engine.py`` for every key the engine can emit and diffs that set against
``STATS_SCHEMA``.  Drift in either direction is an error:

==========  =========  =====================================================
check id    severity   fires on
==========  =========  =====================================================
``ST001``   error      a key ``stats()`` emits that ``STATS_SCHEMA`` does
                       not document, or a documented key no code path
                       emits — bump ``SCHEMA_VERSION`` and update the
                       schema (and its consumers) instead of letting the
                       surfaces drift apart
==========  =========  =====================================================

The scan is deliberately syntactic: string-literal keys in the
``self._stats`` seed dict and in subscript stores onto ``stats()``'s
result dict.  Dynamically-computed keys would evade it, which is exactly
the style this check exists to keep out of the telemetry surface.
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional, Set, Tuple

from repro.analysis.findings import Finding, SEV_ERROR

SLUGS = {
    "ST001": "stats-schema-drift",
}

#: the module that emits the stats dict, relative to the repo root
ENGINE_REL = os.path.join("src", "repro", "serve", "engine.py")


def _literal_keys(node: ast.Dict) -> Set[str]:
    return {k.value for k in node.keys
            if isinstance(k, ast.Constant) and isinstance(k.value, str)}


class _EmittedKeys(ast.NodeVisitor):
    """Collect every stats key ``Engine`` can emit.

    Two emission sites, by construction of the engine:

    * the ``self._stats = {...}`` counter seed in ``__init__`` (its keys
      pass straight through ``stats()``'s ``dict(self._stats)`` copy);
    * ``<name>["key"] = ...`` subscript stores inside the ``stats``
      method, whatever the local result dict is called.
    """

    def __init__(self):
        self.keys: Set[str] = set()
        self.stats_line: int = 0
        self._in_stats = False

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        if node.name == "stats":
            self.stats_line = node.lineno
            self._in_stats = True
            self.generic_visit(node)
            self._in_stats = False
        else:
            self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        # self._stats = {...} seed literal
        for tgt in node.targets:
            if (isinstance(tgt, ast.Attribute) and tgt.attr == "_stats"
                    and isinstance(node.value, ast.Dict)):
                self.keys |= _literal_keys(node.value)
            if (self._in_stats and isinstance(tgt, ast.Subscript)
                    and isinstance(tgt.slice, ast.Constant)
                    and isinstance(tgt.slice.value, str)
                    and isinstance(tgt.value, ast.Name)):
                self.keys.add(tgt.slice.value)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        tgt = node.target
        if (isinstance(tgt, ast.Attribute) and tgt.attr == "_stats"
                and isinstance(node.value, ast.Dict)):
            self.keys |= _literal_keys(node.value)
        self.generic_visit(node)


def emitted_stats_keys(engine_path: str) -> Tuple[Set[str], int]:
    """The statically-visible key set ``stats()`` can emit, plus the
    ``stats()`` def line for finding locations."""
    with open(engine_path) as f:
        tree = ast.parse(f.read(), filename=engine_path)
    visitor = _EmittedKeys()
    visitor.visit(tree)
    return visitor.keys, visitor.stats_line


def check_stats_schema(root: str, engine_rel: Optional[str] = None
                       ) -> List[Finding]:
    """ST001 over one repo checkout; empty list = schema and emission
    agree exactly."""
    from repro.serve.stats_schema import STATS_SCHEMA
    rel = engine_rel or ENGINE_REL
    path = os.path.join(root, rel)
    findings: List[Finding] = []
    if not os.path.exists(path):
        findings.append(Finding(
            check_id="ST001", severity=SEV_ERROR, path=rel, line=0,
            scope="Engine.stats",
            message="engine module missing — nothing emits the stats "
                    "schema"))
        return findings
    emitted, line = emitted_stats_keys(path)
    documented = set(STATS_SCHEMA)
    for key in sorted(emitted - documented):
        findings.append(Finding(
            check_id="ST001", severity=SEV_ERROR, path=rel, line=line,
            scope=f"stats.{key}",
            message=f"stats() emits {key!r} but stats_schema.STATS_SCHEMA "
                    f"does not document it — add it to the schema and bump "
                    f"SCHEMA_VERSION"))
    for key in sorted(documented - emitted):
        findings.append(Finding(
            check_id="ST001", severity=SEV_ERROR, path=rel, line=line,
            scope=f"stats.{key}",
            message=f"STATS_SCHEMA documents {key!r} but no stats() code "
                    f"path emits it — remove it from the schema and bump "
                    f"SCHEMA_VERSION"))
    return findings
