"""Pragma ledger: every ``# analysis: allow(...)`` site, what it actually
suppresses, and the PR900 unused-pragma check.

Pragmas are the sanctioned-waiver mechanism of the AST lint (see
:mod:`repro.analysis.purity`): a ``# analysis: allow(TP001)`` on (or right
above) an offending line silences that check there.  But a waiver whose
offense has since been refactored away is a live hand-grenade — it will
silently excuse the *next* violation someone writes on that line.  So the
lint now runs with a :class:`PragmaLedger` that records every suppression
it performs, and :func:`unused_pragma_findings` turns each pragma site
that suppressed nothing into a **PR900** error that rides the same
baseline ratchet as every other finding.

``scripts/analyze.py --list-pragmas`` (or the ``pragmas`` subcommand)
prints the ledger: each site, the checks it waives, and how many findings
it is currently eating.
"""
from __future__ import annotations

import dataclasses
import io
import tokenize
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding, SEV_ERROR
from repro.analysis.purity import _PRAGMA_RE, SLUGS

#: slug -> check id (a pragma may name either; the ledger normalizes)
_SLUG_TO_ID = {slug: cid for cid, slug in SLUGS.items()}


@dataclasses.dataclass(frozen=True)
class PragmaSite:
    """One ``# analysis: allow(...)`` occurrence in the source tree."""
    path: str                                # repo-relative module path
    line: int                                # 1-indexed pragma line
    check_ids: Optional[Tuple[str, ...]]     # None = bare allow (waives all)
    text: str                                # the pragma text as written

    @property
    def label(self) -> str:
        if self.check_ids is None:
            return "allow(*)"
        return f"allow({', '.join(self.check_ids)})"


def _normalize(tokens: str) -> Tuple[str, ...]:
    out = []
    for tok in tokens.split(","):
        tok = tok.strip()
        if tok:
            out.append(_SLUG_TO_ID.get(tok, tok))
    return tuple(sorted(set(out)))


def _comment_lines(source: str) -> Set[int]:
    """Line numbers holding a real ``#`` comment token — pragma *mentions*
    in docstrings and string literals (this package documents the syntax a
    lot) are not pragma sites."""
    out: Set[int] = set()
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                out.add(tok.start[0])
    except (tokenize.TokenError, IndentationError, SyntaxError):
        pass
    return out


def scan_pragmas(graph) -> List[PragmaSite]:
    """Every pragma site in the graph's module index (all of src/repro)."""
    sites: List[PragmaSite] = []
    for path, mod in sorted(graph.modules.items()):
        commented = _comment_lines("\n".join(mod.lines) + "\n")
        for lineno, line in enumerate(mod.lines, start=1):
            if lineno not in commented:
                continue
            m = _PRAGMA_RE.search(line)
            if m is None:
                continue
            tokens = m.group(1)
            ids = (None if tokens is None or not tokens.strip()
                   else _normalize(tokens))
            sites.append(PragmaSite(path=path, line=lineno, check_ids=ids,
                                    text=m.group(0).strip()))
    return sites


class PragmaLedger:
    """Suppressions the lint actually performed, keyed by pragma site."""

    def __init__(self):
        self._hits: Dict[Tuple[str, int], Set[str]] = {}

    def record(self, path: str, pragma_line: int, check_id: str) -> None:
        self._hits.setdefault((path, pragma_line), set()).add(check_id)

    def suppressed(self, path: str, line: int) -> Set[str]:
        return self._hits.get((path, line), set())

    def count(self) -> int:
        return sum(len(v) for v in self._hits.values())


def unused_pragma_findings(sites: Sequence[PragmaSite],
                           ledger: PragmaLedger) -> List[Finding]:
    """PR900 — a pragma that no longer suppresses anything.  Either its
    offense was refactored away (delete the pragma) or it was written
    somewhere the lint never looks (it never worked)."""
    out: List[Finding] = []
    for site in sites:
        if ledger.suppressed(site.path, site.line):
            continue
        out.append(Finding(
            check_id="PR900", severity=SEV_ERROR, path=site.path,
            line=site.line, scope=site.label,
            message=(f"`{site.text}` suppresses no finding — stale waiver; "
                     f"delete it (a dead pragma silently excuses the next "
                     f"violation written on this line)")))
    return out


def pragma_table(sites: Sequence[PragmaSite],
                 ledger: PragmaLedger) -> List[dict]:
    """JSON-ready rows for ``--list-pragmas`` and the findings blob."""
    return [{
        "path": s.path,
        "line": s.line,
        "allows": list(s.check_ids) if s.check_ids is not None else ["*"],
        "suppresses": sorted(ledger.suppressed(s.path, s.line)),
        "live": bool(ledger.suppressed(s.path, s.line)),
    } for s in sites]
