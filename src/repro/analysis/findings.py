"""Findings + the committed-baseline ratchet of the static-analysis gate.

A :class:`Finding` is one invariant violation: a check id from the catalog
(``docs/STATIC_ANALYSIS.md``), a severity, a repo-relative location, and a
*scope* — the function qualname, artifact entry, or param leaf it anchors
to.  The ratchet identity is ``check_id:path:scope`` (NOT the line number):
unrelated edits shift lines constantly, and a ratchet that churned on every
shift would train people to re-bless it blindly.  The line is still
reported for navigation; only the identity is line-free.

The ratchet itself mirrors ``scripts/ci_ratchet.py``: a committed
``tests/analysis_baseline.json`` lists the findings allowed to exist.  Any
finding whose key is not in the baseline fails CI; fixed findings print a
reminder to re-bless with ``scripts/analyze.py report --update-baseline``
so the smaller set becomes the new floor.  The goal state — and the shipped
state — is an **empty** baseline: every sanctioned host sync carries an
explicit ``# analysis: allow(...)`` pragma at the line instead of a
grandfather entry here, so the waiver is visible in the code it waives.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional, Tuple

#: current on-disk schema of tests/analysis_baseline.json
BASELINE_SCHEMA_VERSION = 1

SEV_ERROR = "error"
SEV_WARNING = "warning"
SEVERITIES = (SEV_ERROR, SEV_WARNING)


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One static-invariant violation, ratchet-keyed by (check, path, scope)."""
    check_id: str            # catalog id, e.g. "TP001"
    severity: str            # error | warning
    path: str                # repo-relative file (or artifact) path
    line: int                # 1-based; 0 for whole-file/artifact findings
    scope: str               # function qualname / artifact entry / param leaf
    message: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r}; "
                             f"known: {SEVERITIES}")

    @property
    def key(self) -> str:
        """Line-free ratchet identity (see module docstring)."""
        return f"{self.check_id}:{self.path}:{self.scope}"

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return (f"{loc}: {self.check_id} [{self.severity}] "
                f"{self.scope}: {self.message}")

    def to_json(self) -> dict:
        return {"check_id": self.check_id, "severity": self.severity,
                "path": self.path, "line": self.line, "scope": self.scope,
                "message": self.message, "key": self.key}


def sort_findings(findings: Iterable[Finding]) -> List[Finding]:
    """Stable report order: errors first, then path/line/check."""
    return sorted(findings,
                  key=lambda f: (f.severity != SEV_ERROR, f.path, f.line,
                                 f.check_id, f.scope))


# ---------------------------------------------------------------------------
# Baseline ratchet
# ---------------------------------------------------------------------------

def default_baseline_path() -> str:
    here = os.path.abspath(os.path.dirname(__file__))   # .../src/repro/analysis
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    return os.path.join(root, "tests", "analysis_baseline.json")


def load_baseline(path: Optional[str] = None) -> Dict[str, dict]:
    """``{finding key: baseline entry}``; missing file -> empty baseline."""
    path = path or default_baseline_path()
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        blob = json.load(f)
    ver = blob.get("schema_version")
    if ver != BASELINE_SCHEMA_VERSION:
        raise ValueError(
            f"{path}: analysis baseline schema_version {ver!r} != supported "
            f"{BASELINE_SCHEMA_VERSION}; regenerate with "
            f"`python scripts/analyze.py report --update-baseline`")
    return {e["key"]: e for e in blob.get("findings", [])}


def save_baseline(findings: Iterable[Finding],
                  path: Optional[str] = None) -> str:
    """Bless the given findings as the new ratchet floor."""
    path = path or default_baseline_path()
    entries = sorted(
        ({"key": f.key, "check_id": f.check_id, "severity": f.severity,
          "path": f.path, "scope": f.scope, "message": f.message}
         for f in findings), key=lambda e: e["key"])
    blob = {
        "schema_version": BASELINE_SCHEMA_VERSION,
        "note": ("Known findings the analyze gate tolerates (ratchet floor)."
                 "  Shrink it; never grow it without a review.  Bless with"
                 " `python scripts/analyze.py report --update-baseline`."),
        "findings": entries,
    }
    with open(path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def ratchet(findings: Iterable[Finding], baseline: Dict[str, dict],
            ) -> Tuple[List[Finding], List[str]]:
    """Split current findings against the baseline.

    Returns ``(new_findings, fixed_keys)``: findings whose key the baseline
    does not list (these fail the gate), and baseline keys no current
    finding matches (candidates for re-blessing the smaller floor).
    """
    current = list(findings)
    current_keys = {f.key for f in current}
    new = [f for f in current if f.key not in baseline]
    fixed = sorted(k for k in baseline if k not in current_keys)
    return sort_findings(new), fixed
