"""AST module index + traced-region call graph over ``src/repro``.

The trace-purity lint needs to know *which* functions execute under a JAX
trace: host syncs are fine in driver code (that is where the sanctioned
once-per-wave ``device_get`` lives) and fatal inside anything reachable
from a ``jax.jit`` / ``pallas_call`` / ``lax.while_loop`` / ``lax.scan``
body.  This module builds that set statically:

1. **Index** every function (including nested defs and lambdas) in every
   module under ``src/repro``, keyed by simple name and by qualname.
2. **Roots**: find call sites of the tracing wrappers (``jax.jit``,
   ``pallas_call``, ``lax.{while_loop,scan,cond,fori_loop,map}``,
   ``vmap``/``pmap``, ``checkpoint``/``remat``, ``grad``/
   ``value_and_grad``, ``shard_map``) and resolve their function-valued
   arguments.  Resolution follows local ``name = factory(...)``
   assignments into the factory's nested defs (the ``step =
   make_train_step(...); jax.jit(step)`` idiom) and unwraps adapter calls
   like ``self._with_mesh(loop)`` down to their function arguments.
3. **Reachability**: BFS over call edges.  Bare and attribute callee
   names resolve against the index; attribute calls whose base is an
   external module alias (``jnp``, ``np``, ``os``, ...) and generic
   container-method names (``.get``, ``.update``, ...) are excluded so
   stdlib lookalikes don't drag host code into the traced set.

This over-approximates (a helper called both from host and traced code is
traced) — exactly the conservatism a purity lint wants.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# wrapper callables whose function-valued arguments start a traced region
TRACING_WRAPPERS = {
    "jit", "pallas_call", "while_loop", "scan", "cond", "fori_loop",
    "map", "vmap", "pmap", "checkpoint", "remat", "grad",
    "value_and_grad", "shard_map", "eval_shape", "custom_vjp",
}
# "map"/"cond" are only tracing wrappers when called off jax/lax — a bare
# builtin map() call must not seed the traced set.
_NEEDS_JAX_BASE = {"map", "cond", "eval_shape"}

# which positional args of each wrapper are function-valued — the rest are
# data (a scan's carry/xs, a fori_loop's bounds) and must not be resolved,
# or a data variable that shares a function's name would seed the traced set
_FN_ARG_INDICES = {
    "jit": (0,), "vmap": (0,), "pmap": (0,), "grad": (0,),
    "value_and_grad": (0,), "checkpoint": (0,), "remat": (0,),
    "eval_shape": (0,), "custom_vjp": (0,), "pallas_call": (0,),
    "shard_map": (0,), "while_loop": (0, 1), "scan": (0,),
    "cond": (1, 2, 3), "fori_loop": (2,), "map": (0,),
}
# keyword names that carry the function across all wrappers
_FN_KEYWORDS = {"f", "fun", "body_fun", "cond_fun", "kernel", "body"}

# attribute-call names too generic to resolve against the index when the
# receiver is not `self` — stdlib/container lookalikes, jnp Array methods
GENERIC_METHOD_NAMES = {
    "get", "add", "update", "items", "keys", "values", "append", "extend",
    "pop", "popleft", "copy", "clear", "join", "split", "strip", "format",
    "read", "write", "close", "open", "mean", "sum", "max", "min", "all",
    "any", "astype", "reshape", "transpose", "at", "set", "dot", "sort",
    "count", "index", "insert", "remove", "save", "load", "render",
    "startswith", "endswith", "replace", "lower", "upper", "setdefault",
    "todo", "put", "run", "result",
}


# calls that take a function argument and invoke it under the caller's
# trace context — their Name/Lambda args become traced too
_HIGHER_ORDER_TAILS = {
    "tree_map", "tree_map_with_path", "partial", "map", "filter", "sorted",
    "reduce", "apply", "switch",
}


@dataclasses.dataclass
class FunctionInfo:
    """One function (or lambda) definition found in the scanned tree."""
    path: str                 # repo-relative module path
    qualname: str             # e.g. "Engine._build_loop.<locals>.loop"
    name: str                 # simple name ("loop"; "<lambda>")
    node: ast.AST             # FunctionDef | AsyncFunctionDef | Lambda
    lineno: int

    @property
    def key(self) -> str:
        return f"{self.path}:{self.qualname}"


class ModuleInfo:
    """Per-module artifacts the indexer keeps around for resolution."""

    def __init__(self, path: str, tree: ast.Module, source: str):
        self.path = path
        self.tree = tree
        self.lines = source.splitlines()
        self.functions: List[FunctionInfo] = []
        # local alias -> fully dotted module/name it was imported as
        self.imports: Dict[str, str] = {}
        # simple local/global name -> Call node it was assigned from
        self.assigned_calls: Dict[str, ast.Call] = {}


def _body_without_nested(fn_node: ast.AST) -> Iterable[ast.AST]:
    """Walk a function body, not descending into nested function defs or
    lambdas (those are indexed and analyzed as their own scopes)."""
    if isinstance(fn_node, ast.Lambda):
        stack: List[ast.AST] = [fn_node.body]
    else:
        stack = list(fn_node.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jax.lax.scan`` -> "jax.lax.scan"; None for non-name chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Indexer(ast.NodeVisitor):
    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.scope: List[str] = []

    def _register(self, name: str, node: ast.AST):
        qual = ".".join(self.scope + [name]) if self.scope else name
        self.mod.functions.append(
            FunctionInfo(self.mod.path, qual, name, node, node.lineno))

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            self.mod.imports[a.asname or a.name.split(".")[0]] = a.name

    def visit_ImportFrom(self, node: ast.ImportFrom):
        base = node.module or ""
        for a in node.names:
            self.mod.imports[a.asname or a.name] = f"{base}.{a.name}"

    def visit_ClassDef(self, node: ast.ClassDef):
        self.scope.append(node.name)
        self.generic_visit(node)
        self.scope.pop()

    def _visit_fn(self, node):
        self._register(node.name, node)
        self.scope.extend([node.name, "<locals>"])
        self.generic_visit(node)
        self.scope.pop()
        self.scope.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_Lambda(self, node: ast.Lambda):
        self._register("<lambda>", node)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign):
        if isinstance(node.value, ast.Call):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self.mod.assigned_calls[tgt.id] = node.value
        self.generic_visit(node)


class CallGraph:
    """Index of every function under a source root + the traced subset."""

    def __init__(self, root: str, package_dir: str = "src/repro"):
        self.root = os.path.abspath(root)
        self.package_dir = package_dir
        self.modules: Dict[str, ModuleInfo] = {}
        self.by_name: Dict[str, List[FunctionInfo]] = {}
        self.by_key: Dict[str, FunctionInfo] = {}
        self._scan()
        self.traced: Dict[str, FunctionInfo] = {}
        self.traced_via: Dict[str, str] = {}   # key -> why it is traced
        self._mark_traced()

    # -- indexing ----------------------------------------------------------

    def _scan(self):
        pkg = os.path.join(self.root, self.package_dir)
        for dirpath, _dirnames, filenames in os.walk(pkg):
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fn)
                rel = os.path.relpath(full, self.root)
                with open(full) as f:
                    source = f.read()
                mod = ModuleInfo(rel, ast.parse(source, filename=rel), source)
                _Indexer(mod).visit(mod.tree)
                self.modules[rel] = mod
                for info in mod.functions:
                    self.by_name.setdefault(info.name, []).append(info)
                    self.by_key[info.key] = info

    # -- alias / external classification -----------------------------------

    def _is_external_base(self, mod: ModuleInfo, base: str) -> bool:
        """True when `base.attr(...)`'s base names a non-repro module."""
        target = mod.imports.get(base)
        if target is None:
            return False
        return not target.split(".")[0] == "repro"

    def _is_jaxish_base(self, mod: ModuleInfo, base: str) -> bool:
        target = mod.imports.get(base, base)
        head = target.split(".")[0]
        return head in {"jax", "pl", "pltpu", "plgpu"} or ".lax" in target \
            or target in {"lax", "jax.lax"}

    # -- traced-root discovery ---------------------------------------------

    def _wrapper_name(self, mod: ModuleInfo, call: ast.Call) -> Optional[str]:
        dn = dotted_name(call.func)
        if dn is None:
            return None
        tail = dn.split(".")[-1]
        if tail not in TRACING_WRAPPERS:
            return None
        if tail in _NEEDS_JAX_BASE:
            base = dn.split(".")[0]
            if "." not in dn or not self._is_jaxish_base(mod, base):
                return None
        # a bare name must itself be imported from jax-land (e.g.
        # `from jax import jit`); repo-local helpers named `scan` don't count
        if "." not in dn:
            target = mod.imports.get(dn, "")
            if not (target.startswith("jax") or "pallas" in target):
                return None
        return tail

    def _resolve_fn_expr(self, mod: ModuleInfo, expr: ast.AST,
                         depth: int = 0) -> List[FunctionInfo]:
        """Resolve a function-valued expression to candidate definitions."""
        if depth > 4:
            return []
        if isinstance(expr, ast.Lambda):
            for info in self.modules[mod.path].functions:
                if info.node is expr:
                    return [info]
            return []
        if isinstance(expr, ast.Call):
            # adapter idiom: self._with_mesh(loop), functools.partial(fn, x)
            out: List[FunctionInfo] = []
            for arg in list(expr.args) + [k.value for k in expr.keywords]:
                out.extend(self._resolve_fn_expr(mod, arg, depth + 1))
            # factory idiom: jax.jit(make_train_step(...)) — the traced code
            # is the factory's nested defs
            dn = dotted_name(expr.func)
            if dn is not None:
                for target in self._resolve_name(mod, dn.split(".")[-1],
                                                 prefer_module=True):
                    out.extend(self._nested_of(target))
            return out
        if isinstance(expr, ast.Name):
            # local `step = make_train_step(...)` then `jax.jit(step)`
            assigned = mod.assigned_calls.get(expr.id)
            if assigned is not None:
                got = self._resolve_fn_expr(mod, assigned, depth + 1)
                if got:
                    return got
            return self._resolve_name(mod, expr.id, prefer_module=True)
        if isinstance(expr, ast.Attribute):
            base = dotted_name(expr.value)
            if base and self._is_external_base(mod, base.split(".")[0]):
                return []
            return self._resolve_name(mod, expr.attr, prefer_module=False)
        return []

    def _resolve_name(self, mod: ModuleInfo, name: str,
                      prefer_module: bool) -> List[FunctionInfo]:
        candidates = self.by_name.get(name, [])
        if prefer_module:
            local = [c for c in candidates if c.path == mod.path]
            if local:
                return local
        return candidates

    def _nested_of(self, info: FunctionInfo) -> List[FunctionInfo]:
        prefix = info.qualname + ".<locals>."
        return [c for c in self.modules[info.path].functions
                if c.qualname.startswith(prefix)]

    def _mark_traced(self):
        queue: List[Tuple[FunctionInfo, str]] = []
        for mod in self.modules.values():
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                wrapper = self._wrapper_name(mod, node)
                if wrapper is None:
                    continue
                why = f"{mod.path}:{node.lineno} {wrapper}()"
                indices = _FN_ARG_INDICES.get(wrapper, (0,))
                fn_args = [node.args[i] for i in indices
                           if i < len(node.args)]
                fn_args += [k.value for k in node.keywords
                            if k.arg in _FN_KEYWORDS]
                for arg in fn_args:
                    for info in self._resolve_fn_expr(mod, arg):
                        queue.append((info, why))
        while queue:
            info, why = queue.pop()
            if info.key in self.traced:
                continue
            self.traced[info.key] = info
            self.traced_via[info.key] = why
            for callee in self._callees(info):
                queue.append((callee, f"called from {info.key}"))

    def _callees(self, info: FunctionInfo) -> List[FunctionInfo]:
        mod = self.modules[info.path]
        out: List[FunctionInfo] = []
        for node in _body_without_nested(info.node):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if isinstance(func, ast.Name):
                assigned = mod.assigned_calls.get(func.id)
                if assigned is not None:
                    out.extend(self._resolve_fn_expr(mod, assigned, 1))
                out.extend(self._resolve_name(mod, func.id,
                                              prefer_module=True))
            elif isinstance(func, ast.Attribute):
                base = dotted_name(func.value)
                base_head = base.split(".")[0] if base else None
                if base_head and self._is_external_base(mod, base_head):
                    continue
                if base_head != "self" and func.attr in GENERIC_METHOD_NAMES:
                    continue
                out.extend(self._resolve_name(mod, func.attr,
                                              prefer_module=False))
            # function-valued arguments — but only of calls that are known
            # higher-order (tree_map etc.); resolving every Name argument
            # would drag in unrelated defs that share a variable's name
            # (e.g. an int parameter called `batch`)
            tail = (dotted_name(func) or "").split(".")[-1]
            if tail in _HIGHER_ORDER_TAILS:
                for arg in node.args:
                    if isinstance(arg, (ast.Lambda, ast.Name)):
                        out.extend(self._resolve_fn_expr(mod, arg, 3))
            else:
                for arg in node.args:
                    if isinstance(arg, ast.Lambda):
                        out.extend(self._resolve_fn_expr(mod, arg, 3))
        return out

    # -- public queries ----------------------------------------------------

    def is_traced(self, info: FunctionInfo) -> bool:
        return info.key in self.traced

    def traced_functions(self) -> List[FunctionInfo]:
        return sorted(self.traced.values(), key=lambda i: (i.path, i.lineno))

    def host_functions(self, path_prefixes: Sequence[str]
                       ) -> List[FunctionInfo]:
        """Non-traced functions in the given subtrees (serve/train drivers)."""
        out = []
        for mod in self.modules.values():
            if not any(mod.path.startswith(p) for p in path_prefixes):
                continue
            out.extend(i for i in mod.functions if i.key not in self.traced)
        return sorted(out, key=lambda i: (i.path, i.lineno))
