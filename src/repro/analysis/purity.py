"""Trace-purity lint: the TP00x check family.

Runs over the :class:`~repro.analysis.callgraph.CallGraph`'s traced set
(everything reachable from a ``jax.jit``/``pallas_call``/``lax.*`` body)
plus the serve/train host drivers, and reports:

==========  =========  =====================================================
check id    severity   fires on
==========  =========  =====================================================
``TP001``   error      host transfers: ``jax.device_get`` /
                       ``block_until_ready`` / ``.item()`` / ``.tolist()``
                       anywhere in serve/train driver code or traced code;
                       ``np.asarray``/``np.array`` in traced code
``TP002``   error      ``float()``/``int()``/``bool()`` coercion of a
                       computed value in traced code (a guaranteed
                       ``ConcretizationTypeError`` or silent trace-time bake)
``TP003``   error      Python ``if``/``while`` branching on a device value
                       (``jnp.``/``lax.`` call or ``.any()``/``.all()`` in
                       the test) inside traced code
``TP004``   error      nondeterminism in traced code: stdlib ``random.*``,
                       ``np.random.*``, ``time.*`` (``jax.random`` is keyed
                       and deterministic — allowed)
``TP005``   error      a jitted entry point (``X = jax.jit(...)``) called
                       outside any ``profiling.annotate(...)`` scope in a
                       serve/train module — invisible to the PR 6 profiler
==========  =========  =====================================================

Sanctioned exceptions carry a pragma **on the offending line or the line
above**::

    buf_h = jax.device_get((buf, lens))  # analysis: allow(TP001)

``allow(host-transfer)`` (the slug) works too, as does a bare ``analysis:
allow`` to waive every check on that line.  Pragmas beat baseline entries:
the waiver lives next to the code it excuses.
"""
from __future__ import annotations

import ast
import re
from typing import List, Sequence, Set

from repro.analysis.callgraph import (CallGraph, FunctionInfo, ModuleInfo,
                                      _body_without_nested, dotted_name)
from repro.analysis.findings import Finding, SEV_ERROR

#: check id -> human slug (either form valid in a pragma)
SLUGS = {
    "TP001": "host-transfer",
    "TP002": "host-coercion",
    "TP003": "traced-control-flow",
    "TP004": "nondeterminism",
    "TP005": "missing-annotation",
}

#: subtrees whose drivers may host-sync only at pragma'd lines
DRIVER_PREFIXES = ("src/repro/serve", "src/repro/train")

_PRAGMA_RE = re.compile(r"#\s*analysis:\s*allow(?:\(([^)]*)\))?")

_HOST_TRANSFER_ATTRS = {"device_get", "block_until_ready"}
_HOST_METHODS = {"item", "tolist"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
_ANNOTATE_TAILS = {"annotate", "trace", "TraceSession"}
# call tails that inspect static metadata — legal in an if/while test
_STATIC_CALL_TAILS = {"dtype", "issubdtype", "result_type", "isdtype",
                      "isinstance", "len", "shape", "ndim"}


def pragma_line(mod: ModuleInfo, lineno: int, check_id: str):
    """Line number of the pragma waiving `check_id` at `lineno` (the line
    itself or the one above), or None — the pragma ledger needs to know
    *which* pragma ate a finding, not just that one did."""
    for ln in (lineno, lineno - 1):
        if not (1 <= ln <= len(mod.lines)):
            continue
        m = _PRAGMA_RE.search(mod.lines[ln - 1])
        if m is None:
            continue
        tokens = m.group(1)
        if tokens is None or not tokens.strip():
            return ln                         # bare allow: waive everything
        toks = {t.strip() for t in tokens.split(",")}
        if check_id in toks or SLUGS.get(check_id, "") in toks:
            return ln
    return None


def pragma_allows(mod: ModuleInfo, lineno: int, check_id: str) -> bool:
    """True when line `lineno` (or the line above) waives `check_id`."""
    return pragma_line(mod, lineno, check_id) is not None


def _is_numpy_alias(mod: ModuleInfo, base: str) -> bool:
    return mod.imports.get(base, "").split(".")[0] == "numpy"


def _in_try(node: ast.AST, fn_node: ast.AST) -> bool:
    """True when `node` sits under a try: — the tracer-probe idiom
    (``try: int(x)`` / ``except TracerError``) is a legal static test."""
    root = fn_node if not isinstance(fn_node, ast.Lambda) else fn_node.body
    for sub in ast.walk(root):
        if isinstance(sub, ast.Try):
            for inner in ast.walk(sub):
                if inner is node:
                    return True
    return False


class PurityChecker:
    """Run the TP00x family over one CallGraph.

    ``ledger`` (a :class:`repro.analysis.pragmas.PragmaLedger`, duck-typed
    on ``.record``) is told about every finding a pragma suppresses, so
    the PR900 unused-pragma check can tell live waivers from stale ones.
    """

    def __init__(self, graph: CallGraph, ledger=None):
        self.graph = graph
        self.ledger = ledger
        self.findings: List[Finding] = []

    # -- emit ---------------------------------------------------------------

    def _flag(self, check_id: str, mod: ModuleInfo, node: ast.AST,
              scope: str, message: str):
        waiver_ln = pragma_line(mod, node.lineno, check_id)
        if waiver_ln is not None:
            if self.ledger is not None:
                self.ledger.record(mod.path, waiver_ln, check_id)
            return
        self.findings.append(Finding(
            check_id=check_id, severity=SEV_ERROR, path=mod.path,
            line=node.lineno, scope=scope, message=message))

    # -- entry --------------------------------------------------------------

    def run(self) -> List[Finding]:
        for info in self.graph.traced_functions():
            self._check_traced(info)
        for info in self.graph.host_functions(DRIVER_PREFIXES):
            self._check_host_driver(info)
        for path, mod in sorted(self.graph.modules.items()):
            if path.startswith(DRIVER_PREFIXES):
                self._check_annotations(mod)
        return self.findings

    # -- traced-code checks --------------------------------------------------

    def _check_traced(self, info: FunctionInfo):
        mod = self.graph.modules[info.path]
        scope = info.qualname
        for node in _body_without_nested(info.node):
            if isinstance(node, ast.Call):
                self._traced_call(mod, info, node, scope)
            elif isinstance(node, (ast.If, ast.While)):
                self._traced_branch(mod, node, scope)

    def _traced_call(self, mod: ModuleInfo, info: FunctionInfo,
                     node: ast.Call, scope: str):
        dn = dotted_name(node.func) or ""
        parts = dn.split(".") if dn else []
        tail = parts[-1] if parts else ""
        base = parts[0] if parts else ""

        # TP001 — host transfers
        if tail in _HOST_TRANSFER_ATTRS:
            self._flag("TP001", mod, node, scope,
                       f"`{dn}` forces a host sync inside traced code")
        elif tail in _HOST_METHODS and len(parts) >= 2:
            self._flag("TP001", mod, node, scope,
                       f"`.{tail}()` materializes a traced value on host")
        elif tail in {"asarray", "array"} and _is_numpy_alias(mod, base):
            self._flag("TP001", mod, node, scope,
                       f"`{dn}` pulls a traced value to host numpy")

        # TP002 — host coercion of a computed value
        elif tail in {"float", "int", "bool"} and len(parts) == 1 \
                and node.args:
            if self._coerces_computed(node) and not _in_try(node, info.node):
                self._flag("TP002", mod, node, scope,
                           f"`{tail}()` on a computed value bakes it at "
                           f"trace time (or raises ConcretizationTypeError)")

        # TP004 — nondeterminism
        if base and len(parts) >= 2:
            target = mod.imports.get(base, base)
            head = target.split(".")[0]
            if head in {"random", "time"}:
                self._flag("TP004", mod, node, scope,
                           f"`{dn}` is host nondeterminism/clock state — "
                           f"baked in at trace time")
            elif head == "numpy" and "random" in parts:
                self._flag("TP004", mod, node, scope,
                           f"`{dn}` draws from host RNG at trace time; "
                           f"use jax.random with an explicit key")

    def _coerces_computed(self, node: ast.Call) -> bool:
        """Heuristic: the coercion argument involves a call or an indexing —
        the shapes real traced-value coercions take (``float(x.mean())``,
        ``int(cur[0])``).  Plain arithmetic on local names
        (``int(d * fraction)``) is static dim math and stays silent, as is
        anything built from ``.shape``/``.ndim``/``.dtype`` lookups."""
        for sub in ast.walk(node.args[0]):
            if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
                return False                   # .shape[...] math is static
        for sub in ast.walk(node.args[0]):
            if isinstance(sub, ast.Subscript):
                return True
            if isinstance(sub, ast.Call):
                if (dotted_name(sub.func) or "") == "len":
                    continue
                return True
        return False

    def _traced_branch(self, mod: ModuleInfo, node: ast.AST, scope: str):
        kind = "if" if isinstance(node, ast.If) else "while"
        for sub in ast.walk(node.test):
            if not isinstance(sub, ast.Call):
                continue
            dn = dotted_name(sub.func) or ""
            parts = dn.split(".")
            if len(parts) < 2:
                continue
            if parts[-1] in _STATIC_CALL_TAILS:
                continue          # dtype/shape introspection is trace-static
            head = mod.imports.get(parts[0], parts[0]).split(".")[0]
            if head == "jax" or parts[-1] in {"any", "all"}:
                self._flag(
                    "TP003", mod, node, scope,
                    f"Python `{kind}` on a device value (`{dn}` in the "
                    f"test) — use lax.cond/lax.while_loop or jnp.where")
                return

    # -- host-driver checks --------------------------------------------------

    def _check_host_driver(self, info: FunctionInfo):
        """In serve/train driver code only the pragma'd once-per-wave sync
        may transfer: every other device_get/block_until_ready is a leak."""
        mod = self.graph.modules[info.path]
        for node in _body_without_nested(info.node):
            if not isinstance(node, ast.Call):
                continue
            dn = dotted_name(node.func) or ""
            tail = dn.split(".")[-1] if dn else ""
            if tail in _HOST_TRANSFER_ATTRS:
                self._flag(
                    "TP001", mod, node, info.qualname,
                    f"`{dn}` in driver code outside the sanctioned "
                    f"per-wave sync (pragma the one blessed site)")

    # -- annotation coverage -------------------------------------------------

    def _jitted_names(self, mod: ModuleInfo) -> Set[str]:
        names: Set[str] = set()
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Assign):
                continue
            if not (isinstance(node.value, ast.Call)
                    and (dotted_name(node.value.func) or ""
                         ).split(".")[-1] == "jit"):
                continue
            for tgt in node.targets:
                if isinstance(tgt, ast.Attribute):
                    names.add(tgt.attr)
                elif isinstance(tgt, ast.Name):
                    names.add(tgt.id)
        return names

    def _check_annotations(self, mod: ModuleInfo):
        jitted = self._jitted_names(mod)
        if not jitted:
            return
        for info in mod.functions:
            if isinstance(info.node, ast.Lambda):
                continue
            self._walk_annotated(mod, info, jitted, info.node.body,
                                 annotated=False)

    def _is_annotate_with(self, stmt: ast.With) -> bool:
        for item in stmt.items:
            ctx = item.context_expr
            if isinstance(ctx, ast.Call):
                dn = dotted_name(ctx.func) or ""
                if dn.split(".")[-1] in _ANNOTATE_TAILS:
                    return True
        return False

    def _walk_annotated(self, mod: ModuleInfo, info: FunctionInfo,
                        jitted: Set[str], body: Sequence[ast.stmt],
                        annotated: bool):
        """Recurse through compound statements tracking whether execution is
        inside a ``with annotate(...)`` scope; flag jitted-entry calls that
        happen outside one."""
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue                        # its own scope
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                inner = annotated or self._is_annotate_with(stmt)
                self._walk_annotated(mod, info, jitted, stmt.body, inner)
                continue
            sub_bodies: List[Sequence[ast.stmt]] = []
            if isinstance(stmt, (ast.If, ast.For, ast.AsyncFor, ast.While)):
                sub_bodies = [stmt.body, stmt.orelse]
            elif isinstance(stmt, ast.Try):
                sub_bodies = [stmt.body, stmt.orelse, stmt.finalbody] + \
                    [h.body for h in stmt.handlers]
            for sb in sub_bodies:
                self._walk_annotated(mod, info, jitted, sb, annotated)
            if annotated:
                continue
            # a simple statement (or a compound header expression): any
            # call to a jitted entry here is un-annotated
            headers = ast.iter_child_nodes(stmt) if sub_bodies else [stmt]
            for header in headers:
                if isinstance(header, ast.stmt) and sub_bodies:
                    continue                    # bodies handled above
                for sub in ast.walk(header):
                    if not isinstance(sub, ast.Call):
                        continue
                    func = sub.func
                    name = func.attr if isinstance(func, ast.Attribute) \
                        else (func.id if isinstance(func, ast.Name) else "")
                    if name in jitted:
                        self._flag(
                            "TP005", mod, sub, info.qualname,
                            f"jitted entry `{name}` called outside any "
                            f"profiling.annotate(...) scope — invisible "
                            f"in trace breakdowns")
