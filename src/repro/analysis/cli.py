"""Static invariant checker CLI — the front door of ``repro.analysis``.

Runnable three ways (all the same entry point)::

    PYTHONPATH=src python -m repro.analysis <cmd>
    python scripts/analyze.py <cmd>               # thin compat shim
    repro-analyze <cmd>                           # installed console script

Subcommands::

    lint        trace-purity lint (TP00x) + unused-pragma check (PR900)
    artifacts   tuned-DB (AR00x) + bench-baseline (BA00x) validation
    coverage    sharding-rule coverage (SH00x) of all model families
    stats       Engine.stats() keys vs the versioned schema (ST001)
    ir          IR-level program contracts (IR000-IR005) over the dry-traced
                config matrix — see repro/analysis/ir/
    pragmas     list every `# analysis: allow(...)` site and what it eats
    report      lint+artifacts+coverage+stats (+ optional --ir leg) behind
                the committed-baseline ratchet gate (what CI runs)

Exit codes (asserted in tests/test_ir_checks.py)::

    0   clean — no findings beyond the committed baseline
    1   new findings (or --strict with any error finding)
    2   usage error (unknown flag/subcommand; argparse)

``report`` is the CI gate: errors not present in
``tests/analysis_baseline.json`` fail the build (exit 1); warnings are
printed but never fail.  ``--update-baseline`` blesses the current error
set as the new floor — shrink it, don't grow it.  ``--json FILE`` writes
the findings (any subcommand) for the step-summary renderer and the
uploaded artifact.

Run it locally before pushing::

    PYTHONPATH=src python -m repro.analysis report

Check catalog and waiver workflow: docs/STATIC_ANALYSIS.md.
"""
from __future__ import annotations

import argparse
import json
import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))


def _lint_findings():
    """-> (findings incl. PR900, graph, pragma sites, ledger)."""
    from repro.analysis import pragmas
    from repro.analysis.callgraph import CallGraph
    from repro.analysis.purity import PurityChecker
    graph = CallGraph(REPO_ROOT)
    ledger = pragmas.PragmaLedger()
    findings = PurityChecker(graph, ledger=ledger).run()
    sites = pragmas.scan_pragmas(graph)
    findings += pragmas.unused_pragma_findings(sites, ledger)
    return findings, graph, sites, ledger


def _artifact_findings():
    from repro.analysis.artifacts import (validate_baselines_dir,
                                          validate_tuned_dir)
    out = validate_tuned_dir(os.path.join(REPO_ROOT, "tuned"),
                             root=REPO_ROOT)
    out += validate_baselines_dir(
        os.path.join(REPO_ROOT, "benchmarks", "baselines"), root=REPO_ROOT)
    return out


def _coverage_findings():
    from repro.analysis.coverage import check_coverage
    return check_coverage()


def _stats_findings():
    from repro.analysis.stats_checks import check_stats_schema
    return check_stats_schema(REPO_ROOT)


def _ir_cases(args):
    from repro.analysis.ir.matrix import (DTYPES, FAMILIES, SCHEDULERS,
                                          default_matrix, smoke_matrix)
    if getattr(args, "smoke", False):
        return smoke_matrix()
    meshes = tuple(None if m in ("single", "none") else m
                   for m in (args.mesh or ["single"]))
    return default_matrix(
        mesh_specs=meshes,
        families=tuple(args.families.split(",")) if args.families
        else FAMILIES,
        schedulers=tuple(args.schedulers.split(",")) if args.schedulers
        else SCHEDULERS,
        dtypes=tuple(args.dtypes.split(",")) if args.dtypes else DTYPES)


def _run_ir(args):
    from repro.analysis.ir.runner import run_ir
    return run_ir(_ir_cases(args),
                  use_cache=not getattr(args, "no_cache", False),
                  cache_dir=getattr(args, "cache_dir", None),
                  write_fingerprints=getattr(args, "write_fingerprints",
                                             False),
                  fingerprint_path=getattr(args, "fingerprints", None))


def _emit(findings, args, extra_blob=None):
    from repro.analysis.findings import SEV_ERROR, sort_findings
    findings = sort_findings(findings)
    for f in findings:
        print(f.render())
    errors = [f for f in findings if f.severity == SEV_ERROR]
    warnings = [f for f in findings if f.severity != SEV_ERROR]
    print(f"[analyze] {len(errors)} error(s), {len(warnings)} warning(s)")
    if getattr(args, "json", None):
        blob = {"findings": [f.to_json() for f in findings],
                "errors": len(errors), "warnings": len(warnings)}
        blob.update(extra_blob or {})
        with open(args.json, "w") as fh:
            json.dump(blob, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"[analyze] wrote {args.json}")
    return errors, warnings


def _ratchet_gate(errors, warnings, baseline_path):
    """The shared exit-code policy: new errors beyond the baseline -> 1."""
    from repro.analysis.findings import load_baseline, ratchet
    baseline = load_baseline(baseline_path)
    new, fixed = ratchet(errors, baseline)
    if fixed:
        print(f"[analyze] {len(fixed)} baseline finding(s) no longer fire "
              f"— ratchet forward with --update-baseline:")
        for key in fixed:
            print(f"  fixed: {key}")
    if new:
        print(f"[analyze] FAIL: {len(new)} finding(s) not in the baseline "
              f"({len(baseline)} tolerated):")
        for f in new:
            print(f"  new: {f.render()}")
        print("[analyze] fix them, pragma a sanctioned exception "
              "(# analysis: allow(<id>)), or — exceptionally — bless with "
              "--update-baseline")
        return 1
    print(f"[analyze] ok: no findings beyond the baseline "
          f"({len(baseline)} tolerated, {len(warnings)} warning(s))")
    return 0


def _print_pragmas(sites, ledger):
    from repro.analysis.pragmas import pragma_table
    rows = pragma_table(sites, ledger)
    if not rows:
        print("[pragmas] no `# analysis: allow` pragmas in src/repro")
        return rows
    for r in rows:
        state = ("suppresses " + ", ".join(r["suppresses"]) if r["live"]
                 else "STALE (suppresses nothing -> PR900)")
        print(f"[pragmas] {r['path']}:{r['line']} "
              f"allow({', '.join(r['allows'])}) — {state}")
    live = sum(1 for r in rows if r["live"])
    print(f"[pragmas] {len(rows)} pragma(s), {live} live, "
          f"{len(rows) - live} stale")
    return rows


def cmd_lint(args):
    findings, graph, sites, ledger = _lint_findings()
    if args.verbose:
        for info in graph.traced_functions():
            print(f"[traced] {info.key}  <- {graph.traced_via[info.key]}")
    if args.list_pragmas:
        _print_pragmas(sites, ledger)
    errors, _ = _emit(findings, args,
                      {"traced_functions": len(graph.traced)})
    return 1 if errors and args.strict else 0


def cmd_artifacts(args):
    errors, _ = _emit(_artifact_findings(), args)
    return 1 if errors and args.strict else 0


def cmd_coverage(args):
    from repro.analysis.coverage import coverage_summary
    findings = _coverage_findings()
    summary = coverage_summary() if args.summary else None
    if summary:
        for family, kinds in summary.items():
            stat = ", ".join(
                f"{kind}: {v['sharded']}/{v['leaves']} leaves sharded"
                for kind, v in kinds.items())
            print(f"[coverage] {family}: {stat}")
    errors, _ = _emit(findings, args, {"coverage": summary} if summary
                      else None)
    return 1 if errors and args.strict else 0


def cmd_stats(args):
    errors, _ = _emit(_stats_findings(), args)
    return 1 if errors and args.strict else 0


def cmd_pragmas(args):
    _, _, sites, ledger = _lint_findings()
    rows = _print_pragmas(sites, ledger)
    if getattr(args, "json", None):
        with open(args.json, "w") as fh:
            json.dump({"pragmas": rows}, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"[analyze] wrote {args.json}")
    return 0


def cmd_ir(args):
    findings, blob = _run_ir(args)
    errors, warnings = _emit(findings, args, blob)
    if args.write_fingerprints:
        print(f"[analyze] fingerprints blessed -> {blob['blessed_path']} "
              f"({len(blob['ir_cases'])} case(s))")
        return 0
    return _ratchet_gate(errors, warnings, args.baseline)


def cmd_report(args):
    from repro.analysis.findings import save_baseline
    findings, graph, sites, ledger = _lint_findings()
    findings = (findings + _artifact_findings() + _coverage_findings()
                + _stats_findings())
    extra = {"traced_functions": len(graph.traced)}
    if args.ir != "off":
        args.smoke = args.ir == "smoke"
        ir_findings, ir_blob = _run_ir(args)
        findings += ir_findings
        extra.update(ir_blob)
    errors, warnings = _emit(findings, args, extra)

    if args.update_baseline:
        path = save_baseline(errors, args.baseline)
        print(f"[analyze] baseline blessed -> {path} "
              f"({len(errors)} finding(s))")
        return 0
    return _ratchet_gate(errors, warnings, args.baseline)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Static invariant checker (exit 0 clean / 1 new "
                    "findings / 2 usage error)",
        prog="repro-analyze")
    ap.add_argument("--list-pragmas", action="store_true",
                    help="shortcut for the `pragmas` subcommand")
    sub = ap.add_subparsers(dest="cmd")

    def common(p, strict_default=False):
        p.add_argument("--json", help="write findings JSON to this path")
        p.add_argument("--strict", action="store_true",
                       default=strict_default,
                       help="exit 1 on any error finding (no baseline)")

    p = sub.add_parser("lint", help="trace-purity lint (TP00x) + "
                                    "unused-pragma check (PR900)")
    common(p)
    p.add_argument("--verbose", action="store_true",
                   help="also print the traced function set")
    p.add_argument("--list-pragmas", action="store_true",
                   help="print the pragma ledger before the findings")
    p.set_defaults(fn=cmd_lint)

    p = sub.add_parser("artifacts",
                       help="tuned-DB + bench-baseline validation "
                            "(AR00x/BA00x)")
    common(p)
    p.set_defaults(fn=cmd_artifacts)

    p = sub.add_parser("coverage",
                       help="sharding-rule coverage of model families "
                            "(SH00x)")
    common(p)
    p.add_argument("--summary", action="store_true",
                   help="print per-family sharded-leaf statistics")
    p.set_defaults(fn=cmd_coverage)

    p = sub.add_parser("stats",
                       help="Engine.stats() key set vs the versioned "
                            "stats schema (ST001)")
    common(p)
    p.set_defaults(fn=cmd_stats)

    p = sub.add_parser("pragmas",
                       help="list `# analysis: allow` sites and what "
                            "each suppresses")
    p.add_argument("--json", help="write the pragma table to this path")
    p.set_defaults(fn=cmd_pragmas)

    def ir_flags(p):
        p.add_argument("--mesh", action="append",
                       help="mesh spec leg (repeatable); 'single' or "
                            "omit = 1 device")
        p.add_argument("--families", help="comma-separated family subset")
        p.add_argument("--schedulers", help="comma-separated scheduler "
                                            "subset")
        p.add_argument("--dtypes", help="comma-separated dtype subset")
        p.add_argument("--smoke", action="store_true",
                       help="one-family bf16 single-device smoke subset")
        p.add_argument("--no-cache", action="store_true",
                       help="retrace even when .ir_cache/ has a summary")
        p.add_argument("--cache-dir", help="summary cache dir "
                                           "(default .ir_cache/)")
        p.add_argument("--write-fingerprints", action="store_true",
                       help="bless the traced programs into "
                            "tests/ir_fingerprints.json (exit 0)")
        p.add_argument("--fingerprints",
                       help="fingerprint file (default "
                            "tests/ir_fingerprints.json)")

    p = sub.add_parser("ir",
                       help="IR program contracts (IR000-IR005) over the "
                            "dry-traced config matrix")
    p.add_argument("--json", help="write findings + IR report JSON")
    p.add_argument("--baseline",
                   help="ratchet file (default tests/analysis_baseline.json)")
    ir_flags(p)
    p.set_defaults(fn=cmd_ir)

    p = sub.add_parser("report",
                       help="all checks + the committed-baseline ratchet "
                            "gate (what CI runs)")
    p.add_argument("--json", help="write findings JSON to this path")
    p.add_argument("--baseline",
                   help="ratchet file (default tests/analysis_baseline.json)")
    p.add_argument("--update-baseline", action="store_true",
                   help="bless the current error findings as the new floor")
    p.add_argument("--ir", choices=("off", "smoke", "full"), default="off",
                   help="also run the IR matrix leg (default off; CI runs "
                        "dedicated `ir` legs instead)")
    ir_flags(p)
    p.set_defaults(fn=cmd_report)

    args = ap.parse_args(argv)
    if args.cmd is None:
        if args.list_pragmas:
            return cmd_pragmas(argparse.Namespace(json=None))
        ap.error("a subcommand is required (or --list-pragmas)")
    return args.fn(args)


if __name__ == "__main__":
    import sys
    sys.exit(main())
