"""Artifact validators: the AR00x (tuned DBs) and BA00x (bench baselines)
check families.

Committed artifacts are the paper's "Tab. 4 outside the kernel" made
durable — and durable artifacts rot silently: a profile's VMEM budget
shrinks, a bucketer changes its power-of-two policy, a mesh axis is
renamed, and the stale entry keeps winning lookups.  These checks re-derive
every entry's legality from the *current* ``HardwareProfile`` and current
tuning-space policy, so rot is a CI failure instead of a perf mystery.

==========  =========  =====================================================
check id    severity   fires on
==========  =========  =====================================================
``AR001``   error      tuned block misaligned for its profile
                       (``TileConfig.aligned`` / ``FlashAttentionConfig
                       .aligned`` against ``mxu_dim``/``sublane``)
``AR002``   error      tuned block's double-buffered working set exceeds
                       the profile's VMEM budget (``.fits``)
``AR003``   error      entry ``mesh`` label unparseable or using axes
                       outside ``launch.mesh.MESH_AXES``
``AR004``   warning    stale entry: bucketed dims no longer power-of-two,
                       unroll outside the decode tuning space, or a dtype
                       jnp cannot resolve — prunable via
                       ``scripts/tune.py verify --prune``
``AR005``   error      DB file name resolves to no registered
                       ``HardwareProfile`` (or the file is unloadable)
``BA001``   error      bench baseline missing/ill-typed ``rows`` /
                       ``name`` / ``us_per_call`` fields
``BA002``   warning    a row with zero ``us_per_call`` and no ``derived``
                       value — the PR 5 zero-baseline rule (warn, stay
                       neutral in the trend gate)
``BA003``   error      ``BENCH_<suite>__<hw>[-mesh].json`` filename whose
                       ``<hw>`` disagrees with the blob's ``hardware`` or
                       resolves to no profile
==========  =========  =====================================================
"""
from __future__ import annotations

import json
import os
import re
from typing import List, Optional, Tuple

import jax.numpy as jnp

from repro.analysis.findings import Finding, SEV_ERROR, SEV_WARNING
from repro.core.hardware import find_profile
from repro.core.registry import (OP_DECODE_LOOP, OP_FLASH_ATTENTION,
                                 OP_GEMM, OP_PAGED_ATTN)
from repro.core.tile_config import (DecodeLoopTuningSpace,
                                    PagedAttentionTuningSpace)
from repro.core.tuning_db import TuningDB, TuningDBError
from repro.launch.mesh import MESH_AXES

SLUGS = {
    "AR001": "tile-misaligned",
    "AR002": "vmem-overflow",
    "AR003": "bad-mesh-label",
    "AR004": "stale-entry",
    "AR005": "unknown-hardware",
    "BA001": "bench-schema",
    "BA002": "zero-baseline",
    "BA003": "bench-name-mismatch",
}

_MESH_LABEL_RE = re.compile(r"^([a-z]+\d+)(x[a-z]+\d+)*$")
# non-greedy axis name + trailing separator, or "xmodel2" would parse as
# one segment with an "xmodel" axis
_MESH_SEGMENT_RE = re.compile(r"([a-z]+?)(\d+)(?:x|$)")
_BENCH_NAME_RE = re.compile(r"^BENCH_(?P<suite>[a-z0-9_]+)__"
                            r"(?P<hw>[a-z0-9-]+?)(?P<mesh>-mesh)?\.json$")


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def _entry_label(rec) -> str:
    shape = "x".join(str(s) for s in rec.shape)
    label = f"{rec.op}/{rec.dtype}/{shape}"
    if rec.mesh:
        label += f"@{rec.mesh}"
    return label


def parse_mesh_label(label: str) -> Optional[List[Tuple[str, int]]]:
    """``"data4xmodel2"`` -> ``[("data", 4), ("model", 2)]``; None if the
    label is not of that shape at all."""
    if not _MESH_LABEL_RE.match(label or ""):
        return None
    return [(axis, int(size))
            for axis, size in _MESH_SEGMENT_RE.findall(label)]


def validate_tuning_db(path: str, rel: Optional[str] = None
                       ) -> List[Finding]:
    """AR00x checks for one ``tuned/<hardware>.json`` file."""
    rel = rel or path
    findings: List[Finding] = []

    def flag(check_id, severity, scope, message):
        findings.append(Finding(check_id=check_id, severity=severity,
                                path=rel, line=0, scope=scope,
                                message=message))

    try:
        db = TuningDB.from_file(path)
    except (TuningDBError, OSError) as e:
        flag("AR005", SEV_ERROR, "db", f"unloadable tuning DB: {e}")
        return findings

    hw = find_profile(db.hardware)
    if hw is None:
        flag("AR005", SEV_ERROR, "db",
             f"hardware {db.hardware!r} matches no registered "
             f"HardwareProfile — tuned entries can never be looked up")
        return findings
    stem = os.path.splitext(os.path.basename(path))[0]
    if find_profile(stem) is not hw:
        flag("AR005", SEV_ERROR, "db",
             f"file stem {stem!r} does not resolve to the blob's "
             f"hardware {db.hardware!r}")

    for rec in db.records():
        scope = _entry_label(rec)

        try:
            jnp.dtype(rec.dtype)
            dtype_ok = True
        except TypeError:
            dtype_ok = False
            flag("AR004", SEV_WARNING, scope,
                 f"dtype {rec.dtype!r} is not a resolvable jnp dtype — "
                 f"stale entry, prune with `tune.py verify --prune`")

        if rec.op == OP_GEMM and dtype_ok:
            cfg = rec.config
            if not cfg.aligned(hw, rec.dtype):
                flag("AR001", SEV_ERROR, scope,
                     f"block {cfg.label} misaligned for {hw.name} "
                     f"(mxu_dim={hw.mxu_dim}, sublane={hw.sublane}, "
                     f"dtype={rec.dtype})")
            if not cfg.fits(hw, rec.dtype):
                flag("AR002", SEV_ERROR, scope,
                     f"block {cfg.label} double-buffered working set "
                     f"exceeds {hw.name} VMEM ({hw.vmem_bytes} B)")
        elif rec.op == OP_FLASH_ATTENTION and dtype_ok:
            cfg = rec.config
            d = rec.shape[2]
            if not cfg.aligned(hw, rec.dtype):
                flag("AR001", SEV_ERROR, scope,
                     f"flash block {cfg.label} misaligned for {hw.name} "
                     f"(mxu_dim={hw.mxu_dim}, sublane={hw.sublane}, "
                     f"dtype={rec.dtype})")
            if not cfg.fits(hw, d, rec.dtype):
                flag("AR002", SEV_ERROR, scope,
                     f"flash block {cfg.label} working set exceeds "
                     f"{hw.name} VMEM at head dim {d}")
            if not (_is_pow2(rec.shape[0]) and _is_pow2(rec.shape[1])):
                flag("AR004", SEV_WARNING, scope,
                     f"sequence shape {rec.shape[:2]} is not the "
                     f"power-of-two the attention bucketer produces — "
                     f"stale key, never hit by a lookup")
        elif rec.op == OP_DECODE_LOOP:
            unroll = rec.block[0]
            space = tuple(DecodeLoopTuningSpace().unroll_candidates)
            if unroll not in space:
                flag("AR004", SEV_WARNING, scope,
                     f"unroll {unroll} outside the decode tuning space "
                     f"{space} — stale entry")
            if not all(_is_pow2(x) for x in rec.shape):
                flag("AR004", SEV_WARNING, scope,
                     f"decode shape {rec.shape} is not power-of-two "
                     f"bucketed — stale key, never hit by a lookup")
        elif rec.op == OP_PAGED_ATTN:
            page = rec.block[0]
            space = tuple(PagedAttentionTuningSpace().page_candidates)
            if page not in space:
                flag("AR004", SEV_WARNING, scope,
                     f"page_size {page} outside the paged-KV tuning space "
                     f"{space} — stale entry")
            if not all(_is_pow2(x) for x in rec.shape):
                flag("AR004", SEV_WARNING, scope,
                     f"paged-KV shape {rec.shape} is not power-of-two "
                     f"bucketed — stale key, never hit by a lookup")

        if rec.mesh is not None:
            segs = parse_mesh_label(rec.mesh)
            if segs is None:
                flag("AR003", SEV_ERROR, scope,
                     f"mesh label {rec.mesh!r} is not of the "
                     f"`axis<N>[xaxis<N>...]` form mesh_axis_label emits")
            else:
                bad = [a for a, _n in segs if a not in MESH_AXES]
                if bad:
                    flag("AR003", SEV_ERROR, scope,
                         f"mesh label {rec.mesh!r} uses axes {bad} "
                         f"outside MESH_AXES {MESH_AXES} — orphaned by "
                         f"every topology the launcher can build")
                elif any(n < 1 for _a, n in segs):
                    flag("AR003", SEV_ERROR, scope,
                         f"mesh label {rec.mesh!r} has a non-positive "
                         f"axis size")
    return findings


def validate_tuned_dir(tuned_dir: str, root: Optional[str] = None
                       ) -> List[Finding]:
    findings: List[Finding] = []
    if not os.path.isdir(tuned_dir):
        return findings
    for name in sorted(os.listdir(tuned_dir)):
        if not name.endswith(".json"):
            continue
        path = os.path.join(tuned_dir, name)
        rel = os.path.relpath(path, root) if root else path
        findings.extend(validate_tuning_db(path, rel))
    return findings


def validate_bench_baseline(path: str, rel: Optional[str] = None
                            ) -> List[Finding]:
    """BA00x checks for one ``benchmarks/baselines/BENCH_*.json``."""
    rel = rel or path
    findings: List[Finding] = []

    def flag(check_id, severity, scope, message):
        findings.append(Finding(check_id=check_id, severity=severity,
                                path=rel, line=0, scope=scope,
                                message=message))

    fname = os.path.basename(path)
    m = _BENCH_NAME_RE.match(fname)
    if m is None:
        flag("BA003", SEV_ERROR, "file",
             f"{fname!r} does not match BENCH_<suite>__<hw>[-mesh].json")
        return findings

    try:
        with open(path) as f:
            blob = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        flag("BA001", SEV_ERROR, "file", f"unreadable baseline: {e}")
        return findings

    rows = blob.get("rows")
    if not isinstance(rows, list) or not rows:
        flag("BA001", SEV_ERROR, "rows",
             "baseline has no `rows` list — nothing for the trend gate "
             "to compare")
        return findings

    hw_name = m.group("hw")
    if find_profile(hw_name) is None:
        flag("BA003", SEV_ERROR, "file",
             f"filename hardware {hw_name!r} matches no registered "
             f"HardwareProfile")
    blob_hw = blob.get("hardware")
    if blob_hw is not None and find_profile(blob_hw) is not find_profile(
            hw_name):
        flag("BA003", SEV_ERROR, "file",
             f"blob hardware {blob_hw!r} != filename hardware {hw_name!r}")
    if m.group("mesh") and not blob.get("mesh"):
        flag("BA003", SEV_ERROR, "file",
             "-mesh filename but the blob records no mesh spec")

    seen = set()
    for i, row in enumerate(rows):
        name = row.get("name") if isinstance(row, dict) else None
        scope = name or f"rows[{i}]"
        if not isinstance(row, dict) or not isinstance(name, str):
            flag("BA001", SEV_ERROR, scope,
                 f"row {i} is not an object with a string `name`")
            continue
        if name in seen:
            flag("BA001", SEV_ERROR, scope,
                 "duplicate row name — trend comparison is ambiguous")
        seen.add(name)
        us = row.get("us_per_call")
        if not isinstance(us, (int, float)) or us < 0:
            flag("BA001", SEV_ERROR, scope,
                 f"`us_per_call` must be a non-negative number, "
                 f"got {us!r}")
            continue
        if us == 0 and not row.get("derived"):
            # PR 5 zero-baseline rule: warn + neutral, never a ratio of 0
            flag("BA002", SEV_WARNING, scope,
                 "zero us_per_call with no derived value — the trend "
                 "gate treats this row as neutral; re-bless with a real "
                 "measurement")
    return findings


def validate_baselines_dir(baselines_dir: str, root: Optional[str] = None
                           ) -> List[Finding]:
    findings: List[Finding] = []
    if not os.path.isdir(baselines_dir):
        return findings
    for name in sorted(os.listdir(baselines_dir)):
        if not name.endswith(".json"):
            continue
        path = os.path.join(baselines_dir, name)
        rel = os.path.relpath(path, root) if root else path
        findings.extend(validate_bench_baseline(path, rel))
    return findings


# ---------------------------------------------------------------------------
# Staleness partition for `tune.py verify --prune`
# ---------------------------------------------------------------------------

def partition_stale(db: TuningDB) -> Tuple[List, List]:
    """Split a DB's records into (live, stale) by the AR004 policy — the
    prunable set `tune.py verify --prune` rewrites the file without."""
    live, stale = [], []
    decode_space = tuple(DecodeLoopTuningSpace().unroll_candidates)
    paged_space = tuple(PagedAttentionTuningSpace().page_candidates)
    for rec in db.records():
        bad = False
        try:
            jnp.dtype(rec.dtype)
        except TypeError:
            bad = True
        if rec.op == OP_FLASH_ATTENTION and not (
                _is_pow2(rec.shape[0]) and _is_pow2(rec.shape[1])):
            bad = True
        if rec.op == OP_DECODE_LOOP and (
                rec.block[0] not in decode_space
                or not all(_is_pow2(x) for x in rec.shape)):
            bad = True
        if rec.op == OP_PAGED_ATTN and (
                rec.block[0] not in paged_space
                or not all(_is_pow2(x) for x in rec.shape)):
            bad = True
        (stale if bad else live).append(rec)
    return live, stale
