"""Tests for the ``repro.analysis`` static invariant checker.

Three layers:

1. **Fixture lint** — a miniature repo tree under ``tests/fixtures/analysis``
   seeded with one instance of every TP00x violation (and a clean twin);
   each check must fire exactly where the fixture marks it and nowhere else.
2. **Artifact validators** — synthetic tuned DBs / bench baselines with
   known defects; each AR00x/BA00x check must reject its case.
3. **Ratchet + live gate** — baseline accept/round-trip semantics, and the
   real repo linted against the committed ``tests/analysis_baseline.json``
   (the same gate CI runs, so a violation fails locally before it fails CI).
"""
import json
import os

import pytest

from repro.analysis.artifacts import (parse_mesh_label,
                                      validate_bench_baseline,
                                      validate_tuning_db)
from repro.analysis.callgraph import CallGraph
from repro.analysis.findings import (Finding, SEV_ERROR, load_baseline,
                                     ratchet, save_baseline)
from repro.analysis.purity import PurityChecker
from repro.core.tuning_db import TuningDB, TuningRecord

FIXTURE_ROOT = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BAD_TRACED = "src/repro/kernels/bad_traced.py"
CLEAN_TRACED = "src/repro/kernels/clean_traced.py"
BAD_DRIVER = "src/repro/serve/bad_driver.py"


@pytest.fixture(scope="module")
def fixture_graph():
    return CallGraph(FIXTURE_ROOT, package_dir="src/repro")


@pytest.fixture(scope="module")
def fixture_findings(fixture_graph):
    return PurityChecker(fixture_graph).run()


# ---------------------------------------------------------------------------
# call graph
# ---------------------------------------------------------------------------

def test_traced_set_includes_jit_roots_and_callees(fixture_graph):
    traced = {i.qualname for i in fixture_graph.traced_functions()}
    assert {"kernel_bad", "kernel_calls_helper", "helper",
            "kernel_clean", "_model"} <= traced


def test_host_code_stays_out_of_traced_set(fixture_graph):
    traced = {i.qualname for i in fixture_graph.traced_functions()}
    assert "host_only" not in traced
    assert "serve_wave" not in traced
    assert "serve_wave_ok" not in traced


# ---------------------------------------------------------------------------
# TP00x purity checks against the fixtures
# ---------------------------------------------------------------------------

def _by_check(findings):
    out = {}
    for f in findings:
        out.setdefault(f.check_id, []).append(f)
    return out


def test_every_tp_check_fires_on_the_bad_fixtures(fixture_findings):
    by = _by_check(fixture_findings)
    counts = {k: len(v) for k, v in by.items()}
    assert counts == {"TP001": 3, "TP002": 2, "TP003": 1,
                      "TP004": 3, "TP005": 1}, [
        f.render() for f in fixture_findings]


def test_findings_anchor_to_the_marked_scopes(fixture_findings):
    by = _by_check(fixture_findings)
    assert {f.scope for f in by["TP002"]} == {"kernel_bad", "helper"}
    assert {f.scope for f in by["TP003"]} == {"kernel_bad"}
    assert {f.scope for f in by["TP005"]} == {"serve_wave"}
    driver_tp001 = [f for f in by["TP001"] if f.path == BAD_DRIVER]
    assert [f.scope for f in driver_tp001] == ["serve_wave"]


def test_clean_fixture_is_silent(fixture_findings):
    assert not [f for f in fixture_findings if f.path == CLEAN_TRACED]


def test_pragma_suppresses_the_sanctioned_sync(fixture_findings):
    # bad_traced.py has three device_get/asarray sites; the pragma'd one
    # must not appear, and serve_wave_ok's pragma'd driver sync neither
    tp001 = [f for f in fixture_findings if f.check_id == "TP001"]
    assert len([f for f in tp001 if f.path == BAD_TRACED]) == 2
    assert not [f for f in tp001 if f.scope == "serve_wave_ok"]


# ---------------------------------------------------------------------------
# baseline ratchet
# ---------------------------------------------------------------------------

def _finding(check="TP001", scope="f", path="src/x.py"):
    return Finding(check_id=check, severity=SEV_ERROR, path=path, line=7,
                   scope=scope, message="m")


def test_ratchet_accepts_baselined_findings(tmp_path):
    path = str(tmp_path / "baseline.json")
    current = [_finding(scope="a"), _finding(scope="b")]
    save_baseline(current, path)
    new, fixed = ratchet(current, load_baseline(path))
    assert new == [] and fixed == []


def test_ratchet_fails_on_any_new_finding(tmp_path):
    path = str(tmp_path / "baseline.json")
    save_baseline([_finding(scope="a")], path)
    extra = _finding(scope="b")
    new, fixed = ratchet([_finding(scope="a"), extra], load_baseline(path))
    assert new == [extra] and fixed == []


def test_ratchet_is_line_free_and_reports_fixed_keys(tmp_path):
    path = str(tmp_path / "baseline.json")
    save_baseline([_finding(scope="a"), _finding(scope="gone")], path)
    moved = Finding(check_id="TP001", severity=SEV_ERROR, path="src/x.py",
                    line=99, scope="a", message="m")   # same key, new line
    new, fixed = ratchet([moved], load_baseline(path))
    assert new == []
    assert fixed == ["TP001:src/x.py:gone"]


def test_missing_baseline_means_empty_floor(tmp_path):
    assert load_baseline(str(tmp_path / "nope.json")) == {}


def test_baseline_schema_version_is_enforced(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"schema_version": 99, "findings": []}))
    with pytest.raises(ValueError, match="schema_version"):
        load_baseline(str(path))


# ---------------------------------------------------------------------------
# artifact validators (AR00x / BA00x)
# ---------------------------------------------------------------------------

def _save_db(tmp_path, records, hardware="tpu-v5e", stem=None):
    db = TuningDB(hardware)
    for rec in records:
        db.add(rec, keep_best=False)
    path = str(tmp_path / f"{stem or hardware}.json")
    db.save(path)
    return path


def _checks(findings):
    return {f.check_id for f in findings}


def test_ar001_misaligned_block_rejected(tmp_path):
    path = _save_db(tmp_path, [
        TuningRecord.gemm("bfloat16", 512, 512, 512, 100, 100, 100)])
    assert "AR001" in _checks(validate_tuning_db(path))


def test_ar002_vmem_overflow_rejected(tmp_path):
    path = _save_db(tmp_path, [
        TuningRecord.gemm("float32", 8192, 8192, 8192, 4096, 4096, 4096)])
    assert "AR002" in _checks(validate_tuning_db(path))


def test_ar003_orphan_mesh_axis_rejected(tmp_path):
    path = _save_db(tmp_path, [
        TuningRecord.gemm("float32", 512, 512, 512, 128, 128, 128,
                          mesh="ring4")])
    assert "AR003" in _checks(validate_tuning_db(path))


def test_ar004_stale_decode_unroll_warned(tmp_path):
    path = _save_db(tmp_path, [
        TuningRecord(op="decode_loop", dtype="float32", shape=(8, 64),
                     block=(3,))])
    found = validate_tuning_db(path)
    assert "AR004" in _checks(found)
    assert all(f.severity != SEV_ERROR for f in found)


def test_ar005_unknown_hardware_rejected(tmp_path):
    path = _save_db(tmp_path, [], hardware="vax-9000", stem="vax-9000")
    assert "AR005" in _checks(validate_tuning_db(path))


def test_committed_record_passes_clean(tmp_path):
    path = _save_db(tmp_path, [
        TuningRecord.gemm("bfloat16", 512, 512, 512, 128, 128, 128,
                          mesh="data4xmodel2")])
    assert validate_tuning_db(path) == []


def _save_bench(tmp_path, fname, blob):
    path = str(tmp_path / fname)
    with open(path, "w") as f:
        json.dump(blob, f)
    return path


def test_ba001_missing_rows_rejected(tmp_path):
    path = _save_bench(tmp_path, "BENCH_gemm__tpu-v5e.json",
                       {"hardware": "tpu-v5e"})
    assert "BA001" in _checks(validate_bench_baseline(path))


def test_ba001_duplicate_names_rejected(tmp_path):
    rows = [{"name": "a", "us_per_call": 1.0},
            {"name": "a", "us_per_call": 2.0}]
    path = _save_bench(tmp_path, "BENCH_gemm__tpu-v5e.json", {"rows": rows})
    assert "BA001" in _checks(validate_bench_baseline(path))


def test_ba002_zero_baseline_warns_not_errors(tmp_path):
    rows = [{"name": "a", "us_per_call": 0}]
    path = _save_bench(tmp_path, "BENCH_gemm__tpu-v5e.json", {"rows": rows})
    found = validate_bench_baseline(path)
    assert _checks(found) == {"BA002"}
    assert all(f.severity != SEV_ERROR for f in found)


def test_ba003_hardware_mismatch_rejected(tmp_path):
    rows = [{"name": "a", "us_per_call": 1.0}]
    path = _save_bench(tmp_path, "BENCH_gemm__tpu-v5e.json",
                       {"rows": rows, "hardware": "cpu-interpret"})
    assert "BA003" in _checks(validate_bench_baseline(path))


def test_ba003_mesh_filename_needs_mesh_blob(tmp_path):
    rows = [{"name": "a", "us_per_call": 1.0}]
    path = _save_bench(tmp_path, "BENCH_serve__tpu-v5e-mesh.json",
                       {"rows": rows})
    assert "BA003" in _checks(validate_bench_baseline(path))


def test_good_bench_baseline_passes(tmp_path):
    rows = [{"name": "a", "us_per_call": 1.0},
            {"name": "b", "us_per_call": 0, "derived": True}]
    path = _save_bench(tmp_path, "BENCH_gemm__tpu-v5e.json",
                       {"rows": rows, "hardware": "tpu-v5e"})
    assert validate_bench_baseline(path) == []


def test_mesh_label_parser():
    assert parse_mesh_label("data4xmodel2") == [("data", 4), ("model", 2)]
    assert parse_mesh_label("model8") == [("model", 8)]
    assert parse_mesh_label("ring4") == [("ring", 4)]   # parses; AR003 later
    assert parse_mesh_label("4data") is None
    assert parse_mesh_label("") is None
    assert parse_mesh_label("dataxmodel") is None


# ---------------------------------------------------------------------------
# the live gate: the repo itself must satisfy its committed baseline
# ---------------------------------------------------------------------------

def test_repo_lint_matches_committed_baseline():
    graph = CallGraph(REPO_ROOT)
    findings = PurityChecker(graph).run()
    errors = [f for f in findings if f.severity == SEV_ERROR]
    new, _fixed = ratchet(errors, load_baseline())
    assert new == [], "new lint errors beyond tests/analysis_baseline.json:" \
        "\n" + "\n".join(f.render() for f in new)


# ---------------------------------------------------------------------------
# ST001: stats emission vs the versioned schema
# ---------------------------------------------------------------------------

_SYNTH_ENGINE = '''
class Engine:
    def __init__(self):
        self._stats = {"requests": 0, "tokens_generated": 0}

    def stats(self):
        out = dict(self._stats)
        out["scheduler"] = "wave"
        out["bogus_key"] = 1
        return out
'''


def test_st001_scan_sees_seed_literal_and_subscript_stores(tmp_path):
    from repro.analysis.stats_checks import emitted_stats_keys
    path = tmp_path / "engine.py"
    path.write_text(_SYNTH_ENGINE)
    keys, line = emitted_stats_keys(str(path))
    assert {"requests", "tokens_generated", "scheduler", "bogus_key"} == keys
    assert line > 0


def test_st001_flags_drift_in_both_directions(tmp_path):
    from repro.analysis.stats_checks import check_stats_schema
    (tmp_path / "sub").mkdir()
    (tmp_path / "sub" / "engine.py").write_text(_SYNTH_ENGINE)
    found = check_stats_schema(str(tmp_path), os.path.join("sub", "engine.py"))
    scopes = {f.scope for f in found}
    assert "stats.bogus_key" in scopes          # emitted, not documented
    assert "stats.schema_version" in scopes     # documented, not emitted
    assert all(f.check_id == "ST001" and f.severity == SEV_ERROR
               for f in found)


def test_st001_live_engine_matches_schema_exactly():
    """The gate CI runs: the real engine.py and stats_schema agree."""
    from repro.analysis.stats_checks import check_stats_schema
    found = check_stats_schema(REPO_ROOT)
    assert found == [], "\n".join(f.render() for f in found)
