"""Device-resident continuous-batching engine: ragged parity (chunked and
flash prefill), EOS in the fused loop, slot reuse, input validation, and the
one-host-transfer-per-call regression guard."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.catalog import ARCHITECTURES
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig, generate_per_prompt


def _build(arch="llama3.2-1b", attention_impl=None, **serve_kw):
    cfg = ARCHITECTURES[arch].reduced()
    if attention_impl:
        cfg = dataclasses.replace(cfg, attention_impl=attention_impl)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    kw = dict(max_batch=3, max_len=64)
    kw.update(serve_kw)
    return cfg, model, params, Engine(model, params, ServeConfig(**kw))


RAGGED = [[5, 9, 2, 7], [1, 3, 3], [2, 4, 6, 8, 1, 5, 3]]


def test_ragged_batch_matches_single_prompt_generation():
    """Satellite bug: shorter prompts in a ragged batch used to attend to
    pad tokens.  Now every row decodes exactly what it decodes alone."""
    cfg, model, params, eng = _build()
    batched = eng.generate(RAGGED, 5)
    singles = [eng.generate([p], 5)[0] for p in RAGGED]
    assert batched == singles


def test_ragged_batch_matches_reference_loop():
    """Parity against the unpadded batch-1 reference loop (no engine code in
    the oracle path)."""
    cfg, model, params, eng = _build()
    batched = eng.generate(RAGGED, 5)
    oracle = generate_per_prompt(model, params, RAGGED, 5, max_len=64)
    assert batched == oracle


def test_ragged_parity_ssm_and_hybrid():
    """SSM/hybrid pad-zeroing keeps the recurrent state of short prompts
    identical to their solo run."""
    for arch in ("mamba2-130m", "zamba2-2.7b"):
        cfg, model, params, eng = _build(arch)
        batched = eng.generate(RAGGED, 4)
        singles = [eng.generate([p], 4)[0] for p in RAGGED]
        assert batched == singles, arch


# one representative per model family (dense / moe / vlm / audio / hybrid);
# mamba2 (ssm) is attention-free, so the hybrid carries the SSM-side check
FLASH_FAMILIES = ["llama3.2-1b", "olmoe-1b-7b", "llama-3.2-vision-11b",
                  "whisper-large-v3", "zamba2-2.7b"]


@pytest.mark.parametrize("arch", FLASH_FAMILIES)
def test_flash_prefill_ragged_parity_all_families(arch):
    """Tentpole acceptance: with attention_impl="flash" the engine's ragged
    prefill routes through the tuned flash kernel and still matches the
    unpadded batch-1 oracle token-for-token."""
    cfg, model, params, eng = _build(arch, attention_impl="flash")
    prompts = [[t % cfg.vocab_size for t in p] for p in RAGGED]
    extra = {k: jnp.zeros((len(prompts),) + s.shape[1:], s.dtype)
             for k, s in model.extra_inputs(len(prompts)).items()}
    batched = eng.generate(prompts, 5, extra_inputs=extra or None)
    oracle = generate_per_prompt(model, params, prompts, 5, max_len=64,
                                 extra_inputs=extra or None)
    assert batched == oracle, arch


def test_flash_prefill_non_divisible_prompt_length():
    """A prompt length that is not divisible by the (tuned or default) bq
    exercises the kernel's internal left-padding inside the engine."""
    cfg, model, params, eng = _build(attention_impl="flash", max_len=128)
    prompts = [[(i * 7 + 3) % cfg.vocab_size for i in range(37)],
               [(i * 5 + 1) % cfg.vocab_size for i in range(11)]]
    batched = eng.generate(prompts, 4)
    oracle = generate_per_prompt(model, params, prompts, 4, max_len=128)
    assert batched == oracle


def test_flash_prefill_provenance_in_stats():
    """Engine.stats() must surface which tuned (bq, bk) blocks prefill used
    and which registry tier satisfied the lookup."""
    cfg, model, params, eng = _build(attention_impl="flash")
    eng.generate([[1, 2, 3]], 2)
    st = eng.stats()
    lookups = st["prefill_flash_lookups"]
    assert lookups, "flash prefill lookups were not recorded"
    for shape, info in lookups.items():
        assert info["source"] in ("exact", "nearest", "generic", "default",
                                  "fallback")
        assert "x" in info["tile"]
    # chunked engines don't report flash provenance
    _, _, _, eng_c = _build()
    eng_c.generate([[1, 2, 3]], 2)
    assert eng_c.stats()["prefill_flash_lookups"] == {}


def test_eos_stops_inside_fused_loop():
    cfg, model, params, eng = _build(max_batch=2)
    # second token of the free-running generation, used as EOS below
    free = eng.generate([[3, 1, 4]], 6)[0]
    eos = free[1]
    eng_eos = Engine(model, params, ServeConfig(max_batch=2, max_len=64,
                                                eos_token=eos))
    if free[0] == eos:              # degenerate repeat: stops on first token
        assert eng_eos.generate([[3, 1, 4]], 6)[0] == free[:1]
        return
    out = eng_eos.generate([[3, 1, 4]], 6)[0]
    assert out == free[:2]          # EOS itself is kept, nothing after it
    # EOS applies per slot: pair a stopping row with a free-running one
    outs = eng_eos.generate([[3, 1, 4], [1, 3, 3]], 6)
    assert outs[0] == free[:2]
    assert len(outs[1]) in range(1, 7)


def test_slot_reuse_across_generate_calls():
    cfg, model, params, eng = _build()
    first = eng.generate(RAGGED, 5)
    second = eng.generate(RAGGED, 5)
    assert first == second          # stale slot KV never leaks into a rerun
    st = eng.stats()
    assert st["cache_allocs"] == 1  # one KV pool for the engine's lifetime
    assert st["slot_reuses"] >= 3
    assert st["slots_admitted"] == st["slots_evicted"] == 6


def test_more_prompts_than_slots_run_in_waves():
    cfg, model, params, eng = _build(max_batch=2, scheduler="wave")
    prompts = RAGGED + [[9, 9, 1]]
    outs = eng.generate(prompts, 4)
    waves = eng.stats()["waves"]
    assert waves == 2
    assert eng.stats()["device_transfers"] == waves   # one fetch per wave
    singles = [eng.generate([p], 4)[0] for p in prompts]
    assert outs == singles


def test_exactly_one_host_transfer_per_generate(monkeypatch):
    """Regression guard for the tentpole: the decode loop must not sync the
    host per token — one device_get per generate call (chunked continuous decode has its own
    transfer contract — see test_recompile_count.py)."""
    cfg, model, params, eng = _build(scheduler="wave")
    eng.generate(RAGGED, 6)                      # compile outside the count
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda *a, **k: (
        calls.append(1), real(*a, **k))[1])
    eng.generate(RAGGED, 6)
    assert len(calls) == 1
    calls.clear()
    eng.generate([[1, 2]], 3)
    assert len(calls) == 1


def test_empty_prompt_and_empty_batch_raise():
    cfg, model, params, eng = _build()
    with pytest.raises(ValueError, match="at least one prompt"):
        eng.generate([], 4)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.generate([[1, 2], []], 4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.generate([[1, 2]], 0)


def test_overlong_request_raises_without_leaking_slots():
    # wave semantics: the continuous scheduler admits this request (12 + 8
    # fits its token pool); test_continuous_token_capacity covers that path
    cfg, model, params, eng = _build(max_len=16, scheduler="wave")
    with pytest.raises(ValueError, match="exceeds"):
        eng.generate([[1] * 12], 8)
    # the rejected request must not have consumed a slot
    outs = eng.generate([[1, 2]], 3)
    assert len(outs[0]) == 3


def test_mixed_wave_capacity_no_over_rejection():
    """Headline bugfix: the engine used to reject a wave when
    max(prompt) + max(max_new) ACROSS the wave exceeded max_len, even though
    each request fit on its own.  Wave packing must schedule a
    long-prompt/small-budget and a short-prompt/big-budget request into
    separate waves and complete both."""
    cfg, model, params, eng = _build(max_batch=2, max_len=16,
                                      scheduler="wave")
    h_a = eng.submit(Request(prompt=[1] * 12, max_new_tokens=3))
    h_b = eng.submit(Request(prompt=[2, 3], max_new_tokens=12))
    eng.run()                           # used to raise: 12 + 12 > 16
    assert len(h_a.result(timeout=0).tokens) == 3
    assert len(h_b.result(timeout=0).tokens) == 12
    assert eng.stats()["waves"] == 2    # packed apart, not rejected together
    # each request decodes exactly what it decodes alone
    assert h_a.result(timeout=0).tokens == eng.generate([[1] * 12], 3)[0]
    assert h_b.result(timeout=0).tokens == eng.generate([[2, 3]], 12)[0]


def test_wave_packing_keeps_compatible_requests_batched():
    """Packing must not needlessly split: requests that fit jointly still
    share one wave (one prefill + one fused decode)."""
    cfg, model, params, eng = _build(max_batch=3, max_len=64,
                                      scheduler="wave")
    for p in RAGGED:
        eng.submit(Request(prompt=p, max_new_tokens=5))
    results = eng.run()
    assert eng.stats()["waves"] == 1
    assert len(results) == 3


def test_submit_rejects_oversized_request_fast():
    """Per-request validation at enqueue time: an oversized request fails at
    submit() instead of bricking the wave it would have joined."""
    cfg, model, params, eng = _build(max_len=16, scheduler="wave")
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(Request(prompt=[1] * 12, max_new_tokens=8))  # 12+8 > 16
    assert eng.stats()["requests"] == 0
    # the queue is untouched: a valid request still round-trips
    h = eng.submit(Request(prompt=[1, 2], max_new_tokens=3))
    eng.run()
    assert len(h.result(timeout=0).tokens) == 3


def test_near_capacity_bucket_clamped_to_max_len():
    """Satellite bugfix: _bucket_len used to overshoot max_len for
    near-capacity prompts, falling back to exact per-length pad sizes (a
    recompile per distinct prompt length).  The clamped bucket keeps nearby
    long prompts in ONE bucket — and stays token-for-token exact."""
    from repro.serve import generate_per_prompt
    cfg, model, params, eng = _build(max_len=48, scheduler="wave")
    for plen in (38, 40):
        prompt = [(i * 7 + 3) % cfg.vocab_size for i in range(plen)]
        out = eng.generate([prompt], 4)[0]
        assert out == generate_per_prompt(model, params, [prompt], 4,
                                          max_len=48)[0]
    buckets = eng.stats()["prefill_plen_buckets"]
    assert len(buckets) == 1, buckets   # 38 and 40 share one clamped bucket
    assert buckets[0] + 4 <= 48         # and it honours the slot capacity


def test_submit_run_queue_api():
    cfg, model, params, eng = _build(max_batch=2)
    handles = [eng.submit(Request(prompt=p, max_new_tokens=4))
               for p in RAGGED]
    results = eng.run()
    assert sorted(r.request_id for r in results) == \
        sorted(h.request_id for h in handles)
    assert handles[0].result(timeout=0).tokens == \
        eng.generate([RAGGED[0]], 4)[0]


def test_run_with_extras_requires_rows():
    cfg, model, params, eng = _build("whisper-large-v3", max_batch=2)
    extra = {k: jax.numpy.zeros((1,) + sds.shape[1:], sds.dtype)
             for k, sds in model.extra_inputs(1).items()}
    # no row= -> can't index extras
    eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=2))
    with pytest.raises(ValueError, match="row"):
        eng.run(extra_inputs=extra)


def test_engine_stats_surface_tile_provenance():
    cfg, model, params, eng = _build()
    eng.generate([[1, 2, 3]], 2)
    st = eng.stats()
    lookups = st["decode_tile_lookups"]
    assert lookups, "decode GEMM shapes were not traced"
    for shape, info in lookups.items():
        assert info["source"] in ("exact", "nearest", "generic", "default",
                                  "fallback")
        assert "x" in info["tile"]
    assert st["registry_hit_stats"]


def test_first_sample_key_decorrelated_from_loop():
    """Satellite bug: the first token used to be sampled with the parent
    PRNG key that the loop then split again, correlating the first two
    samples.  Pin the fixed key schedule with an oracle: the first token
    must come from a fresh split, not from the wave key itself."""
    cfg, model, params, eng = _build(temperature=1.5, max_batch=1,
                                     scheduler="wave")
    out = eng.generate([[1, 2, 3, 4]], 1)[0]
    # oracle: replicate the engine's padding (bucket 8, pad token 0) and
    # key schedule (seed key -> per-wave split -> pre-sample split)
    batch = {"tokens": jnp.asarray([[0, 0, 0, 0, 1, 2, 3, 4]], jnp.int32),
             "kv_start": jnp.asarray([4], jnp.int32)}
    logits, _ = jax.jit(model.prefill)(params, batch, model.init_cache(1, 64))
    _, wave_key = jax.random.split(jax.random.PRNGKey(0))
    _, sub = jax.random.split(wave_key)
    expected = int(jax.random.categorical(sub, logits / 1.5, axis=-1)[0])
    buggy = int(jax.random.categorical(wave_key, logits / 1.5, axis=-1)[0])
    assert out[0] == expected
    assert expected != buggy        # the regression is distinguishable
    # same seed -> deterministic across engines
    cfg2, model2, params2, eng2 = _build(temperature=1.5, max_batch=1,
                                         scheduler="wave")
    assert eng2.generate([[1, 2, 3, 4]], 1)[0] == out


def test_failed_call_frees_slots_and_queue():
    """A request that dies mid-wave (here: whisper without its required
    encoder_embeds) must neither leak its KV slot nor leave queued requests
    behind for the next call."""
    cfg, model, params, eng = _build("whisper-large-v3", max_batch=1)
    with pytest.raises(KeyError):
        eng.generate([[1, 2, 3]], 2)
    extra = {k: jnp.zeros((1,) + sds.shape[1:], sds.dtype)
             for k, sds in model.extra_inputs(1).items()}
    outs = eng.generate([[1, 2, 3]], 2, extra_inputs=extra)
    assert len(outs[0]) == 2
    st = eng.stats()
    assert st["slots_admitted"] == st["slots_evicted"]


def test_varied_max_new_shares_one_decode_compile():
    """max_new is bucketed before becoming the loop's static width, so
    near-miss budgets don't each pay a full while_loop compile — and the
    bucket must not change the tokens produced."""
    cfg, model, params, eng = _build()
    a = eng.generate(RAGGED, 5)
    b = eng.generate(RAGGED, 6)     # same bucket (8) as 5
    assert [x[:5] for x in b] == a  # shared prefix: bucketing is invisible


def test_decode_unroll_config_and_heuristic_provenance():
    """ServeConfig.decode_unroll is the top of the resolution order; with no
    config and no tuned entry, a single-device engine falls back to the
    u1 heuristic.  Both value and provenance surface in stats()."""
    cfg, model, params, eng = _build(decode_unroll=2)
    out_u2 = eng.generate(RAGGED, 5)
    st = eng.stats()
    assert st["decode_unroll"] == 2
    assert st["decode_unroll_source"] == "config"
    _, _, _, eng_h = _build()
    out_u1 = eng_h.generate(RAGGED, 5)
    st = eng_h.stats()
    assert st["decode_unroll"] == 1
    assert st["decode_unroll_source"] == "heuristic"
    # the unroll changes the loop schedule, never the tokens
    assert out_u2 == out_u1


def test_decode_unroll_tuned_entry_resolves_and_keeps_parity():
    """A decode_loop entry in the registry (shape = (max_batch, max_len))
    must win over the heuristic, report tuned provenance, and decode the
    same tokens as an unrolled=1 engine."""
    from repro.core import (GLOBAL_REGISTRY, OP_DECODE_LOOP, DecodeLoopConfig)
    import jax.numpy as _jnp
    cfg, model, params, _ = _build()
    dt = _jnp.dtype(cfg.dtype).name
    GLOBAL_REGISTRY.put_op(OP_DECODE_LOOP, DecodeLoopConfig(2),
                           "cpu-interpret", cfg.dtype, (3, 64))
    try:
        eng = Engine(model, params,
                     ServeConfig(max_batch=3, max_len=64,
                                 hardware="cpu-interpret"))
        out = eng.generate(RAGGED, 5)
        st = eng.stats()
        assert st["decode_unroll"] == 2
        assert st["decode_unroll_source"] == "tuned:exact"
        ref = Engine(model, params,
                     ServeConfig(max_batch=3, max_len=64, decode_unroll=1,
                                 hardware="cpu-interpret"))
        assert out == ref.generate(RAGGED, 5)
    finally:
        # drop the entry: provenance assertions elsewhere expect a clean
        # registry (nearest-tier would otherwise satisfy nearby shapes)
        GLOBAL_REGISTRY._exact.pop((OP_DECODE_LOOP, "cpu-interpret", dt),
                                   None)


# -- continuous scheduler (paged KV cache) -----------------------------------

@pytest.mark.parametrize("arch", FLASH_FAMILIES)
def test_continuous_matches_wave_engine_all_families(arch):
    """Tentpole acceptance: the paged continuous engine is token-for-token
    identical to the wave engine AND the per-prompt oracle across every
    model family, on ragged prompts with flash prefill."""
    cfg, model, params, eng_c = _build(arch, attention_impl="flash")
    eng_w = Engine(model, params, ServeConfig(max_batch=3, max_len=64,
                                              scheduler="wave"))
    prompts = [[t % cfg.vocab_size for t in p] for p in RAGGED]
    extra = {k: jnp.zeros((len(prompts),) + s.shape[1:], s.dtype)
             for k, s in model.extra_inputs(len(prompts)).items()}
    out_c = eng_c.generate(prompts, 5, extra_inputs=extra or None)
    out_w = eng_w.generate(prompts, 5, extra_inputs=extra or None)
    assert out_c == out_w, arch
    oracle = generate_per_prompt(model, params, prompts, 5, max_len=64,
                                 extra_inputs=extra or None)
    assert out_c == oracle, arch
    assert eng_c.stats()["scheduler"] == "continuous"


def test_continuous_falls_back_to_wave_for_ssm_and_kv_quant():
    """Models with no self-attention KV (pure SSM) or an int8-quantized
    cache transparently keep the wave path, with the reason in stats()."""
    cfg, model, params, eng = _build("mamba2-130m")
    assert eng.stats()["scheduler"] == "wave"
    assert "KV" in eng.stats()["scheduler_forced"]
    out = eng.generate(RAGGED, 4)
    assert out == [eng.generate([p], 4)[0] for p in RAGGED]


def test_continuous_token_capacity_admits_beyond_max_len():
    """Satellite fix: submit() used to enforce prompt + max_new <= max_len
    even for the paged engine, whose true constraint is the token pool.
    12 + 8 > max_len=16 but fits the 3 * 16 = 48-token pool."""
    cfg, model, params, eng = _build(max_len=16)
    assert eng.stats()["capacity_tokens"] == 48
    out = eng.generate([[1] * 12], 8)[0]
    assert out == generate_per_prompt(model, params, [[1] * 12], 8,
                                      max_len=32)[0]
    # the pool itself still bounds a single request, at submit time
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(Request(prompt=[1] * 12, max_new_tokens=48))
    assert eng.stats()["requests"] == 1      # the rejected one never queued
    with pytest.raises(ValueError, match="exceeds"):
        eng.generate([[1] * 12], 48)


def test_continuous_stats_report_paged_provenance():
    """stats() must surface the paged-cache telemetry: page size + its
    resolution provenance, pool utilization, and the admission/eviction/
    preemption counters."""
    cfg, model, params, eng = _build(page_size=4)
    eng.generate(RAGGED, 5)
    st = eng.stats()
    assert st["scheduler"] == "continuous"
    assert st["scheduler_forced"] is None
    assert st["page_size"] == 4
    assert st["page_size_source"] == "config"
    assert st["admissions"] == st["evictions"] == 3
    assert st["preemptions"] == 0
    pages = st["pages"]
    assert pages["page_size"] == 4
    # drained pool: the only pages still out are the prefix cache's pins
    assert pages["used_pages"] == st["prefix_cache"]["pinned_pages"]
    assert pages["high_water_pages"] > 0
    assert 0.0 <= pages["utilization"] <= 1.0
    eng.clear_prefix_cache()
    pages = eng.stats()["pages"]
    assert pages["used_pages"] == 0          # cache cleared: all pages home
    assert pages["free_pages"] == pages["usable_pages"]
    assert pages["alloc_count"] == pages["free_count"]
    assert st["chunks"] >= 1
    assert st["admission_prefills"] >= 1
    # with no explicit page_size the tuned paged_attn entry resolves it
    _, _, _, eng_t = _build(hardware="cpu-interpret")
    eng_t.generate([[1, 2, 3]], 2)
    src = eng_t.stats()["page_size_source"]
    assert src.startswith("tuned:") or src in ("default", "fallback")


def test_continuous_preemption_restart_is_exact():
    """A pool too small for every row's chunk growth forces youngest-first
    preemption; victims requeue at the front, restart cleanly, and still
    decode their exact solo tokens (greedy determinism)."""
    cfg, model, params, eng = _build(capacity_tokens=40, page_size=8)
    prompts = RAGGED + [[9, 9, 1]]
    handles = [eng.submit(Request(prompt=p, max_new_tokens=10))
               for p in prompts]
    eng.run()
    st = eng.stats()
    assert st["preemptions"] >= 1
    # drained: only the prefix cache's pins are still out
    assert st["pages"]["used_pages"] == st["prefix_cache"]["pinned_pages"]
    eng.clear_prefix_cache()
    assert eng.stats()["pages"]["used_pages"] == 0
    for h, p in zip(handles, prompts):
        assert h.result(timeout=0).tokens == generate_per_prompt(
            model, params, [p], 10, max_len=64)[0]
