"""Pallas GEMM kernel vs pure-jnp oracle: shape/dtype/epilogue sweeps +
hypothesis property tests (task-required per-kernel validation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, strategies as st

from repro.core.tile_config import TileConfig
from repro.kernels import ops
from repro.kernels.gemm import gemm_pallas
from repro.kernels.ref import gemm_ref

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


SHAPES = [
    (8, 16, 8), (32, 32, 32), (33, 65, 17), (64, 128, 96),
    (100, 100, 100), (1, 256, 7), (128, 64, 128),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_gemm_shape_dtype_sweep(m, k, n, dtype):
    a, b = _rand((m, k), dtype, 1), _rand((k, n), dtype, 2)
    cfg = TileConfig(16, 32, 16)
    out = ops.gemm(a, b, config=cfg, backend=ops.BACKEND_PALLAS_INTERPRET)
    ref = gemm_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("activation", [None, "relu", "gelu", "silu", "tanh"])
def test_gemm_epilogues(activation):
    m, k, n = 48, 64, 40
    a, b = _rand((m, k), jnp.float32, 3), _rand((k, n), jnp.float32, 4)
    bias = _rand((n,), jnp.float32, 5)
    cfg = TileConfig(16, 16, 16)
    out = ops.gemm(a, b, config=cfg, backend=ops.BACKEND_PALLAS_INTERPRET,
                   bias=bias, activation=activation)
    ref = gemm_ref(a, b, bias=bias, activation=activation)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_gemm_alpha_beta_full_form():
    """Paper Eq. 1: C = alpha*A@B + beta*C."""
    m, k, n = 32, 48, 32
    a, b = _rand((m, k), jnp.float32, 6), _rand((k, n), jnp.float32, 7)
    c = _rand((m, n), jnp.float32, 8)
    out = gemm_pallas(a, b, c, bm=16, bk=16, bn=16, alpha=1.7, beta=0.3,
                      interpret=True)
    ref = gemm_ref(a, b, c, alpha=1.7, beta=0.3)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_gemm_out_dtype_override():
    a, b = _rand((32, 32), jnp.bfloat16, 9), _rand((32, 32), jnp.bfloat16, 10)
    out = ops.gemm(a, b, config=TileConfig(16, 16, 16),
                   backend=ops.BACKEND_PALLAS_INTERPRET, out_dtype=jnp.float32)
    assert out.dtype == jnp.float32


def test_batched_gemm():
    a = _rand((3, 2, 16, 24), jnp.float32, 11)
    b = _rand((3, 2, 24, 8), jnp.float32, 12)
    out = ops.batched_gemm(a, b, config=TileConfig(8, 8, 8),
                           backend=ops.BACKEND_PALLAS_INTERPRET)
    ref = jnp.einsum("...ij,...jk->...ik", a, b)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_all_backends_agree():
    a, b = _rand((40, 56, ), jnp.float32, 13).reshape(40, 56), _rand((56, 24), jnp.float32, 14)
    outs = {}
    for backend in (ops.BACKEND_REF, ops.BACKEND_XLA, ops.BACKEND_PALLAS_INTERPRET):
        outs[backend] = ops.gemm(a, b, config=TileConfig(8, 8, 8), backend=backend)
    for backend, out in outs.items():
        np.testing.assert_allclose(out, outs[ops.BACKEND_REF], rtol=1e-5,
                                   atol=1e-5, err_msg=backend)


# ---------------------------------------------------------------------------
# Property-based invariants (hypothesis)
# ---------------------------------------------------------------------------

small = st.integers(min_value=1, max_value=24)


@settings(max_examples=15, deadline=None)
@given(m=small, k=small, n=small, seed=st.integers(0, 2**16))
def test_property_matches_oracle(m, k, n, seed):
    a, b = _rand((m, k), jnp.float32, seed), _rand((k, n), jnp.float32, seed + 1)
    out = ops.gemm(a, b, config=TileConfig(8, 8, 8),
                   backend=ops.BACKEND_PALLAS_INTERPRET)
    np.testing.assert_allclose(out, gemm_ref(a, b), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(m=small, k=small, seed=st.integers(0, 2**16))
def test_property_identity(m, k, seed):
    """A @ I == A (exactly representable)."""
    a = _rand((m, k), jnp.float32, seed)
    eye = jnp.eye(k, dtype=jnp.float32)
    out = ops.gemm(a, eye, config=TileConfig(8, 8, 8),
                   backend=ops.BACKEND_PALLAS_INTERPRET)
    np.testing.assert_allclose(out, a, rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(m=small, k=small, n=small, seed=st.integers(0, 2**16))
def test_property_linearity(m, k, n, seed):
    """(A1 + A2) @ B == A1 @ B + A2 @ B within f32 tolerance."""
    a1 = _rand((m, k), jnp.float32, seed)
    a2 = _rand((m, k), jnp.float32, seed + 7)
    b = _rand((k, n), jnp.float32, seed + 13)
    cfg = TileConfig(8, 8, 8)
    lhs = ops.gemm(a1 + a2, b, config=cfg, backend=ops.BACKEND_PALLAS_INTERPRET)
    rhs = ops.gemm(a1, b, config=cfg, backend=ops.BACKEND_PALLAS_INTERPRET) \
        + ops.gemm(a2, b, config=cfg, backend=ops.BACKEND_PALLAS_INTERPRET)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)
