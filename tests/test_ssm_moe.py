"""Layer-level correctness: SSD chunked-vs-recurrent equivalence and MoE
capacity-dispatch vs dense reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.params import init_params


def _ssm_cfg(chunk=8, d_model=32, state=16, head_dim=16):
    return ModelConfig(name="t", family="ssm", num_layers=1, d_model=d_model,
                       vocab_size=64, ssm_state=state, ssm_head_dim=head_dim,
                       ssm_chunk=chunk, dtype="float32", use_rope=False)


@pytest.mark.parametrize("seq,chunk", [(16, 8), (24, 8), (7, 8), (32, 4)])
def test_ssd_chunked_equals_recurrent(seq, chunk):
    """The SSD block decomposition must equal the plain recurrence: running
    ssm_decode_step token-by-token from zero state reproduces ssm_block."""
    cfg = _ssm_cfg(chunk=chunk)
    params = init_params(S.ssm_template(cfg), jax.random.PRNGKey(0))
    b = 2
    x = jax.random.normal(jax.random.PRNGKey(1), (b, seq, cfg.d_model))
    full, final_state = S.ssm_block(params, x, cfg, return_state=True)

    state = S.ssm_state_init(cfg, b)
    outs = []
    for t in range(seq):
        y, state = S.ssm_decode_step(params, x[:, t:t + 1], state, cfg)
        outs.append(y)
    rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(rec),
                               rtol=2e-4, atol=2e-4)
    # final state from the chunked path matches the recurrent path
    np.testing.assert_allclose(np.asarray(final_state["ssm"]),
                               np.asarray(state["ssm"]), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final_state["conv"]),
                               np.asarray(state["conv"]), rtol=1e-5, atol=1e-5)


def _moe_dense_ref(params, x, top_k, num_experts):
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, params["w_gate"]))
    h = h * jnp.einsum("bsd,edf->bsef", x, params["w_up"])
    out_e = jnp.einsum("bsef,efd->bsed", h, params["w_down"])
    w = jnp.zeros(probs.shape).at[
        jnp.arange(x.shape[0])[:, None, None],
        jnp.arange(x.shape[1])[None, :, None], idx].set(gate)
    return jnp.einsum("bsed,bse->bsd", out_e, w)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), top_k=st.sampled_from([1, 2, 4]))
def test_moe_matches_dense_reference(seed, top_k):
    d, f, e = 16, 32, 8
    params = init_params(M.moe_template(d, f, e), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 12, d))
    out, aux = M.moe_layer(params, x, top_k=top_k, num_experts=e,
                           capacity_factor=float(e))  # no drops
    ref = _moe_dense_ref(params, x, top_k, e)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) >= 0.99  # Switch aux loss lower bound is 1 at balance


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 some tokens may drop, but output stays finite and within
    the convex hull scale of expert outputs."""
    d, f, e = 16, 32, 4
    params = init_params(M.moe_template(d, f, e), jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, d))
    out, _ = M.moe_layer(params, x, top_k=2, num_experts=e,
                         capacity_factor=1.0)
    assert np.isfinite(np.asarray(out)).all()


def test_moe_capacity_rounding():
    assert M.capacity(4096, 64, 8, 1.25) % 8 == 0
    assert M.capacity(1, 64, 8, 1.25) >= 8
