"""Substrate tests: data determinism, checkpoint roundtrip/corruption/elastic,
optimizer behaviour, gradient compression invariants."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, strategies as st

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, TokenPipeline
from repro.optim import AdamW, global_norm, warmup_cosine
from repro.optim import compression as comp


# -- data -------------------------------------------------------------------

def test_pipeline_deterministic_by_step():
    cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=4, seed=7)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    for step in (0, 3, 1000):
        b1, b2 = p1.batch(step), p2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_pipeline_labels_are_shifted_tokens():
    p = TokenPipeline(DataConfig(vocab_size=512, seq_len=16, global_batch=2))
    b = p.batch(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 10**6), seed=st.integers(0, 100))
def test_property_pipeline_tokens_in_vocab(step, seed):
    p = TokenPipeline(DataConfig(vocab_size=97, seq_len=8, global_batch=2,
                                 seed=seed))
    b = p.batch(step)
    assert (np.asarray(b["tokens"]) >= 0).all()
    assert (np.asarray(b["tokens"]) < 97).all()


def test_pipeline_file_source(tmp_path):
    path = os.path.join(tmp_path, "toks.bin")
    np.arange(10000, dtype=np.int32).tofile(path)
    p = TokenPipeline(DataConfig(vocab_size=50000, seq_len=16, global_batch=2,
                                 source="file", path=path))
    b = p.batch(0)
    assert b["tokens"].shape == (2, 16)
    # contiguity: labels are the next token in file order
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# -- checkpoint --------------------------------------------------------------

def _state():
    return {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.int32(5)}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(5, state)
    assert ck.latest_step() == 5
    restored = ck.restore(5, jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        state, restored)
    assert restored["params"]["b"].dtype == jnp.bfloat16


def test_checkpoint_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state()
    path = ck.save(1, state)
    # flip bytes in one leaf
    leaf = os.path.join(path, "params__w.npy")
    arr = np.load(leaf)
    arr[0, 0] += 1
    np.save(leaf, arr)
    with pytest.raises(IOError, match="corruption"):
        ck.restore(1, state)


def test_checkpoint_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state())
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_checkpoint_atomic_tmp_invisible(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state())
    os.makedirs(os.path.join(tmp_path, "step_00000002.tmp"))
    assert ck.latest_step() == 1


# -- optimizer ---------------------------------------------------------------

def test_adamw_decreases_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.array([[3.0, -2.0]])}
    state = opt.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}   # d/dw of w^2
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adamw_grad_clip():
    opt = AdamW(learning_rate=0.0, grad_clip_norm=1.0)
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    _, _, metrics = opt.update({"w": jnp.full((4,), 100.0)}, state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_warmup_cosine_shape():
    sch = warmup_cosine(1.0, 10, 100)
    assert float(sch(jnp.int32(0))) == 0.0
    assert float(sch(jnp.int32(10))) == pytest.approx(1.0)
    assert float(sch(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)


def test_master_weights_bf16_params():
    params = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    opt = AdamW(learning_rate=1e-4, weight_decay=0.0)
    state = opt.init(params)
    assert state.master["w"].dtype == jnp.float32
    grads = {"w": jnp.full((8, 8), 1e-3, jnp.bfloat16)}
    new_params, state, _ = opt.update(grads, state, params)
    assert new_params["w"].dtype == jnp.bfloat16
    # master moved even though bf16 param may round
    assert float(jnp.abs(state.master["w"] - 1.0).max()) > 0


# -- gradient compression ----------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_compression_error_feedback_bounded(seed):
    """deq + residual == original grad + previous residual (lossless split)."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (32,))
    state = comp.init_state({"g": g})
    deq, new_state = comp.compress_grads({"g": g}, state)
    recon = np.asarray(deq["g"]) + np.asarray(new_state.residual["g"])
    np.testing.assert_allclose(recon, np.asarray(g), rtol=1e-6, atol=1e-6)
    # int8 quantization error bounded by scale
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.abs(deq["g"] - g).max()) <= scale * 0.5 + 1e-7


def test_compression_converges_over_steps():
    """Error feedback: averaged compressed grads -> true grad over steps."""
    g = jnp.array([1e-4, 5e-3, -2e-3, 1.0])  # tiny components would vanish
    state = comp.init_state({"g": g})
    total = np.zeros(4)
    n = 50
    for _ in range(n):
        deq, state = comp.compress_grads({"g": g}, state)
        total += np.asarray(deq["g"])
    # error-feedback convergence bound: |avg - g| <= quant_scale / n
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    np.testing.assert_allclose(total / n, np.asarray(g),
                               rtol=0.02, atol=2 * scale / n + 1e-7)
