"""Driver-code fixture: sanctioned-sync and annotation-coverage patterns.

Lives under a ``serve/`` path on purpose — the fixture tree mirrors the real
layout so the DRIVER_PREFIXES host checks (pragma'd once-per-wave sync,
TP005 annotate coverage) apply here exactly as they do in the repo.
"""
import jax

from repro.profiling import annotate


def _model(tokens):
    return tokens * 2


step = jax.jit(_model)


def serve_wave(batch):
    out = step(batch)                    # TP005: jitted entry, no annotate
    jax.device_get(out)                  # TP001: driver sync, no pragma
    return out


def serve_wave_ok(batch):
    with annotate("wave"):
        out = step(batch)
    host = jax.device_get(out)           # analysis: allow(TP001)
    return host
