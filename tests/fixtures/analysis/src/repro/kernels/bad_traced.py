"""Deliberately impure traced code — fodder for the TP00x lint tests.

This file is never imported at runtime; ``tests/test_analysis.py`` points a
:class:`repro.analysis.callgraph.CallGraph` at the fixture tree and asserts
each check fires exactly where marked below.
"""
import random
import time

import jax
import jax.numpy as jnp
import numpy as np


def kernel_bad(x):
    jax.device_get(x)                       # TP001: host transfer
    y = float(x.sum())                      # TP002: host coercion
    if jnp.any(x > 0):                      # TP003: traced control flow
        y += random.random()                # TP004: stdlib RNG
    z = np.asarray(x)                       # TP001: numpy pull
    t = time.time()                         # TP004: clock state
    r = np.random.rand(3)                   # TP004: host RNG
    sanctioned = jax.device_get(x)          # analysis: allow(TP001)
    return y, z, t, r, sanctioned


run = jax.jit(kernel_bad)


def helper(x):
    return int(x[0])                        # TP002, via reachability


def kernel_calls_helper(x):
    return helper(x) + 1


run2 = jax.jit(kernel_calls_helper)


def host_only(x):
    # negative control: unreachable from any traced root, and this module
    # is not a serve/train driver — the same patterns stay silent here
    return float(np.asarray(x).sum())
