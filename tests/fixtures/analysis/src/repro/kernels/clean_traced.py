"""Pure traced code — must produce zero findings.

Exercises the patterns the lint must NOT flag: static shape/dtype
introspection, ``float()`` of shape math, keyed jax.random draws.
"""
import jax
import jax.numpy as jnp


def kernel_clean(x, key):
    if x.ndim == 2:                      # static: branches on rank
        x = x.reshape(-1)
    if jnp.issubdtype(x.dtype, jnp.integer):   # static: dtype introspection
        x = x.astype(jnp.float32)
    scale = float(x.shape[0])            # static: shape math, not a tracer
    noise = jax.random.normal(key, x.shape)    # keyed RNG is deterministic
    return jnp.tanh(x) * scale + noise


run = jax.jit(kernel_clean)
