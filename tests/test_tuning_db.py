"""Tuning-database subsystem: schema-checked persistence (op-keyed v3 +
legacy-gemm migration), nearest-shape fallback ordering, op-keyed registry
isolation, guided-vs-exhaustive search, and end-to-end pickup of a committed
DB by a fresh process running matmul under pallas-interpret."""
import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import pytest

from repro.core import (FlashAttentionConfig, OP_FLASH_ATTENTION, OP_GEMM,
                        SEARCH_EXHAUSTIVE, SEARCH_GUIDED, TileConfig,
                        TileRegistry, TuningDB, TuningDBError, TuningRecord,
                        sweep_flash_attention, sweep_gemm)
from repro.core import tuning_db as tdb

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(REPO, "src")


def _rec(m, k, n, bm=128, bk=128, bn=128, dtype="bfloat16", secs=1e-4):
    return TuningRecord.gemm(dtype, m, k, n, bm, bk, bn,
                             source="model", seconds=secs, gflops=1.0)


def _flash_rec(sq, skv, d, bq=128, bk=128, dtype="bfloat16", secs=1e-4):
    return TuningRecord(op=OP_FLASH_ATTENTION, dtype=dtype,
                        shape=(sq, skv, d), block=(bq, bk),
                        source="model", seconds=secs, gflops=1.0)


# ---------------------------------------------------------------------------
# TuningDB persistence
# ---------------------------------------------------------------------------

def test_db_roundtrip(tmp_path):
    db = TuningDB("tpu-v5e")
    db.add(_rec(1024, 1024, 1024, 512, 1024, 1024))
    db.add(_rec(2048, 2048, 2048, 256, 512, 512, dtype="float32"))
    path = str(tmp_path / "tpu-v5e.json")
    db.save(path)
    db2 = TuningDB.from_file(path)
    assert db2.hardware == "tpu-v5e"
    assert len(db2) == 2
    rec = db2.get("bfloat16", 1024, 1024, 1024)
    assert rec.config == TileConfig(512, 1024, 1024)
    assert rec.source == "model"


def test_db_keep_best_merge():
    db = TuningDB("tpu-v5e")
    # model vs model: the LATEST sweep wins even with a worse score —
    # estimates are recomputable, so a corrected cost model must be able to
    # replace stale winners (see TuningDB.add docstring)
    db.add(_rec(64, 64, 64, 128, 128, 128, secs=2e-4))
    db.add(_rec(64, 64, 64, 256, 256, 256, secs=5e-4))
    assert db.get("bfloat16", 64, 64, 64).config == TileConfig(256, 256, 256)
    # measure vs measure: best-of-runs, worse score kept out
    def meas(bm, secs):
        return TuningRecord.gemm("float32", 8, 8, 8, bm, bm, bm,
                                 source="measure", seconds=secs)
    db.add(meas(32, 2e-3))
    db.add(meas(64, 1e-3))                               # better -> replaces
    assert db.get("float32", 8, 8, 8).config == TileConfig(64, 64, 64)
    db.add(meas(32, 5e-3))                               # worse -> kept out
    assert db.get("float32", 8, 8, 8).config == TileConfig(64, 64, 64)


def test_partial_shape_lookup_and_put_fall_back_to_generic():
    """m without k/n must not crash the nearest-shape scan; partial puts are
    stored as generic entries."""
    reg = TileRegistry()
    reg.put(TileConfig(512, 1024, 1024), "tpu-v5e", jnp.bfloat16,
            1024, 1024, 1024)
    assert reg.lookup("tpu-v5e", jnp.bfloat16, 512).source == "default"
    reg.put(TileConfig(64, 128, 128), "tpu-v5e", jnp.bfloat16, 256)
    assert reg.lookup("tpu-v5e", jnp.bfloat16, 512).source == "generic"


def test_db_measure_outranks_model_estimate():
    """Measured 'seconds' aren't comparable to analytic estimates: a real
    measurement replaces a model entry even when its score looks worse, and
    a model estimate can never displace a measurement."""
    db = TuningDB("host-cpu")
    db.add(TuningRecord.gemm("float32", 64, 64, 64, 128, 128, 128,
                             source="model", seconds=1e-6))
    db.add(TuningRecord.gemm("float32", 64, 64, 64, 32, 32, 32,
                             source="measure", seconds=1e-3))
    assert db.get("float32", 64, 64, 64).source == "measure"
    db.add(TuningRecord.gemm("float32", 64, 64, 64, 128, 128, 128,
                             source="model", seconds=1e-9))
    assert db.get("float32", 64, 64, 64).source == "measure"


def test_explicit_load_supersedes_lazy_autoload(tmp_path, monkeypatch):
    """A launcher's explicit --tuned-dir load must not be overwritten by the
    registry's lazy default-dir autoload at first lookup."""
    custom, default = tmp_path / "custom", tmp_path / "default"
    db = TuningDB("tpu-v5e")
    db.add(_rec(128, 128, 128, 256, 256, 256, dtype="float32"))
    db.save(str(custom / "tpu-v5e.json"))
    db2 = TuningDB("tpu-v5e")
    db2.add(_rec(128, 128, 128, 512, 512, 512, dtype="float32"))
    db2.save(str(default / "tpu-v5e.json"))
    monkeypatch.setenv(tdb.TUNED_DIR_ENV, str(default))
    reg = TileRegistry(autoload=True)
    tdb.load_all(reg, str(custom))          # the explicit startup load
    res = reg.lookup("tpu-v5e", jnp.float32, 128, 128, 128)
    assert res.source == "exact"
    assert res.config == TileConfig(256, 256, 256)   # custom entry survived


def test_db_schema_version_mismatch_rejected(tmp_path):
    path = str(tmp_path / "old.json")
    blob = {"schema_version": tdb.SCHEMA_VERSION + 1, "hardware": "tpu-v5e",
            "entries": []}
    with open(path, "w") as f:
        json.dump(blob, f)
    with pytest.raises(TuningDBError, match="schema_version"):
        TuningDB.from_file(path)
    # non-strict registry load skips with a warning instead of raising
    reg = TileRegistry()
    with pytest.warns(UserWarning, match="skipping tuning DB"):
        loaded = tdb.load_into_registry(reg, path)
    assert loaded == 0 and reg.entries() == {}


def test_db_malformed_rejected(tmp_path):
    path = str(tmp_path / "bad.json")
    with open(path, "w") as f:
        f.write("{not json")
    with pytest.raises(TuningDBError):
        TuningDB.from_file(path)
    with open(path, "w") as f:
        json.dump({"no_version": True}, f)
    with pytest.raises(TuningDBError, match="schema_version"):
        TuningDB.from_file(path)


def test_db_merge_rejects_other_hardware():
    a, b = TuningDB("tpu-v5e"), TuningDB("host-cpu")
    with pytest.raises(TuningDBError, match="merge"):
        a.merge(b)


# ---------------------------------------------------------------------------
# Nearest-shape fallback
# ---------------------------------------------------------------------------

def test_nearest_shape_ordering():
    reg = TileRegistry()
    near_cfg = TileConfig(256, 512, 512)
    far_cfg = TileConfig(512, 1024, 1024)
    reg.put(near_cfg, "tpu-v5e", jnp.bfloat16, 1024, 1024, 1024)
    reg.put(far_cfg, "tpu-v5e", jnp.bfloat16, 8192, 8192, 8192)
    # query between the two, closer (in log space) to 1024^3
    res = reg.lookup("tpu-v5e", jnp.bfloat16, 1536, 1536, 1536)
    assert res.source == "nearest"
    assert res.matched_shape == (1024, 1024, 1024)
    assert res.config == near_cfg
    # query nearer the big entry resolves the other way
    res = reg.lookup("tpu-v5e", jnp.bfloat16, 6000, 6000, 6000)
    assert res.source == "nearest"
    assert res.matched_shape == (8192, 8192, 8192)
    assert res.config == far_cfg


def test_nearest_shape_threshold_falls_back_to_default():
    reg = TileRegistry()
    reg.put(TileConfig(512, 1024, 1024), "tpu-v5e", jnp.bfloat16,
            8192, 8192, 8192)
    res = reg.lookup("tpu-v5e", jnp.bfloat16, 8, 8, 8)   # miles away
    assert res.source == "default"
    assert res.config == TileConfig(128, 128, 128)


def test_lookup_tier_ordering_exact_beats_nearest_beats_generic():
    reg = TileRegistry()
    reg.put(TileConfig(64, 128, 128), "tpu-v5e", jnp.bfloat16)  # generic
    reg.put(TileConfig(256, 256, 256), "tpu-v5e", jnp.bfloat16, 512, 512, 512)
    assert reg.lookup("tpu-v5e", jnp.bfloat16, 512, 512, 512).source == "exact"
    near = reg.lookup("tpu-v5e", jnp.bfloat16, 640, 512, 512)
    assert near.source == "nearest"
    assert near.config == TileConfig(256, 256, 256)
    far = reg.lookup("tpu-v5e", jnp.bfloat16, 7, 7, 7)
    assert far.source == "generic"
    assert far.config == TileConfig(64, 128, 128)


def test_nearest_lookup_bucketed_per_hardware_and_dtype():
    """Nearest-shape resolution only scans its own (hardware, dtype) bucket:
    a perfect-distance entry under another hardware or dtype must not win
    (and hot decode lookups never pay for other backends' entries)."""
    reg = TileRegistry()
    reg.put(TileConfig(256, 256, 256), "host-cpu", jnp.bfloat16, 512, 512, 512)
    reg.put(TileConfig(512, 512, 512), "tpu-v5e", jnp.float32, 512, 512, 512)
    # same shape, wrong hardware/dtype -> falls through to the default tier
    res = reg.lookup("tpu-v5e", jnp.bfloat16, 512, 512, 500)
    assert res.source == "default"
    # entries land in their own buckets and round-trip through entries()
    reg.put(TileConfig(128, 256, 256), "tpu-v5e", jnp.bfloat16, 512, 512, 512)
    res = reg.lookup("tpu-v5e", jnp.bfloat16, 512, 512, 500)
    assert res.source == "nearest"
    assert res.config == TileConfig(128, 256, 256)
    assert len(reg.entries()) == 3


# ---------------------------------------------------------------------------
# Guided search
# ---------------------------------------------------------------------------

def test_guided_evaluates_fewer_with_equal_or_better_winner():
    kw = dict(dtype=jnp.bfloat16, mode="model", record=False)
    full = sweep_gemm(4096, 4096, 4096, search=SEARCH_EXHAUSTIVE, **kw)
    guided = sweep_gemm(4096, 4096, 4096, search=SEARCH_GUIDED, top_k=8, **kw)
    assert guided.candidates_total == full.candidates_total
    assert guided.evaluated < full.evaluated
    assert len(guided.points) == guided.evaluated
    assert guided.best.seconds <= full.best.seconds
    assert guided.best.config == full.best.config


def test_guided_search_records_winner_to_registry():
    reg = TileRegistry()
    res = sweep_gemm(2048, 2048, 2048, dtype=jnp.bfloat16, mode="model",
                     search=SEARCH_GUIDED, registry=reg)
    hit = reg.lookup("tpu-v5e", jnp.bfloat16, 2048, 2048, 2048)
    assert hit.source == "exact"
    assert hit.config == res.best.config


# ---------------------------------------------------------------------------
# End-to-end: tune.py sweep -> fresh process matmul pickup
# ---------------------------------------------------------------------------

_PICKUP = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    from repro.core import execution_context, matmul
    from repro.core.registry import GLOBAL_REGISTRY

    x = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    with execution_context(backend="pallas-interpret"):
        out = matmul(x, w)          # tuned shape -> exact hit
        x2 = jax.random.normal(jax.random.PRNGKey(2), (192, 512), jnp.float32)
        out2 = matmul(x2, w)        # untuned shape -> nearest hit
    exact = GLOBAL_REGISTRY.lookup("tpu-v5e", jnp.float32, 256, 512, 256)
    near = GLOBAL_REGISTRY.lookup("tpu-v5e", jnp.float32, 192, 512, 256)
    print("RESULT " + json.dumps({
        "exact": exact.source, "near": near.source,
        "near_matched": list(near.matched_shape),
        "cfg": [exact.config.bm, exact.config.bk, exact.config.bn],
        "stats": GLOBAL_REGISTRY.hit_stats,
        "out_ok": bool(jnp.allclose(out, x @ w, atol=1e-3)),
    }))
""")


def test_sweep_cli_then_fresh_process_matmul_pickup(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["REPRO_TUNED_DIR"] = str(tmp_path)
    # 1. tune one small problem via the CLI into the tmp tuned dir
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "tune.py"), "sweep",
         "--hardware", "tpu-v5e", "--mode", "model",
         "--shapes", "256x512x256", "--dtype", "float32"],
        capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    db_file = tmp_path / "tpu-v5e.json"
    assert db_file.exists()
    db = TuningDB.from_file(str(db_file))
    assert db.get("float32", 256, 512, 256) is not None

    # 2. a FRESH process auto-loads it at first matmul
    proc = subprocess.run([sys.executable, "-c", _PICKUP],
                          capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    rec = json.loads(line[len("RESULT "):])
    assert rec["exact"] == "exact"
    assert rec["near"] == "nearest"
    assert rec["near_matched"] == [256, 512, 256]
    assert rec["out_ok"]
    tuned = db.get("float32", 256, 512, 256)
    assert rec["cfg"] == [tuned.bm, tuned.bk, tuned.bn]


def test_autoload_respects_disable_env(tmp_path, monkeypatch):
    db = TuningDB("tpu-v5e")
    db.add(_rec(128, 128, 128, 256, 256, 256, dtype="float32"))
    db.save(str(tmp_path / "tpu-v5e.json"))
    monkeypatch.setenv(tdb.TUNED_DIR_ENV, str(tmp_path))
    monkeypatch.setenv(tdb.DISABLE_ENV, "1")
    reg = TileRegistry(autoload=True)
    assert reg.lookup("tpu-v5e", jnp.float32, 128, 128, 128).source == "default"
    # and with the kill switch off, the same lookup hits the DB
    monkeypatch.delenv(tdb.DISABLE_ENV)
    reg2 = TileRegistry(autoload=True)
    assert reg2.lookup("tpu-v5e", jnp.float32, 128, 128, 128).source == "exact"


def test_markdown_rendering_matches_tab4_shape():
    db = TuningDB("tpu-v5e")
    db.add(_rec(1024, 1024, 1024, 512, 1024, 1024))
    db.add(_flash_rec(2048, 2048, 128, 256, 512))
    md = db.markdown()
    assert "paper Tab. 4" in md
    assert "Tuned gemm table" in md and "Tuned flash_attention table" in md
    assert "| bfloat16 | 1024x1024x1024 | 512x1024x1024 | model |" in md
    assert "| bfloat16 | 2048x2048x128 | 256x512 | model |" in md


# ---------------------------------------------------------------------------
# Op-keyed v3 schema: legacy migration + op isolation
# ---------------------------------------------------------------------------

def test_legacy_gemm_db_migrates_and_roundtrips(tmp_path):
    """A legacy (schema_version 2, flat m/k/n entries, no op) file — the
    format the repo committed before the multi-op framework — must load with
    every entry as op="gemm", and save back as an op-keyed v3 file that
    reloads identically."""
    legacy = {
        "schema_version": 2, "hardware": "tpu-v5e",
        "entries": [{"dtype": "bfloat16", "m": 1024, "k": 1024, "n": 1024,
                     "bm": 512, "bk": 1024, "bn": 1024,
                     "source": "model", "seconds": 1e-5, "gflops": 100.0}],
    }
    path = str(tmp_path / "legacy.json")
    with open(path, "w") as f:
        json.dump(legacy, f)
    db = TuningDB.from_file(path)
    rec = db.get("bfloat16", 1024, 1024, 1024)
    assert rec is not None and rec.op == OP_GEMM
    assert rec.config == TileConfig(512, 1024, 1024)
    # round-trip: the migrated DB persists op-keyed (v3) and reloads equal
    out = str(tmp_path / "migrated.json")
    db.save(out)
    blob = json.load(open(out))
    assert blob["schema_version"] == tdb.SCHEMA_VERSION
    assert blob["entries"][0]["op"] == OP_GEMM
    assert blob["entries"][0]["shape"] == [1024, 1024, 1024]
    db2 = TuningDB.from_file(out)
    assert db2.records() == db.records()


def test_db_holds_both_ops_and_reloads(tmp_path):
    db = TuningDB("tpu-v5e")
    db.add(_rec(1024, 1024, 1024, 512, 1024, 1024))
    db.add(_flash_rec(2048, 2048, 128, 512, 1024))
    path = str(tmp_path / "tpu-v5e.json")
    db.save(path)
    db2 = TuningDB.from_file(path)
    assert db2.ops() == [OP_FLASH_ATTENTION, OP_GEMM]
    flash = db2.get_op(OP_FLASH_ATTENTION, "bfloat16", (2048, 2048, 128))
    assert flash.config == FlashAttentionConfig(512, 1024)
    gemm = db2.get("bfloat16", 1024, 1024, 1024)
    assert gemm.config == TileConfig(512, 1024, 1024)
    # same (dtype, shape) under different ops are distinct entries
    db2.add(TuningRecord(op=OP_FLASH_ATTENTION, dtype="bfloat16",
                         shape=(1024, 1024, 1024), block=(64, 128)))
    assert len(db2) == 3
    assert db2.get("bfloat16", 1024, 1024, 1024).config == \
        TileConfig(512, 1024, 1024)


def test_registry_lookups_never_cross_ops():
    """Op buckets mirror the (hardware, dtype) bucket fix: a perfect-shape
    GEMM entry must never satisfy (nor be scanned by) a flash lookup, and
    vice versa."""
    reg = TileRegistry()
    reg.put(TileConfig(512, 1024, 1024), "tpu-v5e", jnp.bfloat16,
            1024, 1024, 1024)
    res = reg.lookup_op(OP_FLASH_ATTENTION, "tpu-v5e", jnp.bfloat16,
                        (1024, 1024, 1024))
    assert res.source == "default"
    assert isinstance(res.config, FlashAttentionConfig)
    reg.put_op(OP_FLASH_ATTENTION, FlashAttentionConfig(256, 512),
               "tpu-v5e", jnp.bfloat16, (1024, 1024, 128))
    # nearest within the flash bucket only
    near = reg.lookup_op(OP_FLASH_ATTENTION, "tpu-v5e", jnp.bfloat16,
                         (2048, 2048, 128))
    assert near.source == "nearest"
    assert near.config == FlashAttentionConfig(256, 512)
    # ...and the gemm side is equally unaffected by the flash entry
    g = reg.lookup("tpu-v5e", jnp.bfloat16, 1024, 1024, 128)
    assert isinstance(g.config, TileConfig)
    assert g.matched_shape == (1024, 1024, 1024)


def test_registry_flat_snapshot_roundtrips_both_ops(tmp_path):
    path = str(tmp_path / "snap.json")
    reg = TileRegistry()
    reg.put(TileConfig(256, 512, 256), "tpu-v5e", jnp.bfloat16, 512, 512, 512)
    reg.put_op(OP_FLASH_ATTENTION, FlashAttentionConfig(64, 128),
               "tpu-v5e", jnp.bfloat16, (512, 512, 64))
    reg.put_op(OP_FLASH_ATTENTION, FlashAttentionConfig(32, 32),
               "host-cpu", jnp.float32)              # generic entry
    reg.save(path)
    reg2 = TileRegistry(path)
    assert reg2.get("tpu-v5e", jnp.bfloat16, 512, 512, 512) == \
        TileConfig(256, 512, 256)
    assert reg2.get_op(OP_FLASH_ATTENTION, "tpu-v5e", jnp.bfloat16,
                       (512, 512, 64)) == FlashAttentionConfig(64, 128)
    assert reg2.lookup_op(OP_FLASH_ATTENTION, "host-cpu",
                          jnp.float32).source == "generic"


def test_flash_sweep_guided_and_recorded():
    reg = TileRegistry()
    kw = dict(dtype=jnp.bfloat16, mode="model", record=False)
    full = sweep_flash_attention(2048, 2048, 128,
                                 search=SEARCH_EXHAUSTIVE, **kw)
    guided = sweep_flash_attention(2048, 2048, 128, search=SEARCH_GUIDED,
                                   top_k=4, **kw)
    assert guided.candidates_total == full.candidates_total
    assert guided.evaluated < full.evaluated
    assert guided.best.seconds <= full.best.seconds
    assert guided.best.config == full.best.config
    res = sweep_flash_attention(2048, 2048, 128, dtype=jnp.bfloat16,
                                mode="model", registry=reg)
    hit = reg.lookup_op(OP_FLASH_ATTENTION, "tpu-v5e", jnp.bfloat16,
                        (2048, 2048, 128))
    assert hit.source == "exact"
    assert hit.config == res.best.config


def test_flash_sweep_measure_mode_runs():
    from repro.core import FLASH_INTERPRET_SPACE, HOST_CPU
    res = sweep_flash_attention(32, 32, 8, dtype=jnp.float32, mode="measure",
                                space=FLASH_INTERPRET_SPACE,
                                hardware=HOST_CPU, repeats=1, record=False)
    assert all(p.seconds > 0 for p in res.points)
    assert all(p.source.startswith("measure") for p in res.points)


def test_sweep_cli_flash_op_writes_op_keyed_entries(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "tune.py"), "sweep",
         "--hardware", "tpu-v5e", "--mode", "model",
         "--op", "flash_attention", "--shapes", "512x512x64",
         "--dtype", "bfloat16", "--db-dir", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    db = TuningDB.from_file(str(tmp_path / "tpu-v5e.json"))
    rec = db.get_op(OP_FLASH_ATTENTION, "bfloat16", (512, 512, 64))
    assert rec is not None
    assert isinstance(rec.config, FlashAttentionConfig)


# ---------------------------------------------------------------------------
# Mesh-keyed entries (schema v4: topology in the op key)
# ---------------------------------------------------------------------------

def test_mesh_keyed_db_roundtrip_and_registry_delivery(tmp_path):
    """A decode_loop record tuned for data=4,model=2 must persist with its
    topology label, coexist with the topology-agnostic record of the SAME
    (op, dtype, shape), and land in the registry's <hardware>@<mesh> bucket
    so only lookups under that mesh see it."""
    from repro.core import OP_DECODE_LOOP, DecodeLoopConfig
    db = TuningDB("cpu-interpret")
    db.add(TuningRecord(op=OP_DECODE_LOOP, dtype="bfloat16",
                        shape=(8, 256), block=(4,), mesh="data4xmodel2",
                        source="measure", seconds=1e-3))
    db.add(TuningRecord(op=OP_DECODE_LOOP, dtype="bfloat16",
                        shape=(8, 256), block=(1,),
                        source="measure", seconds=2e-3))
    assert len(db) == 2                      # mesh is part of the record key
    path = str(tmp_path / "cpu-interpret.json")
    db.save(path)
    db2 = TuningDB.from_file(path)
    rec = db2.get_op(OP_DECODE_LOOP, "bfloat16", (8, 256),
                     mesh="data4xmodel2")
    assert rec.mesh == "data4xmodel2"
    assert rec.config == DecodeLoopConfig(4)
    assert db2.get_op(OP_DECODE_LOOP, "bfloat16", (8, 256)).mesh is None

    reg = TileRegistry()
    assert tdb.load_into_registry(reg, path) == 2
    on_mesh = reg.lookup_op(OP_DECODE_LOOP, "cpu-interpret", jnp.bfloat16,
                            (8, 256), mesh="data4xmodel2")
    assert on_mesh.source == "exact"
    assert on_mesh.mesh == "data4xmodel2"
    assert on_mesh.config == DecodeLoopConfig(4)
    alone = reg.lookup_op(OP_DECODE_LOOP, "cpu-interpret", jnp.bfloat16,
                          (8, 256))
    assert alone.source == "exact"
    assert alone.mesh is None
    assert alone.config == DecodeLoopConfig(1)


def test_mesh_bucket_outranks_plain_and_falls_back(tmp_path):
    """Lookup order: the mesh bucket's exact/nearest tiers beat every
    plain-hardware tier; an unknown topology falls straight through to the
    topology-agnostic entry."""
    from repro.core import OP_DECODE_LOOP, DecodeLoopConfig
    reg = TileRegistry()
    reg.put_op(OP_DECODE_LOOP, DecodeLoopConfig(2), "cpu-interpret",
               jnp.bfloat16, (8, 256))
    reg.put_op(OP_DECODE_LOOP, DecodeLoopConfig(8), "cpu-interpret",
               jnp.bfloat16, (8, 512), mesh="data4xmodel2")
    # nearest within the mesh bucket outranks exact in the plain bucket
    res = reg.lookup_op(OP_DECODE_LOOP, "cpu-interpret", jnp.bfloat16,
                        (8, 256), mesh="data4xmodel2")
    assert res.source == "nearest"
    assert res.config == DecodeLoopConfig(8)
    # a topology with no tuned entries falls back to the plain bucket
    res = reg.lookup_op(OP_DECODE_LOOP, "cpu-interpret", jnp.bfloat16,
                        (8, 256), mesh="data2xmodel4")
    assert res.source == "exact"
    assert res.config == DecodeLoopConfig(2)
    # alias canonicalization applies inside the mesh key too
    reg.put_op(OP_DECODE_LOOP, DecodeLoopConfig(4), "host-cpu",
               jnp.bfloat16, (8, 256), mesh="data2xmodel1")
    res = reg.lookup_op(OP_DECODE_LOOP, "cpu-interpret", jnp.bfloat16,
                        (8, 256), mesh="data2xmodel1")
    assert res.source == "exact"
    assert res.config == DecodeLoopConfig(4)


def test_legacy_v3_db_still_loads(tmp_path):
    """v1/2/3 files (no mesh field) must keep loading as topology-agnostic
    records — blessing v4 does not orphan committed tuned tables."""
    path = str(tmp_path / "cpu-interpret.json")
    blob = {"schema_version": 3, "hardware": "cpu-interpret",
            "entries": [{"op": "gemm", "dtype": "bfloat16",
                         "shape": [64, 64, 64], "block": [32, 32, 32],
                         "source": "model", "seconds": 1e-4, "gflops": 1.0}]}
    with open(path, "w") as f:
        json.dump(blob, f)
    db = TuningDB.from_file(path)
    rec = db.get("bfloat16", 64, 64, 64)
    assert rec is not None and rec.mesh is None
    # and re-saves as v4
    db.save(path)
    assert json.load(open(path))["schema_version"] == tdb.SCHEMA_VERSION
