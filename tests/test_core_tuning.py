"""Core tuning machinery: tile feasibility invariants, cost-model behaviour
(paper Eqs. 5-7), tuner sweeps, registry persistence."""
import os

import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, strategies as st

from repro.core import (GLOBAL_REGISTRY, HOST_CPU, INTERPRET_SPACE, TPU_V5E,
                        TileConfig, TileRegistry, TuningSpace, sweep_gemm)
from repro.core.cost_model import gemm_cost, ratio_model
from repro.core.tile_config import square


def test_vmem_working_set_matches_paper_eq5_for_square_tiles():
    """K(S,T) = 2 T^2 S for the A/B tiles (paper Eq. 5)."""
    for t in (64, 128, 256):
        cfg = square(t)
        s = 4  # f32
        ab_bytes = (cfg.bm * cfg.bk + cfg.bk * cfg.bn) * s
        assert ab_bytes == 2 * t * t * s


def test_candidates_all_fit_vmem():
    space = TuningSpace()
    for cfg in space.candidates(TPU_V5E, jnp.bfloat16):
        assert cfg.fits(TPU_V5E, jnp.bfloat16)
        assert cfg.aligned(TPU_V5E, jnp.bfloat16)


def test_candidate_space_nonempty_for_all_dtypes():
    for dt in (jnp.bfloat16, jnp.float32):
        assert len(list(TuningSpace().candidates(TPU_V5E, dt))) > 0


@settings(max_examples=20, deadline=None)
@given(t=st.sampled_from([128, 256, 512]), n=st.integers(1024, 20480))
def test_ratio_model_monotone_in_t(t, n):
    """Paper Eq. 7: R(N, T) grows with T and approaches T for large N."""
    assert ratio_model(n, 2 * t) > ratio_model(n, t)
    assert ratio_model(n, t) < t


def test_cost_model_prefers_larger_tiles_until_vmem():
    """The paper's headline tuning curve: bigger T -> fewer HBM bytes."""
    n = 8192
    costs = [gemm_cost(n, n, n, square(t), TPU_V5E, jnp.bfloat16)
             for t in (128, 256, 512)]
    for a, b in zip(costs, costs[1:]):
        assert b.hbm_bytes < a.hbm_bytes


def test_cost_model_arithmetic_intensity_tracks_eq7():
    """Measured AI of the tiled GEMM ~ R(N,T) = 2NT/(2N+T) (square tiles,
    equal in/out dtype) up to the f32-accumulator/output constant."""
    n, t = 4096, 256
    c = gemm_cost(n, n, n, square(t), TPU_V5E, jnp.float32)
    # model AI in flops/element: R(N,T); convert to bytes (4 B/elem)
    want = ratio_model(n, t) / 4.0
    assert 0.5 * want < c.arithmetic_intensity < 2.0 * want


def test_sweep_model_mode_records_registry():
    reg = TileRegistry()
    res = sweep_gemm(2048, 2048, 2048, dtype=jnp.bfloat16, mode="model",
                     registry=reg)
    assert len(res.points) > 4
    best = res.best.config
    assert reg.get("tpu-v5e", jnp.bfloat16, 2048, 2048, 2048) == best


def test_sweep_measure_mode_runs():
    res = sweep_gemm(32, 32, 32, dtype=jnp.float32, mode="measure",
                     space=INTERPRET_SPACE, hardware=HOST_CPU, repeats=1,
                     record=False)
    assert all(p.seconds > 0 for p in res.points)


def test_registry_persistence_roundtrip(tmp_path):
    path = os.path.join(tmp_path, "tuned.json")
    reg = TileRegistry()
    cfg = TileConfig(256, 512, 256)
    reg.put(cfg, "tpu-v5e", jnp.bfloat16, 1024, 1024, 1024)
    reg.put(TileConfig(64, 128, 128), "tpu-v5e", jnp.bfloat16)
    reg.save(path)
    reg2 = TileRegistry(path)
    assert reg2.get("tpu-v5e", jnp.bfloat16, 1024, 1024, 1024) == cfg
    # shape-specific beats hardware-default; unknown shape falls back
    assert reg2.get("tpu-v5e", jnp.bfloat16, 7, 7, 7) == TileConfig(64, 128, 128)


def test_registry_fallback_default():
    reg = TileRegistry()
    cfg = reg.get("tpu-v5e", jnp.bfloat16)
    assert isinstance(cfg, TileConfig)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(128, 8192), k=st.integers(128, 8192),
       n=st.integers(128, 8192))
def test_property_cost_model_positive_and_flops_exact(m, k, n):
    c = gemm_cost(m, k, n, TileConfig(128, 128, 128), TPU_V5E, jnp.bfloat16)
    assert c.flops == 2 * m * k * n
    assert c.total_s > 0
    assert c.hbm_bytes > 0
