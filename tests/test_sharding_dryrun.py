"""Sharding-rule unit tests + an in-subprocess mini dry-run on an 8-device
host mesh (subprocess isolates XLA_FLAGS from the 1-device test session)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.models.params import ParamSpec


def test_param_spec_rules_small_mesh():
    """Verify the logical->mesh mapping rules without building a mesh, via a
    stub mesh object."""
    from repro.distributed.sharding import ShardingRules, spec_for_param

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    rules = ShardingRules(tensor_axis="model", fsdp_axis="data",
                          batch_axes=("data",))
    mesh = FakeMesh()

    # embedding (vocab, embed) -> (model, data)
    sp = spec_for_param(mesh, rules, ParamSpec((128, 64), ("vocab", "embed")))
    assert tuple(sp) == ("model", "data")
    # attention wq (embed, ff) -> (data, model)
    sp = spec_for_param(mesh, rules, ParamSpec((64, 128), ("embed", "ff")))
    assert tuple(sp) == ("data", "model")
    # expert weights (expert, embed, ff): model used once (expert wins)
    sp = spec_for_param(mesh, rules,
                        ParamSpec((8, 64, 128), ("expert", "embed", "ff")))
    assert tuple(sp) == ("model", "data", None)
    # non-divisible dim falls back to replicated
    sp = spec_for_param(mesh, rules, ParamSpec((63, 128), ("vocab", "ff")))
    assert tuple(sp) == (None, "model")
    # 1-D params replicated
    sp = spec_for_param(mesh, rules, ParamSpec((64,), ("embed",)))
    assert tuple(sp) == ()
    # stacked layer axis never sharded
    sp = spec_for_param(mesh, rules,
                        ParamSpec((4, 64, 128), ("layer", "embed", "ff")))
    assert tuple(sp) == (None, "data", "model")


class _FakeMesh:
    def __init__(self, **shape):
        self.axis_names = tuple(shape)
        self.shape = shape


def test_rules_for_mesh_axis_presence():
    """rules_for_mesh degrades gracefully with whatever axes the mesh has."""
    from repro.distributed.sharding import rules_for_mesh

    r = rules_for_mesh(_FakeMesh(data=4, model=2))
    assert (r.tensor_axis, r.fsdp_axis, r.batch_axes) == ("model", "data",
                                                          ("data",))
    assert r.sequence_axis is None
    # data-only mesh: no tensor axis to map TP onto
    r = rules_for_mesh(_FakeMesh(data=8))
    assert r.tensor_axis is None and r.fsdp_axis == "data"
    # model-only mesh: batch falls back to the first axis
    r = rules_for_mesh(_FakeMesh(model=8))
    assert r.tensor_axis == "model" and r.fsdp_axis is None
    assert r.batch_axes == ("model",)
    # multi-pod: batch spans the pod AND data axes, in that order
    r = rules_for_mesh(_FakeMesh(pod=2, data=4, model=2))
    assert r.batch_axes == ("pod", "data")
    # knobs: FSDP off, sequence parallelism on
    r = rules_for_mesh(_FakeMesh(data=4, model=2), fsdp=False,
                       sequence_parallel=True)
    assert r.fsdp_axis is None and r.sequence_axis == "model"
    # sequence parallelism needs a model axis to land on
    r = rules_for_mesh(_FakeMesh(data=8), sequence_parallel=True)
    assert r.sequence_axis is None


def test_rules_for_mesh_spec_edge_cases():
    """Edge cases threaded end-to-end through rules_for_mesh -> specs:
    non-divisible dims replicate, 1-D params replicate, and a mesh axis is
    used at most once per spec."""
    from repro.distributed.sharding import rules_for_mesh, spec_for_param

    mesh = _FakeMesh(data=4, model=2)
    rules = rules_for_mesh(mesh)
    # dims not divisible by their target axis size fall back to replicated
    sp = spec_for_param(mesh, rules, ParamSpec((63, 128), ("vocab", "ff")))
    assert tuple(sp) == (None, "model")
    sp = spec_for_param(mesh, rules, ParamSpec((64, 125), ("embed", "ff")))
    assert tuple(sp) == ("data", None)
    # 1-D params (norm scales, biases) always replicate
    for axes in (("embed",), ("vocab",), (None,)):
        assert tuple(spec_for_param(mesh, rules, ParamSpec((64,), axes))) == ()
    # a mesh axis is used at most once per spec (first dim wins)
    sp = spec_for_param(mesh, rules,
                        ParamSpec((8, 64, 128), ("expert", "embed", "ff")))
    assert tuple(sp) == ("model", "data", None)
    sp = spec_for_param(mesh, rules, ParamSpec((128, 64), ("vocab", "ff")))
    assert tuple(sp) == ("model", None)


def test_local_gemm_divisors():
    """The serve engine's local-shape lookups: weight (K, N) dims map to the
    mesh-axis sizes their sharding spec divides them by."""
    from repro.distributed.sharding import local_gemm_divisors, rules_for_mesh

    mesh = _FakeMesh(data=4, model=2)
    rules = rules_for_mesh(mesh)
    template = {
        "wq": ParamSpec((64, 128), ("embed", "ff")),       # (data, model)
        "embed": ParamSpec((256, 64), ("vocab", "embed")),  # (model, data)
        "stack": ParamSpec((4, 64, 128), ("layer", "embed", "ff")),
        "norm": ParamSpec((64,), ("embed",)),               # 1-D: skipped
        "odd": ParamSpec((63, 125), ("vocab", "embed")),    # non-divisible
        # square projections: same global (K, N), different axis order —
        # BOTH divisor variants must be surfaced, not first-leaf-wins
        "sq_in": ParamSpec((64, 64), ("embed", "ff")),
        "sq_out": ParamSpec((64, 64), ("ff", "embed")),
    }
    div = local_gemm_divisors(mesh, rules, template)
    assert div[(64, 128)] == ((4, 2),)    # K split by FSDP, N by TP
    assert div[(256, 64)] == ((2, 4),)
    assert div[(63, 125)] == ((1, 1),)    # non-divisible -> replicated -> 1
    assert div[(64, 64)] == ((2, 4), (4, 2))   # wq-like AND wo-like variants
    assert (64,) not in div


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax
    from repro.configs.catalog import get_config
    from repro.configs.base import ShapeSpec
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_host_mesh
    from repro.distributed import sharding as sh

    cfg = get_config("{arch}").reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    shape = ShapeSpec("t", seq_len=16, global_batch=8, kind="{kind}")
    mesh = make_host_mesh(data=4, model=2)
    rules = sh.rules_for_mesh(mesh)
    lowered, meta = lower_cell(cfg, shape, mesh, rules)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    print("RESULT " + json.dumps({{"flops": float(cost["flops"]),
                                   "kind": meta["kind"]}}))
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch,kind", [
    ("llama3.2-1b", "train"),
    ("olmoe-1b-7b", "train"),
    ("mamba2-130m", "decode"),
    ("zamba2-2.7b", "prefill"),
    ("whisper-large-v3", "decode"),
    ("llama-3.2-vision-11b", "train"),
])
def test_mini_dryrun_compiles_on_8dev_mesh(arch, kind):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROC.format(arch=arch, kind=kind)],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    rec = json.loads(line[len("RESULT "):])
    assert rec["flops"] > 0
    assert rec["kind"] == kind
