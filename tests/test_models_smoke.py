"""Per-architecture smoke tests (task-required): REDUCED same-family config,
one forward + one train step on CPU, asserting output shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.catalog import ARCHITECTURES
from repro.models import build_model
from repro.optim import AdamW
from repro.train import init_train_state, make_train_step

ARCH_IDS = sorted(ARCHITECTURES)


def _batch(model, b, s, with_labels=False, seed=0):
    cfg = model.cfg
    key = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(
            jax.random.PRNGKey(seed + 1), (b, s), 0, cfg.vocab_size)
    for k, sds in model.extra_inputs(b).items():
        batch[k] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(seed + 2), sds.shape).astype(sds.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_shapes_finite(arch):
    cfg = ARCHITECTURES[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    logits, aux = model.forward(params, _batch(model, b, s))
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = ARCHITECTURES[arch].reduced()
    model = build_model(cfg)
    opt = AdamW(learning_rate=1e-3)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt))
    state, metrics = step(state, _batch(model, 2, 16, with_labels=True))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state.step) == 1
    # params actually changed
    before = build_model(cfg).init(jax.random.PRNGKey(0))
    diffs = jax.tree_util.tree_map(
        lambda a, b_: float(jnp.abs(a.astype(jnp.float32)
                                    - b_.astype(jnp.float32)).max()),
        before, state.params)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_decode_consistency(arch):
    """decode_step after prefill == teacher-forced forward at that position."""
    import dataclasses
    cfg = ARCHITECTURES[arch].reduced()
    if cfg.num_experts:  # avoid capacity-drop nondeterminism between paths
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    batch_full = _batch(model, b, s + 1, seed=3)
    batch_pre = dict(batch_full)
    batch_pre["tokens"] = batch_full["tokens"][:, :s]
    logits_full, _ = model.forward(params, batch_full)
    cache = model.init_cache(b, 32)
    lg_pre, cache = model.prefill(params, batch_pre, cache)
    np.testing.assert_allclose(np.asarray(lg_pre),
                               np.asarray(logits_full[:, s - 1]),
                               rtol=2e-4, atol=2e-4)
    lg_dec, _ = model.decode_step(params, batch_full["tokens"][:, s:s + 1],
                                  cache, jnp.int32(s))
    np.testing.assert_allclose(np.asarray(lg_dec),
                               np.asarray(logits_full[:, s]),
                               rtol=2e-4, atol=2e-4)


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (guards against config drift)."""
    a = ARCHITECTURES
    v = a["llama-3.2-vision-11b"]
    assert (v.num_layers, v.d_model, v.num_heads, v.num_kv_heads,
            v.d_ff, v.vocab_size) == (40, 4096, 32, 8, 14336, 128256)
    o = a["olmoe-1b-7b"]
    assert (o.num_layers, o.d_model, o.num_experts, o.experts_per_token,
            o.d_ff, o.vocab_size) == (16, 2048, 64, 8, 1024, 50304)
    mo = a["moonshot-v1-16b-a3b"]
    assert (mo.num_layers, mo.d_model, mo.num_experts, mo.experts_per_token,
            mo.vocab_size) == (48, 2048, 64, 6, 163840)
    l1 = a["llama3.2-1b"]
    assert (l1.num_layers, l1.d_model, l1.num_heads, l1.num_kv_heads,
            l1.d_ff, l1.vocab_size) == (16, 2048, 32, 8, 8192, 128256)
    cg = a["chatglm3-6b"]
    assert (cg.num_layers, cg.d_model, cg.num_kv_heads, cg.d_ff,
            cg.vocab_size, cg.rope_fraction) == (28, 4096, 2, 13696, 65024, 0.5)
    sl = a["stablelm-12b"]
    assert (sl.num_layers, sl.d_model, sl.num_kv_heads, sl.d_ff,
            sl.vocab_size) == (40, 5120, 8, 13824, 100352)
    yi = a["yi-9b"]
    assert (yi.num_layers, yi.d_model, yi.num_kv_heads, yi.d_ff,
            yi.vocab_size) == (48, 4096, 4, 11008, 64000)
    mb = a["mamba2-130m"]
    assert (mb.num_layers, mb.d_model, mb.ssm_state, mb.vocab_size,
            mb.num_heads) == (24, 768, 128, 50280, 0)
    wh = a["whisper-large-v3"]
    assert (wh.num_layers, wh.d_model, wh.num_heads, wh.d_ff,
            wh.vocab_size) == (32, 1280, 20, 5120, 51866)
    za = a["zamba2-2.7b"]
    assert (za.num_layers, za.d_model, za.num_heads, za.d_ff,
            za.vocab_size, za.ssm_state, za.attn_period) == (
        54, 2560, 32, 10240, 32000, 64, 6)
