"""Property-based tests for the paged-KV allocator + continuous scheduler.

The scheduler invariants documented in ``repro/serve/kv_pages.py`` are the
contract the serve engine builds on; this suite drives the pure host-side
bookkeeping with a simulated decode over randomized workloads (arrival
order, prompt/max_new lengths, slot counts, page sizes, pool capacities)
and checks them at every chunk boundary:

1. no page is ever double-allocated (nor a reserved NULL/TRASH page);
2. FIFO bias: requests enter first service in submit order, and every
   request completes (no starvation, preemption included);
3. freed pages always return — a drained scheduler restores full capacity;
4. admission + lazy growth never exceed the pool's token capacity.

Runs under real hypothesis when installed, else the deterministic fallback
in ``repro.testing`` (seed derived from the test name, pinned per CI run).
"""
import random

import numpy as np
import pytest

from repro.serve.kv_pages import (ContinuousScheduler, NULL_PAGE,
                                  PageAllocator, PagePoolExhausted,
                                  RESERVED_PAGES, TRASH_PAGE, gather_indices,
                                  pages_for, scatter_indices)
from repro.testing import given, settings, strategies as st


# ---------------------------------------------------------------------------
# allocator unit tests
# ---------------------------------------------------------------------------

def test_reserved_pages_never_allocated():
    alloc = PageAllocator(capacity_tokens=16, page_size=4)
    pages = alloc.alloc(alloc.usable_pages)
    assert NULL_PAGE not in pages and TRASH_PAGE not in pages
    assert min(pages) >= RESERVED_PAGES


def test_alloc_exhaustion_raises_and_keeps_state():
    alloc = PageAllocator(capacity_tokens=8, page_size=4)   # 2 usable pages
    got = alloc.alloc(2)
    with pytest.raises(PagePoolExhausted):
        alloc.alloc(1)
    alloc.free(got)
    assert alloc.free_pages == alloc.usable_pages == 2


def test_double_free_raises():
    alloc = PageAllocator(capacity_tokens=8, page_size=4)
    pages = alloc.alloc(1)
    alloc.free(pages)
    with pytest.raises(RuntimeError, match="not live"):
        alloc.free(pages)


def test_pages_for_rounds_up():
    assert pages_for(0, 4) == 0
    assert pages_for(1, 4) == 1
    assert pages_for(4, 4) == 1
    assert pages_for(5, 4) == 2


# ---------------------------------------------------------------------------
# gather/scatter index helpers
# ---------------------------------------------------------------------------

def test_gather_indices_right_aligns_content():
    alloc = PageAllocator(capacity_tokens=32, page_size=4)
    sched = ContinuousScheduler(2, alloc)
    row = sched.admit(rid=0, prompt_len=6, budget=4)       # pages for 6 toks
    width, chunk = 16, 4
    idx = gather_indices(sched.rows, 2, width, chunk, 4)
    offset0 = width - chunk
    # columns before the row's kv_start and the whole empty slot read NULL
    kv_start = offset0 - row.length
    assert (idx[0, :kv_start] == NULL_PAGE).all()
    assert (idx[1] == NULL_PAGE).all()
    # content columns map logical position t to pages[t//P]*P + t%P
    for t in range(row.length):
        want = row.pages[t // 4] * 4 + t % 4
        assert idx[0, kv_start + t] == want
    # the chunk's columns are not yet content: NULL
    assert (idx[0, offset0:] == NULL_PAGE).all()


def test_scatter_indices_cover_budget_and_trash_the_rest():
    alloc = PageAllocator(capacity_tokens=32, page_size=4)
    sched = ContinuousScheduler(2, alloc)
    row = sched.admit(rid=0, prompt_len=5, budget=2)
    sched.ensure_chunk_pages(chunk=4)                       # covers 5 + 2
    idx = scatter_indices(sched.rows, 2, 4, 4)
    covered = row.covered(4)
    for j in range(4):
        t = row.length + j
        if t < covered:
            assert idx[0, j] == row.pages[t // 4] * 4 + t % 4
        else:
            assert TRASH_PAGE * 4 <= idx[0, j] < (TRASH_PAGE + 1) * 4
    # empty slot writes land entirely in TRASH
    assert ((idx[1] >= TRASH_PAGE * 4) & (idx[1] < (TRASH_PAGE + 1) * 4)).all()


def test_live_rows_gather_disjoint_flat_ranges():
    """Two rows' content indices must never alias (the device-side analogue
    of the no-double-allocation invariant)."""
    alloc = PageAllocator(capacity_tokens=64, page_size=4)
    sched = ContinuousScheduler(3, alloc)
    sched.admit(rid=0, prompt_len=7, budget=4)
    sched.admit(rid=1, prompt_len=9, budget=4)
    idx = gather_indices(sched.rows, 3, 32, 4, 4)
    content = idx[idx != NULL_PAGE]
    assert len(set(content.tolist())) == len(content)


# ---------------------------------------------------------------------------
# scheduler simulation harness
# ---------------------------------------------------------------------------

def _simulate(n_slots, page_size, capacity_tokens, chunk, requests):
    """Drive the scheduler with a fake decode; return telemetry for the
    invariant assertions.  ``requests`` is [(prompt_len, max_new), ...] in
    submit order; each satisfies the submit-time capacity check."""
    alloc = PageAllocator(capacity_tokens, page_size)
    sched = ContinuousScheduler(n_slots, alloc)
    queue = [(rid, p, m) for rid, (p, m) in enumerate(requests)]
    first_admit, completed = [], []
    seen_admitted = set()
    rounds = 0
    while queue or sched.rows:
        rounds += 1
        assert rounds < 10_000, "scheduler failed to drain (starvation?)"
        # strict FIFO: only the queue head may enter service
        while queue and sched.can_admit(queue[0][1]):
            rid, p, m = queue.pop(0)
            sched.admit(rid, p, m)
            if rid not in seen_admitted:
                seen_admitted.add(rid)
                first_admit.append(rid)
        preempted = sched.ensure_chunk_pages(chunk)
        # preempted rows restart from scratch at the queue FRONT (rid order)
        queue = [(r.rid,) + requests[r.rid]
                 for r in sorted(preempted, key=lambda r: r.rid)] + queue

        # ---- invariants checked every chunk boundary ----
        live_pages = [p for r in sched.rows.values() for p in r.pages]
        assert len(set(live_pages)) == len(live_pages), "page double-alloc"
        assert all(p >= RESERVED_PAGES for p in live_pages)
        assert len(live_pages) == alloc.used_pages
        assert len(live_pages) <= alloc.usable_pages, "capacity exceeded"
        idx = gather_indices(sched.rows, n_slots,
                             max((r.length for r in sched.rows.values()),
                                 default=0) + chunk, chunk, page_size)
        content = idx[idx >= RESERVED_PAGES * page_size]
        assert len(set(content.tolist())) == len(content), "gather aliasing"

        # ---- simulated decode: each live row emits up to `chunk` tokens ----
        for row in list(sched.live):
            emitted = min(chunk, row.budget_left)
            assert row.length + emitted <= row.covered(page_size), \
                "decode would write past the row's allocated pages"
            row.length += emitted
            row.budget_left -= emitted
            if row.budget_left == 0:
                completed.append(row.rid)
                sched.evict(row)
    return alloc, sched, first_admit, completed


def _workload(rng, n_requests, capacity_tokens):
    reqs = []
    for _ in range(n_requests):
        p = rng.randint(1, max(1, capacity_tokens // 2))
        m = rng.randint(1, capacity_tokens - p)
        reqs.append((p, m))
    return reqs


@settings(max_examples=30, derandomize=True)   # pinned seed in CI
@given(n_slots=st.integers(1, 4),
       page_size=st.sampled_from([1, 2, 4, 8, 16]),
       capacity_tokens=st.integers(24, 96),
       chunk=st.sampled_from([1, 2, 4, 8]),
       n_requests=st.integers(1, 12),
       workload_seed=st.integers(0, 2**16))
def test_scheduler_invariants_under_random_workloads(
        n_slots, page_size, capacity_tokens, chunk, n_requests,
        workload_seed):
    rng = random.Random(workload_seed)
    requests = _workload(rng, n_requests, capacity_tokens)
    alloc, sched, first_admit, completed = _simulate(
        n_slots, page_size, capacity_tokens, chunk, requests)
    # every request completed exactly once (no starvation), FIFO first-service
    assert sorted(completed) == list(range(n_requests))
    assert first_admit == sorted(first_admit), \
        f"admission order {first_admit} violates FIFO"
    # freed pages always returned: the drained pool is whole again
    assert alloc.free_pages == alloc.usable_pages
    assert alloc.used_pages == 0
    assert alloc.alloc_count == alloc.free_count
    assert not sched.rows


@settings(max_examples=10, derandomize=True)   # pinned seed in CI
@given(workload_seed=st.integers(0, 2**16))
def test_tight_pool_forces_preemption_but_still_drains(workload_seed):
    """A pool barely bigger than the largest request must preempt (youngest
    first) yet still complete everything in FIFO first-service order."""
    rng = random.Random(workload_seed)
    capacity = 16
    requests = [(rng.randint(4, 8), rng.randint(6, capacity - 8))
                for _ in range(6)]
    alloc, sched, first_admit, completed = _simulate(
        n_slots=3, page_size=2, capacity_tokens=capacity, chunk=2,
        requests=requests)
    assert sorted(completed) == list(range(len(requests)))
    assert first_admit == sorted(first_admit)
    assert alloc.free_pages == alloc.usable_pages


def test_preemption_never_picks_the_oldest_row():
    """The oldest admitted row is the one the FIFO guarantee protects: with
    a pool sized for one big request, a younger row is the victim."""
    alloc = PageAllocator(capacity_tokens=16, page_size=2)   # 8 pages
    sched = ContinuousScheduler(2, alloc)
    old = sched.admit(rid=0, prompt_len=8, budget=8)         # 4 pages now
    young = sched.admit(rid=1, prompt_len=6, budget=8)       # 3 pages now
    preempted = sched.ensure_chunk_pages(chunk=8)            # old needs 8 more
    assert [r.rid for r in preempted] == [1]
    assert old.slot in sched.rows and young.slot not in sched.rows
    assert sched.preemptions == 1
    # and the old row is now fully covered for its next chunk
    assert old.covered(2) >= old.length + min(8, old.budget_left)


def test_eviction_returns_exact_pages():
    alloc = PageAllocator(capacity_tokens=32, page_size=4)
    sched = ContinuousScheduler(2, alloc)
    row = sched.admit(rid=0, prompt_len=10, budget=4)
    taken = list(row.pages)
    sched.evict(row)
    assert alloc.used_pages == 0
    # the exact pages are reusable immediately
    again = alloc.alloc(len(taken))
    assert sorted(again) == sorted(taken)
