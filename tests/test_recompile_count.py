"""Recompile-count regression tests — the dynamic complement of the static
trace-purity lint (``scripts/analyze.py lint``).

The static checks prove nothing syncs *inside* a trace; these prove the
engine's bucketing policy keeps the number of traces themselves bounded.
Every distinct (plen bucket, width bucket) pair costs one XLA compile; if
bucketing regressed to per-exact-length shapes, steady-state serving would
recompile per request — the exact pathology PR 2 removed.  jit's
compilation-cache counter (``jitted._cache_size()``) is the ground truth:
it counts compiled variants, not calls.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.serve.engine import _bucket_len


@pytest.fixture(scope="module")
def engine_setup():
    from repro.configs.catalog import ARCHITECTURES
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig
    cfg = ARCHITECTURES["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_batch=4, max_len=64,
                                            scheduler="wave"))
    return cfg, eng


@pytest.fixture(scope="module")
def continuous_setup():
    from repro.configs.catalog import ARCHITECTURES
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig
    cfg = ARCHITECTURES["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_batch=4, max_len=64))
    assert eng.stats()["scheduler"] == "continuous"
    return cfg, eng


def _gen(eng, cfg, lengths, new_tokens):
    prompts = [[(3 * i + j) % cfg.vocab_size for j in range(n)]
               for i, n in enumerate(lengths)]
    return eng.generate(prompts, new_tokens)


def test_prefill_compiles_bounded_by_plen_buckets(engine_setup):
    cfg, eng = engine_setup
    # lengths spanning two plen buckets (<=8 -> 8, 9..16 -> 16), one width
    _gen(eng, cfg, [3, 5], 4)
    _gen(eng, cfg, [12, 14], 4)
    _gen(eng, cfg, [4, 15], 4)
    buckets = eng.stats()["prefill_plen_buckets"]
    assert buckets == [8, 16]
    assert eng._prefill._cache_size() <= len(buckets), (
        f"{eng._prefill._cache_size()} prefill compiles for "
        f"{len(buckets)} plen buckets — bucketing is leaking shapes")


def test_decode_loop_compiles_bounded_by_width_buckets(engine_setup):
    cfg, eng = engine_setup
    # max_new_tokens 4 and 7 share the width-8 bucket; 12 opens width 16
    _gen(eng, cfg, [3], 4)
    _gen(eng, cfg, [3], 7)
    _gen(eng, cfg, [3], 12)
    widths = {_bucket_len(4), _bucket_len(7), _bucket_len(12)}
    assert widths == {8, 16}
    assert eng._loop is not None
    assert eng._loop._cache_size() <= len(widths), (
        f"{eng._loop._cache_size()} loop compiles for width buckets "
        f"{sorted(widths)} — (width, unroll) signature is leaking")


def test_steady_state_adds_no_compiles(engine_setup):
    """Repeating previously-seen shapes must hit the jit cache exactly."""
    cfg, eng = engine_setup
    out1 = _gen(eng, cfg, [3, 12], 4)
    before = (eng._prefill._cache_size(), eng._loop._cache_size())
    out2 = _gen(eng, cfg, [3, 12], 4)
    after = (eng._prefill._cache_size(), eng._loop._cache_size())
    assert after == before, (
        f"steady-state generate recompiled: {before} -> {after}")
    assert out1 == out2


def test_cache_counter_is_live():
    """Guard the guard: _cache_size must actually count compilations, or
    the bounds above would vacuously pass on a broken counter."""
    calls = jax.jit(lambda x: x + 1)
    assert calls._cache_size() == 0
    calls(jnp.zeros((2,)))
    assert calls._cache_size() == 1
    calls(jnp.zeros((2,)))           # cache hit
    assert calls._cache_size() == 1
    calls(jnp.zeros((3,)))           # new shape -> new compile
    assert calls._cache_size() == 2


# -- continuous scheduler (paged KV) -----------------------------------------

def test_continuous_steady_state_zero_recompiles(continuous_setup):
    """Admission/eviction churn in steady state must be compile-free: the
    chunk fn is keyed only on (width bucket, chunk, unroll) and the admit fn
    on the plen bucket, so repeating a workload whose shapes were all seen
    before must add ZERO compiled variants to either."""
    cfg, eng = continuous_setup
    # 6 requests over 4 slots with budgets spanning 2 chunks: mid-decode
    # evictions, a second admission wave, several width buckets
    lengths = [3, 5, 12, 4, 7, 9]
    out1 = _gen(eng, cfg, lengths, 12)
    assert eng.stats()["admissions"] >= 6          # churn actually happened
    assert eng.stats()["chunks"] >= 2
    before = (eng._chunk_fn._cache_size(), eng._admit_fn._cache_size())
    out2 = _gen(eng, cfg, lengths, 12)
    after = (eng._chunk_fn._cache_size(), eng._admit_fn._cache_size())
    assert after == before, (
        f"steady-state continuous decode recompiled: {before} -> {after}")
    assert out1 == out2


def test_continuous_one_device_get_per_chunk(continuous_setup, monkeypatch):
    """The continuous drain's host-transfer contract: exactly one
    device_get per decode chunk — admission, eviction and block-table
    bookkeeping are host-side and must not add transfers."""
    cfg, eng = continuous_setup
    _gen(eng, cfg, [3, 5, 12, 4], 12)            # compile outside the count
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda *a, **k: (
        calls.append(1), real(*a, **k))[1])
    chunks0 = eng.stats()["chunks"]
    _gen(eng, cfg, [3, 5, 12, 4, 7, 9], 12)
    chunks = eng.stats()["chunks"] - chunks0
    assert chunks >= 2
    assert len(calls) == chunks, (
        f"{len(calls)} host transfers for {chunks} chunks")
