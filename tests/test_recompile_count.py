"""Recompile-count regression tests — the dynamic complement of the static
trace-purity lint (``scripts/analyze.py lint``).

The static checks prove nothing syncs *inside* a trace; these prove the
engine's bucketing policy keeps the number of traces themselves bounded.
Every distinct (plen bucket, width bucket) pair costs one XLA compile; if
bucketing regressed to per-exact-length shapes, steady-state serving would
recompile per request — the exact pathology PR 2 removed.  jit's
compilation-cache counter (``jitted._cache_size()``) is the ground truth:
it counts compiled variants, not calls.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.serve.engine import _bucket_len


@pytest.fixture(scope="module")
def engine_setup():
    from repro.configs.catalog import ARCHITECTURES
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig
    cfg = ARCHITECTURES["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_batch=4, max_len=64))
    return cfg, eng


def _gen(eng, cfg, lengths, new_tokens):
    prompts = [[(3 * i + j) % cfg.vocab_size for j in range(n)]
               for i, n in enumerate(lengths)]
    return eng.generate(prompts, new_tokens)


def test_prefill_compiles_bounded_by_plen_buckets(engine_setup):
    cfg, eng = engine_setup
    # lengths spanning two plen buckets (<=8 -> 8, 9..16 -> 16), one width
    _gen(eng, cfg, [3, 5], 4)
    _gen(eng, cfg, [12, 14], 4)
    _gen(eng, cfg, [4, 15], 4)
    buckets = eng.stats()["prefill_plen_buckets"]
    assert buckets == [8, 16]
    assert eng._prefill._cache_size() <= len(buckets), (
        f"{eng._prefill._cache_size()} prefill compiles for "
        f"{len(buckets)} plen buckets — bucketing is leaking shapes")


def test_decode_loop_compiles_bounded_by_width_buckets(engine_setup):
    cfg, eng = engine_setup
    # max_new_tokens 4 and 7 share the width-8 bucket; 12 opens width 16
    _gen(eng, cfg, [3], 4)
    _gen(eng, cfg, [3], 7)
    _gen(eng, cfg, [3], 12)
    widths = {_bucket_len(4), _bucket_len(7), _bucket_len(12)}
    assert widths == {8, 16}
    assert eng._loop is not None
    assert eng._loop._cache_size() <= len(widths), (
        f"{eng._loop._cache_size()} loop compiles for width buckets "
        f"{sorted(widths)} — (width, unroll) signature is leaking")


def test_steady_state_adds_no_compiles(engine_setup):
    """Repeating previously-seen shapes must hit the jit cache exactly."""
    cfg, eng = engine_setup
    out1 = _gen(eng, cfg, [3, 12], 4)
    before = (eng._prefill._cache_size(), eng._loop._cache_size())
    out2 = _gen(eng, cfg, [3, 12], 4)
    after = (eng._prefill._cache_size(), eng._loop._cache_size())
    assert after == before, (
        f"steady-state generate recompiled: {before} -> {after}")
    assert out1 == out2


def test_cache_counter_is_live():
    """Guard the guard: _cache_size must actually count compilations, or
    the bounds above would vacuously pass on a broken counter."""
    calls = jax.jit(lambda x: x + 1)
    assert calls._cache_size() == 0
    calls(jnp.zeros((2,)))
    assert calls._cache_size() == 1
    calls(jnp.zeros((2,)))           # cache hit
    assert calls._cache_size() == 1
    calls(jnp.zeros((3,)))           # new shape -> new compile
    assert calls._cache_size() == 2
