"""Flash-attention Pallas kernel vs naive oracle: shape/dtype/causal sweeps
(interpret mode), per task-required kernel validation protocol."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref


def _qkv(b, sq, skv, h, kvh, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, skv, kvh, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, skv, kvh, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("sq,skv,bq,bk", [
    (64, 64, 16, 16), (128, 128, 32, 64), (64, 128, 64, 32), (32, 32, 32, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(sq, skv, bq, bk, causal):
    if causal and sq != skv:
        pytest.skip("causal requires aligned q/kv ends in this test")
    q, k, v = _qkv(2, sq, skv, 4, 4, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_gqa_grouping():
    q, k, v = _qkv(2, 64, 64, 8, 2, 16, jnp.float32, seed=3)
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    q, k, v = _qkv(1, 64, 64, 2, 2, 32, jnp.bfloat16, seed=5)
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_extreme_values_stable():
    """Online softmax must not overflow with large logits."""
    q, k, v = _qkv(1, 32, 32, 2, 2, 16, jnp.float32, seed=7)
    q = q * 30.0
    out = flash_attention(q, k, v, causal=True, bq=16, bk=16, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_non_divisible_seq_len_padded():
    """S % bq != 0 and S % bk != 0: the kernel left-pads internally and the
    result must still match the unpadded oracle exactly."""
    q, k, v = _qkv(2, 40, 40, 4, 2, 16, jnp.float32, seed=11)
    out = flash_attention(q, k, v, causal=True, bq=16, bk=16, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def _ref_ragged(q, k, v, kv_start):
    """Oracle for left-padded ragged rows: per-row causal+pad mask."""
    import jax
    d = q.shape[-1]
    s = jnp.einsum("bqhd,bthd->bhqt", q.astype(jnp.float32) * d ** -0.5,
                   k.astype(jnp.float32))
    sq = q.shape[1]
    mask = jnp.tril(jnp.ones((sq, sq), bool))[None, None]
    mask = mask & (jnp.arange(sq)[None, None, None, :]
                   >= kv_start[:, None, None, None])
    p = jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1)
    return jnp.einsum("bhqt,bthd->bqhd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def test_flash_ragged_kv_start_matches_masked_ref():
    """Per-row kv_start masking (left-padded ragged batch), including a row
    whose valid length is not divisible by bq."""
    b, s, h, d = 3, 24, 2, 16
    q, k, v = _qkv(b, s, s, h, h, d, jnp.float32, seed=13)
    kv_start = jnp.asarray([0, 5, 17], jnp.int32)
    out = flash_attention(q, k, v, causal=True, bq=8, bk=8, interpret=True,
                          kv_start=kv_start)
    ref = _ref_ragged(q, k, v, kv_start)
    for i, st in enumerate([0, 5, 17]):   # pad rows are don't-care
        np.testing.assert_allclose(np.asarray(out[i, st:]),
                                   np.asarray(ref[i, st:]),
                                   rtol=2e-4, atol=2e-4)


def test_flash_ragged_rows_match_their_solo_runs():
    """Each ragged row must equal the same row run alone and unpadded — the
    kernel-level version of the serve engine's parity guarantee."""
    b, s, h, d = 3, 24, 2, 16
    q, k, v = _qkv(b, s, s, h, h, d, jnp.float32, seed=17)
    starts = [0, 5, 17]
    out = flash_attention(q, k, v, causal=True, bq=8, bk=8, interpret=True,
                          kv_start=jnp.asarray(starts, jnp.int32))
    for i, st in enumerate(starts):
        solo = flash_attention(q[i:i + 1, st:], k[i:i + 1, st:],
                               v[i:i + 1, st:], causal=True, bq=8, bk=8,
                               interpret=True)
        np.testing.assert_allclose(np.asarray(out[i, st:]),
                                   np.asarray(solo[0]),
                                   rtol=2e-4, atol=2e-4)


def test_tuned_entry_point_resolves_blocks_from_registry():
    """core.flash_attention (the public tuned entry point) pulls (bq, bk)
    from the op="flash_attention" registry bucket."""
    import repro.core as core
    from repro.core.attention_api import flash_tile_lookup

    q, k, v = _qkv(1, 32, 32, 2, 2, 16, jnp.float32, seed=19)
    core.GLOBAL_REGISTRY.put_op(
        core.OP_FLASH_ATTENTION, core.FlashAttentionConfig(16, 16),
        "tpu-v5e", jnp.float32, (32, 32, 16))
    try:
        res = flash_tile_lookup("tpu-v5e", jnp.float32, 32, 32, 16)
        assert res.source == "exact"
        assert res.config == core.FlashAttentionConfig(16, 16)
        out = core.flash_attention(q, k, v, causal=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
    finally:
        # drop the synthetic entry so other tests see pristine lookups
        core.GLOBAL_REGISTRY._exact.get(
            (core.OP_FLASH_ATTENTION, "tpu-v5e", "float32"), {}
        ).pop((32, 32, 16), None)


def test_prefill_with_cache_routes_through_flash(monkeypatch):
    """Satellite bugfix: attn_impl="flash" must be honored for prefill even
    though a KV cache is being filled (the old routing silently fell back to
    the chunked path whenever kv_cache was not None)."""
    import dataclasses
    from repro.configs.catalog import ARCHITECTURES
    from repro.kernels import flash_attention as fa_mod
    from repro.models import build_model

    calls = []
    real = fa_mod.flash_attention_bhsd
    monkeypatch.setattr(fa_mod, "flash_attention_bhsd",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])

    cfg = dataclasses.replace(ARCHITECTURES["llama3.2-1b"].reduced(),
                              attention_impl="flash")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 32)
    batch = {"tokens": jnp.asarray([[3, 1, 4, 1], [5, 9, 2, 6]], jnp.int32)}
    logits, cache = model.prefill(params, batch, cache)
    assert calls, "prefill with a KV cache did not reach the flash kernel"

    # and the chunked model produces the same logits (numerics parity)
    m_c = build_model(dataclasses.replace(cfg, attention_impl="chunked"))
    logits_c, _ = m_c.prefill(params, batch, model.init_cache(2, 32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_c),
                               rtol=2e-4, atol=2e-4)

    # decode steps stay on the chunked path (documented fallback)
    calls.clear()
    tok = jnp.asarray([[1], [2]], jnp.int32)
    model.decode_step(params, tok, cache, jnp.int32(4))
    assert not calls, "decode step must not use the flash kernel"


def test_model_with_flash_attention_matches_chunked():
    """Selectable attention backend: flash == chunked at the model level."""
    import dataclasses
    from repro.configs.catalog import ARCHITECTURES
    from repro.models import build_model

    cfg_c = ARCHITECTURES["llama3.2-1b"].reduced()
    cfg_f = dataclasses.replace(cfg_c, attention_impl="flash")
    m_c, m_f = build_model(cfg_c), build_model(cfg_f)
    params = m_c.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg_c.vocab_size)}
    lc, _ = m_c.forward(params, batch)
    lf, _ = m_f.forward(params, batch)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(lf),
                               rtol=2e-4, atol=2e-4)
