"""Flash-attention Pallas kernel vs naive oracle: shape/dtype/causal sweeps
(interpret mode), per task-required kernel validation protocol."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref


def _qkv(b, sq, skv, h, kvh, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, skv, kvh, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, skv, kvh, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("sq,skv,bq,bk", [
    (64, 64, 16, 16), (128, 128, 32, 64), (64, 128, 64, 32), (32, 32, 32, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(sq, skv, bq, bk, causal):
    if causal and sq != skv:
        pytest.skip("causal requires aligned q/kv ends in this test")
    q, k, v = _qkv(2, sq, skv, 4, 4, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_gqa_grouping():
    q, k, v = _qkv(2, 64, 64, 8, 2, 16, jnp.float32, seed=3)
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    q, k, v = _qkv(1, 64, 64, 2, 2, 32, jnp.bfloat16, seed=5)
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_extreme_values_stable():
    """Online softmax must not overflow with large logits."""
    q, k, v = _qkv(1, 32, 32, 2, 2, 16, jnp.float32, seed=7)
    q = q * 30.0
    out = flash_attention(q, k, v, causal=True, bq=16, bk=16, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_model_with_flash_attention_matches_chunked():
    """Selectable attention backend: flash == chunked at the model level."""
    import dataclasses
    from repro.configs.catalog import ARCHITECTURES
    from repro.models import build_model

    cfg_c = ARCHITECTURES["llama3.2-1b"].reduced()
    cfg_f = dataclasses.replace(cfg_c, attention_impl="flash")
    m_c, m_f = build_model(cfg_c), build_model(cfg_f)
    params = m_c.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg_c.vocab_size)}
    lc, _ = m_c.forward(params, batch)
    lf, _ = m_f.forward(params, batch)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(lf),
                               rtol=2e-4, atol=2e-4)
