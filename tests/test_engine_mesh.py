"""Mesh-sharded serving: spec parsing, topology plumbing, and the tentpole
token-for-token parity guarantee (1-device engine == data=4,model=2 mesh).

The parity tests need 8 devices; in-process versions run when the session
already exposes them (the CI multi-device leg sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and a slow
subprocess version forces them for single-device sessions (full tier).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch.mesh import MESH_AXES, build_mesh, describe_mesh, parse_mesh_spec

# one representative per model family (dense / ssm / moe / vlm / audio)
FAMILIES = ["llama3.2-1b", "mamba2-130m", "olmoe-1b-7b",
            "llama-3.2-vision-11b", "whisper-large-v3"]

PROMPTS = [[5, 9, 2, 7], [1, 3, 3], [2, 4, 6, 8, 1, 5, 3], [9, 9, 1],
           [4, 4], [7, 1, 2, 3, 4], [8, 8, 8], [1, 2]]


# ---------------------------------------------------------------------------
# Spec parsing / mesh construction (no multi-device requirement)
# ---------------------------------------------------------------------------

def test_parse_mesh_spec():
    assert parse_mesh_spec("data=4,model=2") == {"data": 4, "model": 2}
    assert list(parse_mesh_spec("model=2,data=4")) == ["model", "data"]
    assert parse_mesh_spec("pod=2, data=2 , model=1") == {
        "pod": 2, "data": 2, "model": 1}


@pytest.mark.parametrize("bad", [
    "", "data", "data=4,data=2", "ring=4", "data=x", "data=0", "data=-1",
])
def test_parse_mesh_spec_rejects(bad):
    with pytest.raises(ValueError, match="mesh spec"):
        parse_mesh_spec(bad)


def test_build_mesh_none_and_trivial():
    assert build_mesh(None) is None
    assert build_mesh("") is None
    mesh = build_mesh("data=1,model=1")
    assert mesh.axis_names == ("data", "model")
    assert describe_mesh(mesh) == {"devices": 1,
                                   "axes": {"data": 1, "model": 1},
                                   "label": "data1xmodel1"}
    assert describe_mesh(None) == {"devices": 1, "axes": None, "label": None}


def test_build_mesh_auto_uses_all_devices():
    mesh = build_mesh("auto")
    assert mesh.axis_names == ("data",)
    assert mesh.size == len(jax.devices())


def test_build_mesh_too_many_devices_is_actionable():
    n = len(jax.devices()) * 2
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        build_mesh(f"data={n}")


def test_mesh_axes_vocabulary_matches_rules():
    """The spec axes the parser admits are exactly the names the sharding
    rules know how to map."""
    from repro.distributed.sharding import ShardingRules
    rules = ShardingRules()
    known = {rules.tensor_axis, rules.fsdp_axis, *rules.batch_axes, "pod"}
    assert set(MESH_AXES) <= known


# ---------------------------------------------------------------------------
# In-process mesh engine tests (run under the CI multi-device leg)
# ---------------------------------------------------------------------------

needs_8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _build(arch, mesh=None):
    from repro.configs.catalog import ARCHITECTURES
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig
    cfg = ARCHITECTURES[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    eng = Engine(model, params,
                 ServeConfig(max_batch=8, max_len=64, mesh=mesh))
    prompts = [[t % cfg.vocab_size for t in p] for p in PROMPTS]
    extra = {k: jnp.zeros((len(prompts),) + s.shape[1:], s.dtype)
             for k, s in model.extra_inputs(len(prompts)).items()}
    return model, params, eng, prompts, (extra or None)


@needs_8
@pytest.mark.parametrize("arch", FAMILIES)
def test_mesh_parity_all_families(arch):
    """Tentpole acceptance: a data=4,model=2 mesh serves token-for-token
    what the single-device engine serves — sharding is a pure layout knob."""
    _, _, base, prompts, extra = _build(arch)
    _, _, meshed, _, _ = _build(arch, mesh="data=4,model=2")
    out_base = base.generate(prompts, 5, extra_inputs=extra)
    out_mesh = meshed.generate(prompts, 5, extra_inputs=extra)
    assert out_mesh == out_base, arch


@needs_8
def test_mesh_continuous_vs_wave_parity():
    """Paged continuous decode on a data=4,model=2 mesh serves the same
    tokens as the wave engine on the SAME mesh, with the page size resolved
    from a mesh-keyed tuned ``paged_attn`` entry."""
    from repro.configs.catalog import ARCHITECTURES
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig
    cfg = ARCHITECTURES["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = [[t % cfg.vocab_size for t in p] for p in PROMPTS]
    cont = Engine(model, params,
                  ServeConfig(max_batch=8, max_len=64, mesh="data=4,model=2"))
    wave = Engine(model, params,
                  ServeConfig(max_batch=8, max_len=64, mesh="data=4,model=2",
                              scheduler="wave"))
    assert cont.generate(prompts, 5) == wave.generate(prompts, 5)
    st = cont.stats()
    assert st["scheduler"] == "continuous"
    assert st["chunks"] >= 1 and st["admissions"] >= len(prompts)
    # tuned/cpu-interpret.json carries a data4xmodel2-tagged paged_attn
    # entry; the mesh label is part of the lookup key
    assert st["page_size_source"].startswith("tuned:")
    assert st["page_size"] == 16


@needs_8
def test_mesh_stats_provenance():
    _, _, eng, prompts, _ = _build("llama3.2-1b", mesh="data=4,model=2")
    eng.generate(prompts[:2], 3)
    st = eng.stats()
    assert st["mesh"] == {"devices": 8, "axes": {"data": 4, "model": 2},
                          "label": "data4xmodel2"}
    assert st["sharding"]["rules"]["tensor_axis"] == "model"
    # serving replicates weights over the data axes (inference TP) — the
    # profiling layer showed FSDP-style gathers serializing the decode loop
    assert st["sharding"]["rules"]["fsdp_axis"] is None
    assert sum(st["sharding"]["params"].values()) > 0
    # some param leaves actually landed on the model axis
    assert any("'model'" in k for k in st["sharding"]["params"])


@needs_8
def test_mesh_local_shape_tile_lookups():
    """Tuned-tile lookups on a mesh are keyed by the per-shard LOCAL GEMM
    shape — TP/FSDP change which tuned entry is hit."""
    _, _, eng, prompts, _ = _build("llama3.2-1b", mesh="data=4,model=2")
    eng.generate(prompts[:8], 3)
    lookups = eng.stats()["decode_tile_lookups"]
    assert lookups
    shrunk = 0
    for key, info in lookups.items():
        global_shape = key.split("->")[0]
        m, k, n = (int(x) for x in global_shape.split("x"))
        lm, lk, ln = (int(x) for x in info["local_shape"].split("x"))
        assert lm <= m and lk <= k and ln <= n
        shrunk += (lm, lk, ln) != (m, k, n)
    assert shrunk > 0, f"no lookup used a local shape: {lookups}"
    # square attention projections (wq: embed->ff vs wo: ff->embed) shard
    # the same global (K, N) both ways — both variants must be reported
    variant_keys = [key for key in lookups if "->" in key]
    assert len(variant_keys) >= 2, lookups
    # single-device engines don't report local shapes
    _, _, base, _, _ = _build("llama3.2-1b")
    base.generate(prompts[:2], 3)
    assert all("local_shape" not in v
               for v in base.stats()["decode_tile_lookups"].values())


@needs_8
def test_ambient_use_mesh_is_picked_up():
    """distributed.ctx.use_mesh installs the topology for engines (and
    Model.init) that are not handed a mesh explicitly."""
    from repro.distributed import use_mesh
    mesh = build_mesh("data=4,model=2")
    with use_mesh(mesh):
        _, _, eng, prompts, _ = _build("llama3.2-1b")
        assert eng.mesh is mesh
        out = eng.generate(prompts[:4], 3)
    _, _, base, _, _ = _build("llama3.2-1b")
    assert base.mesh is None
    assert base.generate(prompts[:4], 3) == out


@needs_8
def test_use_mesh_none_clears_ambient_topology():
    """use_mesh(None) inside an outer mesh scope restores single-device
    behavior — the way a parity check builds its unsharded reference."""
    from repro.distributed import current_mesh, use_mesh
    mesh = build_mesh("data=4,model=2")
    with use_mesh(mesh):
        assert current_mesh() is mesh
        with use_mesh(None):
            assert current_mesh() is None
            _, _, eng, _, _ = _build("llama3.2-1b")
            assert eng.mesh is None
        assert current_mesh() is mesh


@needs_8
def test_sharded_init_matches_unsharded_values():
    """Model.init(mesh=...) changes the layout, never the values."""
    from repro.configs.catalog import ARCHITECTURES
    from repro.distributed import sharding as sh
    from repro.models import build_model
    cfg = ARCHITECTURES["llama3.2-1b"].reduced()
    model = build_model(cfg)
    mesh = build_mesh("data=4,model=2")
    plain = model.init(jax.random.PRNGKey(7))
    sharded = model.init(jax.random.PRNGKey(7), mesh=mesh)
    jax.tree_util.tree_map(
        lambda a, b: None if (a == b).all() else pytest.fail("values drifted"),
        plain, sharded)
    # and at least one leaf is genuinely partitioned across devices
    leaves = jax.tree_util.tree_leaves(sharded)
    assert any(not l.sharding.is_fully_replicated for l in leaves)


@needs_8
def test_mesh_streaming_and_warm_cache_parity():
    """The serving front-end composes with sharding: streamed tokens on a
    data=4,model=2 mesh equal the single-device batch output, and a second
    (warm, full-hit) pass through the prefix cache serves the exact same
    tokens — layout and caching are both invisible in the output."""
    from repro.serve import Request, Server
    _, _, base, prompts, _ = _build("llama3.2-1b")
    _, _, meshed, _, _ = _build("llama3.2-1b", mesh="data=4,model=2")
    expected = base.generate(prompts, 5)
    for wanted_hits in (0, len(prompts)):      # cold pass, then warm pass
        before = meshed.stats()["prefix_cache"]["hits_full"]
        events = [[] for _ in prompts]
        with Server(meshed) as srv:
            handles = [srv.submit(Request(prompt=p, max_new_tokens=5,
                                          stream=events[i].append))
                       for i, p in enumerate(prompts)]
            results = [h.result(timeout=600) for h in handles]
        assert [r.tokens for r in results] == expected
        assert [[e.token for e in ev if not e.finished]
                for ev in events] == expected
        hits = meshed.stats()["prefix_cache"]["hits_full"] - before
        assert hits >= wanted_hits


@needs_8
def test_per_token_sync_baseline_mesh_parity():
    """The serving benchmark's sync baseline accepts a mesh so the headline
    ratio compares execution models at fixed placement — sharding it must
    stay pure layout: same tokens on and off the mesh."""
    from repro.configs.catalog import ARCHITECTURES
    from repro.models import build_model
    from repro.serve import PerTokenSyncEngine
    cfg = ARCHITECTURES["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    # uniform lengths: the sync baseline has no ragged handling
    prompts = [[(5 * i + j) % cfg.vocab_size for j in range(8)]
               for i in range(8)]
    plain = PerTokenSyncEngine(model, params, max_len=64)
    meshed = PerTokenSyncEngine(model, params, max_len=64,
                                mesh="data=4,model=2")
    assert meshed.mesh is not None and meshed.rules is not None
    out_plain = plain.generate(prompts, 5)
    out_mesh = meshed.generate(prompts, 5)
    assert out_mesh == out_plain
    # the mesh engine's params really are sharded, not just re-placed
    leaves = jax.tree_util.tree_leaves(meshed.params)
    assert any(not l.sharding.is_fully_replicated for l in leaves)


# ---------------------------------------------------------------------------
# Subprocess variant for single-device sessions (full tier)
# ---------------------------------------------------------------------------

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs.catalog import ARCHITECTURES
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig

    PROMPTS = {prompts!r}
    cfg = ARCHITECTURES[{arch!r}].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = [[t % cfg.vocab_size for t in p] for p in PROMPTS]
    extra = {{k: jnp.zeros((len(prompts),) + s.shape[1:], s.dtype)
              for k, s in model.extra_inputs(len(prompts)).items()}} or None
    base = Engine(model, params, ServeConfig(max_batch=8, max_len=64))
    out1 = base.generate(prompts, 5, extra_inputs=extra)
    meshed = Engine(model, params,
                    ServeConfig(max_batch=8, max_len=64, mesh="data=4,model=2"))
    out2 = meshed.generate(prompts, 5, extra_inputs=extra)
    st = meshed.stats()
    print("RESULT " + json.dumps({{
        "parity": out1 == out2,
        "devices": st["mesh"]["devices"],
        "axes": st["mesh"]["axes"]}}))
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILIES)
def test_mesh_parity_subprocess(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROC.format(arch=arch, prompts=PROMPTS)],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    rec = json.loads(line[len("RESULT "):])
    assert rec["parity"], arch
    assert rec["devices"] == 8
    assert rec["axes"] == {"data": 4, "model": 2}
