"""IR checker tests: check semantics on synthetic summaries (fast, no
tracing), static jit-key enumeration and its IR004 diff, fingerprint
stability and re-bless mechanics, CLI exit codes, legacy tuned-DB loading
under the IR artifact pass, and the seeded PR-6 regression (FSDP rules
leaking into serving) being caught by IR001 — all without ever executing
a program on a device.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis.findings import SEV_ERROR, SEV_WARNING
from repro.analysis.ir import checks, fingerprints, recompile
from repro.analysis.ir.matrix import (DTYPES, FAMILIES, SCHEDULERS, IRCase,
                                      default_matrix, smoke_matrix)
from repro.analysis.ir.trace import CaseResult, EntrySummary

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

WEIGHT = [64, 64]              # a weight leaf shape of the synthetic case
ACTIVATION = [4, 16, 64]       # same numel (4096) but an activation shape


def _summary(entry, **kw):
    base = dict(
        entry=entry, jaxpr_hash="h" * 8, prim_histogram={"dot_general": 2},
        converts=[], dots=[], f64_avals=0,
        memory={"argument_bytes": 1024, "output_bytes": 512,
                "temp_bytes": 256, "peak_bytes": None},
        while_collectives=[], collectives=[])
    base.update(kw)
    return EntrySummary(**base)


def _case(entries, dtype="bfloat16", hardware="cpu-interpret", errors=None):
    return CaseResult(
        case_id=f"llama3.2-1b/wave/single/{dtype}",
        entries=entries,
        weight_shapes=[WEIGHT, [2] + WEIGHT, [128, 64]],
        params_bytes=1 << 20, hardware=hardware, jax_version="x",
        errors=errors or {})


def _ids(findings):
    return sorted({f.check_id for f in findings})


# ---------------------------------------------------------------------------
# IR000-IR003 semantics on synthetic summaries
# ---------------------------------------------------------------------------

def test_ir000_trace_error_is_a_finding():
    case = _case({}, errors={"prefill": "ValueError: boom"})
    fs = checks.check_trace_errors(case)
    assert _ids(fs) == ["IR000"] and fs[0].scope == "prefill"
    assert "boom" in fs[0].message


def test_ir001_flags_weight_shaped_gather_in_decode_loop():
    rec = {"op": "all-gather", "numel": 4096, "bytes": 8192, "dims": WEIGHT}
    case = _case({"decode_loop": _summary("decode_loop",
                                          while_collectives=[rec, rec])})
    fs = checks.check_collectives(case)
    assert len(fs) == 1 and fs[0].check_id == "IR001"
    assert fs[0].severity == SEV_ERROR and fs[0].scope == "decode_loop"
    assert "2x" in fs[0].message and "fsdp=False" in fs[0].message


def test_ir001_scan_sliced_weight_shape_also_flags():
    rec = {"op": "all-reduce", "numel": 8192, "bytes": 16384,
           "dims": [2, 64, 64]}
    case = _case({"decode_chunk": _summary("decode_chunk",
                                           while_collectives=[rec])})
    assert _ids(checks.check_collectives(case)) == ["IR001"]


def test_ir001_ignores_activation_collectives():
    """The discriminator is the *shape*: an activation whose element count
    collides with a weight's must not fire (the IR002 smoke false positive
    that motivated shape matching)."""
    rec = {"op": "all-reduce", "numel": 4096, "bytes": 8192,
           "dims": ACTIVATION}
    case = _case({"decode_loop": _summary("decode_loop",
                                          while_collectives=[rec])})
    assert checks.check_collectives(case) == []


def test_ir001_only_decode_entries_gate():
    """Prefill/train legitimately gather FSDP-sharded weights."""
    rec = {"op": "all-gather", "numel": 4096, "bytes": 8192, "dims": WEIGHT}
    case = _case({"prefill": _summary("prefill", while_collectives=[rec]),
                  "train_step": _summary("train_step",
                                         while_collectives=[rec])})
    assert checks.check_collectives(case) == []


def test_ir002_f64_anywhere_is_an_error():
    case = _case({"prefill": _summary("prefill", f64_avals=3)})
    fs = checks.check_numerics(case)
    assert _ids(fs) == ["IR002"] and "float64" in fs[0].message


def test_ir002_weight_upcast_only_in_bf16_serve_entries():
    conv = {"src": "bfloat16", "dst": "float32", "numel": 4096,
            "dims": WEIGHT}
    act = {"src": "bfloat16", "dst": "float32", "numel": 4096,
           "dims": ACTIVATION}
    # bf16 case, serve entry, weight shape -> fires
    case = _case({"prefill": _summary("prefill", converts=[conv])})
    assert _ids(checks.check_numerics(case)) == ["IR002"]
    # activation-shaped upcast (numel collision) -> clean
    case = _case({"prefill": _summary("prefill", converts=[act])})
    assert checks.check_numerics(case) == []
    # train_step is exempt: f32 master params are the mixed-precision recipe
    case = _case({"train_step": _summary("train_step", converts=[conv])})
    assert checks.check_numerics(case) == []
    # fp32 case has no bf16 contract to defend
    case = _case({"prefill": _summary("prefill", converts=[conv])},
                 dtype="float32")
    assert checks.check_numerics(case) == []


def test_ir002_dot_accumulate_allowlist():
    ok = {"lhs": "bfloat16", "rhs": "bfloat16", "out": "float32"}
    bad = {"lhs": "float32", "rhs": "float32", "out": "float16"}
    case = _case({"prefill": _summary("prefill", dots=[ok])})
    assert checks.check_numerics(case) == []
    case = _case({"prefill": _summary("prefill", dots=[ok, bad])})
    fs = checks.check_numerics(case)
    assert _ids(fs) == ["IR002"] and "allowlist" in fs[0].message


def test_ir003_budget_error_warning_and_fallback():
    profile_budget = 8 * 1024**3          # cpu-interpret hbm_bytes
    over = _summary("prefill",
                    memory={"argument_bytes": None, "output_bytes": None,
                            "temp_bytes": None,
                            "peak_bytes": profile_budget + 1})
    case = _case({"prefill": over})
    fs = checks.check_memory(case)
    assert _ids(fs) == ["IR003"] and fs[0].severity == SEV_ERROR
    warn = _summary("prefill",
                    memory={"argument_bytes": None, "output_bytes": None,
                            "temp_bytes": None,
                            "peak_bytes": int(profile_budget * 0.9)})
    fs = checks.check_memory(_case({"prefill": warn}))
    assert fs and fs[0].severity == SEV_WARNING
    # no backend peak -> argument+output+temp sum
    assert checks.peak_bytes(_summary("x")) == 1024 + 512 + 256


def test_ir003_unknown_hardware_is_an_error():
    case = _case({"prefill": _summary("prefill")}, hardware="martian-npu")
    fs = checks.check_memory(case)
    assert _ids(fs) == ["IR003"] and "unregistered" in fs[0].message


# ---------------------------------------------------------------------------
# IR004 static jit-key enumeration
# ---------------------------------------------------------------------------

def test_wave_keys_match_engine_bucket_policy():
    keys = recompile.wave_keys(max_len=64, unroll=1)
    assert keys["prefill"] and keys["decode_loop"]
    # every key is a bucket the engine could actually produce
    from repro.serve.engine import _bucket_len
    for (plen,) in keys["prefill"]:
        assert plen >= 1
    for (width, unroll) in keys["decode_loop"]:
        assert width == _bucket_len(width) and unroll == 1


def test_bucket_bump_changes_ir004_counts():
    """A serve-shape/bucket change must move the static key count — the
    signal IR004 pins in the fingerprint file."""
    small = recompile.wave_keys(64, 1)
    big = recompile.wave_keys(128, 1)
    assert len(big["prefill"]) > len(small["prefill"])
    c8 = recompile.continuous_keys(64, 4, chunk=8, unroll=1)
    c16 = recompile.continuous_keys(64, 4, chunk=16, unroll=1)
    assert c8["decode_chunk"] != c16["decode_chunk"]


def test_continuous_unroll_clamped_to_chunk_divisor():
    keys = recompile.continuous_keys(64, 4, chunk=8, unroll=3)
    for (_w, chunk, u) in keys["decode_chunk"]:
        assert chunk % u == 0


def test_ir004_diff_names_the_entry_point():
    record = {"jit_keys": {"prefill": 12, "decode_loop": 7, "total": 19},
              "entries": {}}
    committed = {"jax_version": "x",
                 "cases": {"c": {"jit_keys": {"prefill": 10,
                                              "decode_loop": 7, "total": 17},
                                 "entries": {}}}}
    fs = fingerprints.compare_case("c", record, committed, jax_matches=True)
    assert _ids(fs) == ["IR004"]
    assert sorted(f.scope for f in fs) == ["prefill", "total"]
    assert "10 -> 12" in [f for f in fs if f.scope == "prefill"][0].message


# ---------------------------------------------------------------------------
# IR005 fingerprints
# ---------------------------------------------------------------------------

def _entry_rec(h, prims):
    return {"jaxpr_hash": h, "prims": prims}


def test_ir005_hash_drift_gates_only_on_matching_jax_version():
    record = {"jit_keys": {}, "entries": {
        "prefill": _entry_rec("new", {"dot_general": 4,
                                      "convert_element_type": 2})}}
    committed = {"jax_version": "0.4.37", "cases": {"c": {
        "jit_keys": {}, "entries": {
            "prefill": _entry_rec("old", {"dot_general": 5})}}}}
    errs = fingerprints.compare_case("c", record, committed,
                                     jax_matches=True)
    assert [f.severity for f in errs] == [SEV_ERROR]
    assert "+2 convert_element_type" in errs[0].message
    assert "-1 dot_general" in errs[0].message
    warns = fingerprints.compare_case("c", record, committed,
                                      jax_matches=False)
    assert [f.severity for f in warns] == [SEV_WARNING]


def test_ir005_unfingerprinted_case_and_entry_churn():
    fs = fingerprints.compare_case(
        "new-case", {"jit_keys": {}, "entries": {}},
        {"jax_version": "x", "cases": {}}, jax_matches=True)
    assert _ids(fs) == ["IR005"] and "no committed fingerprint" in \
        fs[0].message
    record = {"jit_keys": {}, "entries": {"admit": _entry_rec("h", {})}}
    committed = {"jax_version": "x", "cases": {"c": {
        "jit_keys": {}, "entries": {"decode_chunk": _entry_rec("h", {})}}}}
    fs = fingerprints.compare_case("c", record, committed, jax_matches=True)
    assert sorted(f.scope for f in fs) == ["admit", "decode_chunk"]
    assert all(f.check_id == "IR005" for f in fs)


def test_fingerprint_file_schema_mismatch_names_rebless(tmp_path):
    path = tmp_path / "fp.json"
    path.write_text(json.dumps({"schema_version": 999, "cases": {}}))
    with pytest.raises(ValueError, match="--write-fingerprints"):
        fingerprints.load_fingerprints(str(path))


def test_merge_keeps_other_legs(tmp_path):
    path = str(tmp_path / "fp.json")
    fingerprints.merge_fingerprints(
        {"a/x": {"jit_keys": {"total": 1}, "entries": {}}}, "v", path)
    fingerprints.merge_fingerprints(
        {"b/y": {"jit_keys": {"total": 2}, "entries": {}}}, "v", path)
    blob = fingerprints.load_fingerprints(path)
    assert sorted(blob["cases"]) == ["a/x", "b/y"]


def test_committed_fingerprints_cover_the_full_matrix():
    """The acceptance matrix: 5 families x 2 schedulers x 2 meshes x 2
    dtypes, every cell blessed in tests/ir_fingerprints.json."""
    blob = fingerprints.load_fingerprints()
    cases = default_matrix(mesh_specs=(None, "data=4,model=2"))
    assert len(cases) == len(FAMILIES) * len(SCHEDULERS) * 2 * len(DTYPES)
    for case in cases:
        rec = blob["cases"].get(case.case_id)
        assert rec is not None, f"unblessed matrix cell {case.case_id}"
        assert set(rec["entries"]) == set(case.entries)
        assert rec["jit_keys"]["total"] == sum(
            v for k, v in rec["jit_keys"].items() if k != "total")


# ---------------------------------------------------------------------------
# fingerprint stability (real traces; summaries come off .ir_cache when warm)
# ---------------------------------------------------------------------------

def test_same_config_traces_to_identical_hashes():
    from repro.analysis.ir.trace import trace_case
    case = IRCase("llama3.2-1b", "continuous", None, "bfloat16")
    a = trace_case(case)
    b = trace_case(case)
    assert not a.errors and not b.errors
    assert {e: s.jaxpr_hash for e, s in a.entries.items()} == \
        {e: s.jaxpr_hash for e, s in b.entries.items()}


def test_fresh_trace_matches_committed_fingerprint():
    """Cross-process determinism: the committed file was blessed in a
    different process; a fresh in-process trace must reproduce its hashes
    (only comparable on the jax version the file was blessed under)."""
    import jax
    blob = fingerprints.load_fingerprints()
    if blob.get("jax_version") != jax.__version__:
        pytest.skip("fingerprints blessed under a different jax version")
    from repro.analysis.ir.trace import trace_case
    case = IRCase("llama3.2-1b", "continuous", None, "bfloat16")
    fresh = trace_case(case)
    committed = blob["cases"][case.case_id]["entries"]
    for entry, summary in fresh.entries.items():
        assert summary.jaxpr_hash == committed[entry]["jaxpr_hash"], entry


# ---------------------------------------------------------------------------
# legacy tuned DBs under the IR artifact pass
# ---------------------------------------------------------------------------

def test_legacy_tuned_dbs_load_under_ir_unroll_resolution(tmp_path,
                                                          monkeypatch):
    """Every schema the repo ever committed (v1/v2 flat GEMM, v3 op-keyed,
    v4 mesh-labeled) must still load into the registry the IR pass's
    static unroll resolution consults."""
    from repro.core import tuning_db as tdb
    from repro.core.registry import OP_DECODE_LOOP, TileRegistry

    flat = {"dtype": "bfloat16", "m": 256, "k": 256, "n": 256,
            "bm": 128, "bk": 256, "bn": 256, "source": "model",
            "seconds": 1e-5, "gflops": 1.0}
    blobs = {
        "v1.json": {"schema_version": 1, "hardware": "cpu-interpret",
                    "entries": [flat]},
        "v2.json": {"schema_version": 2, "hardware": "cpu-interpret",
                    "entries": [dict(flat, m=512)]},
        "v3.json": {"schema_version": 3, "hardware": "cpu-interpret",
                    "entries": [{"op": "decode_loop", "dtype": "bfloat16",
                                 "shape": [4, 64], "block": [2],
                                 "source": "model"}]},
    }
    for name, blob in blobs.items():
        (tmp_path / name).write_text(json.dumps(blob))
        db = tdb.TuningDB.from_file(str(tmp_path / name))   # loads cleanly
        assert len(db) == 1
    # v4 (current): written through the API, mesh-labeled decode_loop entry
    db = tdb.TuningDB("cpu-interpret")
    db.add(tdb.TuningRecord(op=OP_DECODE_LOOP, dtype="bfloat16",
                            shape=(4, 64), block=(2,)))
    db.save(str(tmp_path / "cpu-interpret.json"))

    reg = TileRegistry()
    for name in list(blobs) + ["cpu-interpret.json"]:
        tdb.load_into_registry(reg, str(tmp_path / name))
    reg.mark_autoloaded()
    monkeypatch.setattr("repro.core.registry.GLOBAL_REGISTRY", reg)

    case = IRCase("llama3.2-1b", "continuous", None, "bfloat16")
    unroll = recompile.resolve_static_unroll(case, "cpu-interpret")
    assert unroll == 2                       # the tuned decode_loop entry
    other = IRCase("llama3.2-1b", "wave", "data=4,model=2", "float32")
    assert recompile.resolve_static_unroll(other, "cpu-interpret") >= 1


# ---------------------------------------------------------------------------
# pragma ledger + PR900
# ---------------------------------------------------------------------------

class _FakeMod:
    def __init__(self, lines):
        self.lines = lines


class _FakeGraph:
    def __init__(self, modules):
        self.modules = modules


def test_pragma_scan_ignores_docstring_mentions():
    from repro.analysis import pragmas
    mod = _FakeMod([
        '"""docs show the syntax: # analysis: allow(TP001)"""',
        "x = 1  # analysis: allow(TP001)",
        "# analysis: allow",
        "y = 2",
    ])
    sites = pragmas.scan_pragmas(_FakeGraph({"src/m.py": mod}))
    assert [(s.line, s.check_ids) for s in sites] == \
        [(2, ("TP001",)), (3, None)]
    assert sites[1].label == "allow(*)"


def test_pr900_fires_only_for_stale_pragmas():
    from repro.analysis import pragmas
    mod = _FakeMod(["a = 1  # analysis: allow(TP001)",
                    "b = 2  # analysis: allow(host-transfer)"])
    sites = pragmas.scan_pragmas(_FakeGraph({"src/m.py": mod}))
    ledger = pragmas.PragmaLedger()
    ledger.record("src/m.py", 1, "TP001")     # line 1 earns its keep
    fs = pragmas.unused_pragma_findings(sites, ledger)
    assert len(fs) == 1 and fs[0].check_id == "PR900"
    assert fs[0].line == 2 and fs[0].severity == SEV_ERROR
    # slugs normalize to check ids in the table
    rows = pragmas.pragma_table(sites, ledger)
    assert rows[1]["allows"] == ["TP001"] and rows[1]["live"] is False


def test_repo_pragmas_are_all_live():
    """Zero stale waivers on main — the PR900 gate's goal state."""
    from repro.analysis import pragmas
    from repro.analysis.callgraph import CallGraph
    from repro.analysis.purity import PurityChecker
    graph = CallGraph(REPO)
    ledger = pragmas.PragmaLedger()
    PurityChecker(graph, ledger=ledger).run()
    sites = pragmas.scan_pragmas(graph)
    assert sites, "expected at least one sanctioned pragma in src/repro"
    stale = pragmas.unused_pragma_findings(sites, ledger)
    assert stale == [], [f.render() for f in stale]
    assert ledger.count() >= len(sites)


# ---------------------------------------------------------------------------
# CLI exit codes (0 clean / 1 new findings / 2 usage error)
# ---------------------------------------------------------------------------

def test_cli_usage_error_exits_2():
    from repro.analysis.cli import main
    with pytest.raises(SystemExit) as exc:
        main(["bogus-subcommand"])
    assert exc.value.code == 2
    with pytest.raises(SystemExit) as exc:
        main([])
    assert exc.value.code == 2


def test_cli_pragmas_clean_exits_0():
    from repro.analysis.cli import main
    assert main(["pragmas"]) == 0


def test_cli_ir_smoke_clean_and_unblessed_fails(tmp_path):
    from repro.analysis.cli import main
    out = str(tmp_path / "ir.json")
    assert main(["ir", "--smoke", "--json", out]) == 0
    blob = json.load(open(out))
    assert {r["case"] for r in blob["ir_cases"]} == \
        {c.case_id for c in smoke_matrix()}
    assert blob["errors"] == 0
    # an empty fingerprint file makes every smoke case unblessed -> exit 1
    empty = tmp_path / "fp.json"
    empty.write_text(json.dumps(
        {"schema_version": fingerprints.FINGERPRINT_SCHEMA_VERSION,
         "jax_version": None, "cases": {}}))
    assert main(["ir", "--smoke", "--fingerprints", str(empty)]) == 1


# ---------------------------------------------------------------------------
# the seeded PR-6 regression, caught statically
# ---------------------------------------------------------------------------

def test_seeded_fsdp_regression_is_caught_by_ir001():
    """Revert PR 6's inference-TP rule (ambient fsdp=True sharding rules,
    so decode re-gathers weights every step) and the IR pass must fail
    with IR001 — no device execution anywhere."""
    code = """
from repro.analysis.ir.matrix import IRCase
from repro.analysis.ir.trace import trace_case
from repro.analysis.ir import checks
from repro.launch.mesh import build_mesh
from repro.distributed import sharding as sh

mesh = build_mesh("data=4,model=2")
case = IRCase("llama3.2-1b", "wave", "data=4,model=2", "bfloat16")
bad = trace_case(case, rules_override=sh.rules_for_mesh(mesh, fsdp=True))
assert not bad.errors, bad.errors
found = checks.check_case(bad)
ids = sorted({f.check_id for f in found})
assert "IR001" in ids, (ids, [f.message for f in found])
scopes = {f.scope for f in found if f.check_id == "IR001"}
assert "decode_loop" in scopes, scopes
print("IR001-CAUGHT")
"""
    env = dict(os.environ,
               PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "IR001-CAUGHT" in proc.stdout
