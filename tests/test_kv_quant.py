"""int8 KV-cache quantization: decode consistency within quantization error."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.catalog import ARCHITECTURES
from repro.models import build_model
from repro.models.layers import kv_dequantize, kv_quantize


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    q, s = kv_quantize(x)
    back = kv_dequantize(q, s, jnp.float32)
    amax = np.abs(np.asarray(x)).max(axis=-1, keepdims=True)
    assert (np.abs(np.asarray(back) - np.asarray(x)) <= amax / 127.0 + 1e-6).all()


def test_decode_with_quantized_cache_close_to_exact():
    cfg = dataclasses.replace(ARCHITECTURES["llama3.2-1b"].reduced(),
                              kv_quant=True)
    cfg_ref = ARCHITECTURES["llama3.2-1b"].reduced()
    m_q, m_r = build_model(cfg), build_model(cfg_ref)
    params = m_r.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 13), 0, cfg.vocab_size)
    logits_full, _ = m_r.forward(params, {"tokens": toks})

    cache = m_q.init_cache(2, 32)
    assert cache["self"][0]["q"].dtype == jnp.int8
    lg, cache = m_q.prefill(params, {"tokens": toks[:, :12]}, cache)
    lg_dec, _ = m_q.decode_step(params, toks[:, 12:13], cache, jnp.int32(12))
    # int8 KV: expect small but nonzero error vs exact teacher-forcing
    err = np.abs(np.asarray(lg_dec) - np.asarray(logits_full[:, 12])).max()
    scale = np.abs(np.asarray(logits_full[:, 12])).max()
    assert err < 0.05 * scale + 0.05, (err, scale)
