"""Typed request/response API and the streaming server front-end.

Covers the redesigned public surface end to end: ``submit(Request)`` →
``RequestHandle`` → ``run()`` → sorted ``GenerationResult`` list; the legacy
positional shim (works, warns exactly once per process); Request-level
temperature assertions; the versioned stats schema validating clean on live
engines of both schedulers; and the :class:`Server` — threaded ingestion,
per-token ``StreamEvent`` callbacks token-for-token equal to batch results
across every model family, and its failure modes (extras rejection,
double-start, submit-after-stop).
"""
import threading
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.configs.catalog import ARCHITECTURES
from repro.models import build_model
from repro.serve import (Engine, GenerationResult, Request, RequestHandle,
                         ServeConfig, Server, StreamEvent, stats_schema)
from repro.serve import api


def _build(arch="llama3.2-1b", **serve_kw):
    cfg = ARCHITECTURES[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    kw = dict(max_batch=3, max_len=64)
    kw.update(serve_kw)
    return cfg, model, params, Engine(model, params, ServeConfig(**kw))


RAGGED = [[5, 9, 2, 7], [1, 3, 3], [2, 4, 6, 8, 1, 5, 3]]

# one representative per model family (dense / moe / vlm / audio / hybrid)
FLASH_FAMILIES = ["llama3.2-1b", "olmoe-1b-7b", "llama-3.2-vision-11b",
                  "whisper-large-v3", "zamba2-2.7b"]


# ---------------------------------------------------------------------------
# typed submit/run surface
# ---------------------------------------------------------------------------

def test_generation_result_round_trip():
    """Every field of GenerationResult is populated and self-consistent,
    and run() returns results sorted by request id."""
    cfg, model, params, eng = _build()
    handles = [eng.submit(Request(prompt=p, max_new_tokens=4))
               for p in RAGGED]
    assert all(isinstance(h, RequestHandle) for h in handles)
    results = eng.run()
    assert [r.request_id for r in results] == \
        sorted(h.request_id for h in handles)
    for h, p in zip(sorted(handles, key=lambda h: h.request_id), RAGGED):
        r = h.result(timeout=0)
        assert isinstance(r, GenerationResult)
        assert r.request_id == h.request_id
        assert len(r.tokens) == 4 or r.finish_reason == api.FINISH_STOP
        assert r.finish_reason in (api.FINISH_STOP, api.FINISH_LENGTH)
        assert r.prompt_len == len(p)
        assert r.total_s >= 0.0 and r.tok_per_s >= 0.0
        assert r.ttft_s is None or r.ttft_s >= 0.0
    # typed drains return the tokens the raw engine would have returned
    assert [h.result(timeout=0).tokens for h in handles] == \
        eng.generate(RAGGED, 4)


def test_unfinished_handle_times_out():
    cfg, model, params, eng = _build()
    h = eng.submit(Request(prompt=[1, 2], max_new_tokens=2))
    assert not h.done
    with pytest.raises(TimeoutError):
        h.result(timeout=0)
    eng.run()
    assert h.done and h.result(timeout=0).tokens


def test_legacy_submit_warns_exactly_once_per_process(monkeypatch):
    """The deprecated positional surface still works (rid + {rid: tokens})
    but emits one DeprecationWarning per process, not one per call."""
    import repro.serve.engine as engine_mod
    monkeypatch.setattr(engine_mod, "_LEGACY_SUBMIT_WARNED", False)
    cfg, model, params, eng = _build()
    with pytest.warns(DeprecationWarning, match="docs/SERVING.md"):
        rid = eng.submit([5, 9, 2], 3)
    assert isinstance(rid, int)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        rid2 = eng.submit([1, 3, 3], 3)       # second call: silent
    out = eng.run()
    assert isinstance(out, dict) and set(out) == {rid, rid2}
    assert out[rid] == eng.generate([[5, 9, 2]], 3)[0]


def test_request_temperature_mismatch_rejected_at_submit():
    cfg, model, params, eng = _build()           # greedy (temperature 0.0)
    with pytest.raises(ValueError, match="temperature"):
        eng.submit(Request(prompt=[1, 2], max_new_tokens=2, temperature=0.7))
    eng.submit(Request(prompt=[1, 2], max_new_tokens=2, temperature=0.0))
    eng.run()                                    # matching assertion is fine


def test_typed_submit_rejects_positional_budget():
    cfg, model, params, eng = _build()
    with pytest.raises(TypeError, match="set them on the Request"):
        eng.submit(Request(prompt=[1, 2], max_new_tokens=2), 5)


# ---------------------------------------------------------------------------
# versioned stats schema on live engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["continuous", "wave"])
def test_live_stats_validate_against_schema(scheduler):
    """ST001 guards the source statically; this guards the runtime dict:
    both schedulers emit exactly the documented key set for their mode."""
    cfg, model, params, eng = _build(scheduler=scheduler)
    eng.generate(RAGGED, 3)
    st = eng.stats()
    assert st["schema_version"] == stats_schema.SCHEMA_VERSION
    assert stats_schema.validate_stats(st) == []


def test_prefix_cache_stats_keys_stable_when_disabled():
    """Consumers never branch on key presence: a cache-disabled engine
    reports the same prefix_cache sub-schema, zeroed."""
    cfg, model, params, eng = _build(prefix_cache=False)
    eng.generate(RAGGED, 2)
    pc = eng.stats()["prefix_cache"]
    assert set(pc) == set(stats_schema.PREFIX_CACHE_KEYS)
    assert pc["enabled"] is False and pc["hits_full"] == 0


# ---------------------------------------------------------------------------
# streaming server front-end
# ---------------------------------------------------------------------------

def _stream_collect(eng, prompts, max_new):
    """Serve ``prompts`` through a Server, collecting per-prompt events."""
    events = [[] for _ in prompts]
    results = []
    with Server(eng) as srv:
        handles = [srv.submit(Request(prompt=p, max_new_tokens=max_new,
                                      stream=events[i].append))
                   for i, p in enumerate(prompts)]
        results = [h.result(timeout=300) for h in handles]
    return events, results


def _check_stream(events, result):
    """Event-sequence contract: ordered indices, one terminal event, and
    the streamed tokens reassemble the final result exactly."""
    *toks, terminal = events
    assert [e.index for e in toks] == list(range(len(toks)))
    assert all(isinstance(e, StreamEvent) and not e.finished and
               e.request_id == result.request_id for e in toks)
    assert terminal.finished and terminal.token is None
    assert terminal.index == len(toks)
    assert terminal.finish_reason == result.finish_reason
    assert [e.token for e in toks] == result.tokens


@pytest.mark.parametrize("arch", FLASH_FAMILIES)
def test_streaming_parity_all_families(arch):
    """Streamed tokens == handle results == plain batch generate, for one
    representative of every model family.  Families that need extra_inputs
    (the VLM's image embeddings) stream through the engine directly —
    extras are per-drain, which the open-ended Server rejects by design —
    so the per-token callback contract is covered on both paths."""
    cfg, model, params, eng = _build(arch)
    prompts = [[t % cfg.vocab_size for t in p] for p in RAGGED]
    extra = {k: jnp.zeros((len(prompts),) + s.shape[1:], s.dtype)
             for k, s in model.extra_inputs(len(prompts)).items()}
    expected = eng.generate(prompts, 5, extra_inputs=extra or None)
    if extra:
        events = [[] for _ in prompts]
        handles = [eng.submit(Request(prompt=p, max_new_tokens=5, row=i,
                                      stream=events[i].append))
                   for i, p in enumerate(prompts)]
        eng.run(extra_inputs=extra)
        results = [h.result(timeout=0) for h in handles]
    else:
        events, results = _stream_collect(eng, prompts, 5)
    for ev, res, want in zip(events, results, expected):
        _check_stream(ev, res)
        assert res.tokens == want, arch


def test_stream_callbacks_fire_off_caller_thread():
    """Events are delivered from the worker thread (host-visible at chunk
    boundaries), never synchronously from submit()."""
    cfg, model, params, eng = _build()
    threads = set()
    with Server(eng) as srv:
        h = srv.submit(Request(
            prompt=[5, 9, 2], max_new_tokens=4,
            stream=lambda e: threads.add(threading.current_thread().name)))
        h.result(timeout=300)
    assert threads == {"serve-worker"}


def test_server_ingests_while_draining():
    """A request submitted after the first drain starts still finishes —
    the ingest hook folds it into the live batch."""
    cfg, model, params, eng = _build()
    oracle = eng.generate([[1, 3, 3]], 3)[0]
    with Server(eng) as srv:
        first = srv.submit(Request(prompt=[5, 9, 2, 7], max_new_tokens=12))
        second = srv.submit(Request(prompt=[1, 3, 3], max_new_tokens=3))
        r1, r2 = first.result(timeout=300), second.result(timeout=300)
    assert len(r1.tokens) == 12 or r1.finish_reason == api.FINISH_STOP
    assert r2.tokens == oracle
    st = srv.stats()
    assert st["server"]["submitted"] == 2 and st["server"]["served"] == 2
    assert st["latency"]["count"] >= 2


def test_server_lifecycle_and_rejections():
    cfg, model, params, eng = _build()
    srv = Server(eng).start()
    with pytest.raises(RuntimeError, match="already started"):
        srv.start()
    with pytest.raises(ValueError, match="row"):
        srv.submit(Request(prompt=[1, 2], max_new_tokens=2, row=0))
    srv.stop()
    with pytest.raises(RuntimeError, match="not running"):
        srv.submit(Request(prompt=[1, 2], max_new_tokens=2))
    assert eng._ingest_hook is None              # engine handed back clean
    eng.generate([[1, 2]], 2)                    # and still serves directly
